"""Shared neural-net building blocks (pure functional, params as pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0])
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def rms_norm(x, weight, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight).astype(dtype)


def layer_norm(x, weight, bias, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, 2 * (dim // 2) / d)
    enc = np.zeros((length, d), dtype=np.float32)
    enc[:, 0::2] = np.sin(angle[:, 0::2])
    enc[:, 1::2] = np.cos(angle[:, 1::2])
    return jnp.asarray(enc)


def init_mlp(key, d, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d), dtype=dtype),
    }


def mlp(params, x):
    """SwiGLU gated MLP."""
    gate = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    up = x @ params["w_up"].astype(x.dtype)
    return (gate * up) @ params["w_down"].astype(x.dtype)
