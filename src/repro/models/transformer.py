"""Decoder-only transformer covering dense / MoE / SSM / hybrid / VLM
families, with stacked-layer parameters (scan-friendly), prefill and
single-token decode paths.

Parameter layout: every per-layer tensor is stacked along a leading [L]
axis so the layer loop is a ``lax.scan`` (small HLO, fast 512-device
compiles); ``scan_layers=False`` unrolls for FLOPs-exact cost analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dense_init, embed_init, mlp, init_mlp, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    p = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if not cfg.attn_free:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.family in ("ssm", "hybrid"):
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg, dtype)
    if cfg.family == "hybrid":
        p["mix_gate"] = jnp.ones((2, cfg.d_model), dtype)
    if cfg.moe_experts:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head, k_proj = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=dtype),
    }
    if cfg.family == "vlm":
        # projector from the (stubbed) vision encoder embedding space
        params["img_proj"] = dense_init(k_proj, (cfg.d_model, cfg.d_model),
                                        dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# block forward (full sequence)
# ---------------------------------------------------------------------------

def _block_forward(bp, cfg: ModelConfig, x, positions, kv_chunk):
    """Returns (x_out, aux_loss, (k, v) or None, ssm_state or None)."""
    h = rms_norm(x, bp["norm1"].astype(x.dtype), cfg.norm_eps)
    kv = None
    ssm_h = None
    if cfg.family == "hybrid":
        a_out, kv = attn.full_attention_forward(
            bp["attn"], cfg, h, positions, kv_chunk=kv_chunk)
        s_out, ssm_h = ssm_lib.ssm_forward(bp["ssm"], cfg, h)
        g = bp["mix_gate"].astype(x.dtype)
        x = x + 0.5 * (a_out * g[0] + s_out * g[1])
    elif cfg.attn_free:
        s_out, ssm_h = ssm_lib.ssm_forward(bp["ssm"], cfg, h)
        x = x + s_out
    else:
        a_out, kv = attn.full_attention_forward(
            bp["attn"], cfg, h, positions, kv_chunk=kv_chunk)
        x = x + a_out

    aux = jnp.zeros((), jnp.float32)
    if cfg.moe_experts:
        h2 = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
        y, aux = moe_lib.moe_ffn(bp["moe"], cfg, h2)
        x = x + y
    elif cfg.d_ff:
        h2 = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
        x = x + mlp(bp["mlp"], h2)
    return x, aux, kv, ssm_h


def embed_inputs(params, cfg: ModelConfig, tokens, image_embeds=None):
    """Token (+ optional VLM patch) embedding. Returns [B, S, d] activations."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.family == "vlm":
        if image_embeds is None:
            raise ValueError("vlm arch requires image_embeds")
        img = image_embeds.astype(dtype) @ params["img_proj"].astype(dtype)
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, image_embeds=None, *,
            scan_layers: bool = True, kv_chunk: int = 512,
            remat: bool = False, return_hidden: bool = False):
    """Full-sequence causal forward -> logits [B, S_total, vocab]."""
    x = embed_inputs(params, cfg, tokens, image_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(carry, bp):
        x, aux = carry
        x, aux_i, _, _ = _block_forward(bp, cfg, x, positions, kv_chunk)
        return (x, aux + aux_i), None

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    if scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            bp = jax.tree_util.tree_map(lambda t, i=i: t[i], params["blocks"])
            (x, aux), _ = body((x, aux), bp)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    if return_hidden:
        return x, aux
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeState:
    """Static-shape decode state: KV caches and/or SSM states per layer.

    With cfg.kv_quant the k/v arrays are int8 and k_scale/v_scale hold the
    per-(position, head) symmetric quantization scales."""
    k: Optional[jax.Array]        # [L, B, Smax, KV, hd]
    v: Optional[jax.Array]
    ssm: Optional[jax.Array]      # [L, B, H, P, N]
    length: jax.Array             # [] int32 valid positions
    k_scale: Optional[jax.Array] = None   # [L, B, Smax, KV, 1] f32
    v_scale: Optional[jax.Array] = None

jax.tree_util.register_dataclass(
    DecodeState,
    data_fields=["k", "v", "ssm", "length", "k_scale", "v_scale"],
    meta_fields=[])


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> DecodeState:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    k = v = ssm = k_scale = v_scale = None
    if not cfg.attn_free:
        # sliding-window archs only need a window-sized cache for decode
        alloc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        shape = (cfg.n_layers, batch, alloc, cfg.n_kv_heads,
                 cfg.resolved_head_dim)
        if cfg.kv_quant:
            k = jnp.zeros(shape, jnp.int8)
            v = jnp.zeros(shape, jnp.int8)
            k_scale = jnp.zeros(shape[:-1] + (1,), jnp.float32)
            v_scale = jnp.zeros(shape[:-1] + (1,), jnp.float32)
        else:
            k = jnp.zeros(shape, dtype)
            v = jnp.zeros(shape, dtype)
    if cfg.family in ("ssm", "hybrid"):
        d_inner, H, P, N = ssm_lib.ssm_dims(cfg)
        ssm = jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32)
    return DecodeState(k=k, v=v, ssm=ssm, length=jnp.zeros((), jnp.int32),
                       k_scale=k_scale, v_scale=v_scale)


def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens, *,
                use_kernel: bool = False, scan_layers: bool = True):
    """One decode step. tokens: [B, 1] -> (logits [B, 1, V], new state).

    ``state.length`` counts tokens already in the cache. For sliding-window
    archs the KV cache is a ring buffer of size `sliding_window`.
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dtype)
    length = state.length
    ring = bool(cfg.sliding_window) and not cfg.attn_free
    if ring:
        alloc = state.k.shape[2]
        write_pos = jnp.mod(length, alloc)
        eff_len = jnp.minimum(length, alloc)
    else:
        write_pos = eff_len = length

    def layer(carry, xs):
        x = carry
        bp, kc, vc, ksc, vsc, sc = xs
        h = rms_norm(x, bp["norm1"].astype(x.dtype), cfg.norm_eps)
        new_kc, new_vc, new_ksc, new_vsc, new_sc = kc, vc, ksc, vsc, sc
        if cfg.family == "hybrid":
            a_out, new_kc, new_vc, new_ksc, new_vsc = _decode_attn(
                bp["attn"], cfg, h, kc, vc, ksc, vsc, write_pos, eff_len,
                length, ring, use_kernel)
            s_out, new_sc = ssm_lib.ssm_decode_step(bp["ssm"], cfg, h, sc)
            g = bp["mix_gate"].astype(x.dtype)
            x = x + 0.5 * (a_out * g[0] + s_out * g[1])
        elif cfg.attn_free:
            s_out, new_sc = ssm_lib.ssm_decode_step(bp["ssm"], cfg, h, sc)
            x = x + s_out
        else:
            a_out, new_kc, new_vc, new_ksc, new_vsc = _decode_attn(
                bp["attn"], cfg, h, kc, vc, ksc, vsc, write_pos, eff_len,
                length, ring, use_kernel)
            x = x + a_out
        if cfg.moe_experts:
            h2 = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
            y, _ = moe_lib.moe_ffn(bp["moe"], cfg, h2)
            x = x + y
        elif cfg.d_ff:
            h2 = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
            x = x + mlp(bp["mlp"], h2)
        return x, (new_kc, new_vc, new_ksc, new_vsc, new_sc)

    L = cfg.n_layers
    zeros = jnp.zeros((L,))
    xs = (params["blocks"],
          state.k if state.k is not None else zeros,
          state.v if state.v is not None else zeros,
          state.k_scale if state.k_scale is not None else zeros,
          state.v_scale if state.v_scale is not None else zeros,
          state.ssm if state.ssm is not None else zeros)

    if scan_layers:
        x, (nk, nv, nks, nvs, ns) = jax.lax.scan(layer, x, xs)
    else:
        outs = []
        for i in range(L):
            xs_i = jax.tree_util.tree_map(lambda t, i=i: t[i], xs)
            x, out_i = layer(x, xs_i)
            outs.append(out_i)
        nk, nv, nks, nvs, ns = (jnp.stack([o[j] for o in outs])
                                for j in range(5))

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    new_state = DecodeState(
        k=nk if state.k is not None else None,
        v=nv if state.v is not None else None,
        ssm=ns if state.ssm is not None else None,
        length=length + 1,
        k_scale=nks if state.k_scale is not None else None,
        v_scale=nvs if state.v_scale is not None else None)
    return logits, new_state


def _decode_attn(ap, cfg, x, kc, vc, ksc, vsc, write_pos, eff_len, length,
                 ring, use_kernel):
    """Single-token attention with ring-buffer support for SWA caches and
    optional int8 KV quantization (cfg.kv_quant)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = length[None, None] * jnp.ones((B, 1), jnp.int32)
    q, k, v = attn.qkv_project(ap, cfg, x, pos, rope=True)
    if cfg.kv_quant:
        from repro.kernels.quant_kv import quantize_kv
        k_q, k_s = quantize_kv(k)
        v_q, v_s = quantize_kv(v)
        kc = jax.lax.dynamic_update_slice(kc, k_q, (0, write_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_q, (0, write_pos, 0, 0))
        ksc = jax.lax.dynamic_update_slice(ksc, k_s, (0, write_pos, 0, 0))
        vsc = jax.lax.dynamic_update_slice(vsc, v_s, (0, write_pos, 0, 0))
        k_read = kc.astype(jnp.float32) * ksc
        v_read = vc.astype(jnp.float32) * vsc
    else:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, write_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, write_pos, 0, 0))
        k_read, v_read = kc, vc
    q1 = q[:, 0]
    n_valid = jnp.minimum(eff_len + 1, kc.shape[1])
    if ring:
        # ring buffer: every resident entry is within the window by
        # construction, so attend over all valid slots (no window mask).
        out = _masked_decode_attn(q1, k_read, v_read, n_valid, 0, use_kernel)
    else:
        out = _masked_decode_attn(q1, k_read, v_read, n_valid,
                                  cfg.sliding_window, use_kernel)
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ ap["wo"].astype(x.dtype), kc, vc, ksc, vsc


def _masked_decode_attn(q1, kc, vc, n_valid, window, use_kernel):
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.decode_attention(q1, kc, vc, n_valid, window=window)
    return attn.decode_attention_ref(q1, kc, vc, n_valid, window=window)


def prefill(params, cfg: ModelConfig, tokens, image_embeds=None, *,
            max_len: Optional[int] = None, kv_chunk: int = 512,
            scan_layers: bool = True):
    """Process a full prompt, returning (logits, DecodeState ready to decode).

    Note: for ring-buffer (SWA) archs prefill writes only the last `window`
    positions of K/V into the cache.
    """
    x = embed_inputs(params, cfg, tokens, image_embeds)
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.arange(S)[None, :]

    def body(carry, bp):
        x, aux = carry
        x, aux_i, kv, ssm_h = _block_forward(bp, cfg, x, positions, kv_chunk)
        k, v = kv if kv is not None else (jnp.zeros(()), jnp.zeros(()))
        ssm_h = ssm_h if ssm_h is not None else jnp.zeros(())
        return (x, aux + aux_i), (k, v, ssm_h)

    if scan_layers:
        (x, _), (ks, vs, ssms) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for i in range(cfg.n_layers):
            bp = jax.tree_util.tree_map(lambda t, i=i: t[i], params["blocks"])
            carry, out_i = body(carry, bp)
            outs.append(out_i)
        x = carry[0]
        ks, vs, ssms = (jnp.stack([o[j] for o in outs]) for j in range(3))

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)

    state = init_decode_state(cfg, B, max_len)
    if state.k is not None:
        if cfg.kv_quant:
            from repro.kernels.quant_kv import quantize_kv
            ks, k_sc = quantize_kv(ks)
            vs, v_sc = quantize_kv(vs)
        alloc = state.k.shape[2]
        if cfg.sliding_window and S > alloc:
            # keep the last `alloc` positions, aligned to the ring layout
            shift = S % alloc
            roll_w = lambda a: jnp.roll(a[:, :, -alloc:], shift, axis=2)
            state = dataclasses.replace(
                state, k=roll_w(ks).astype(state.k.dtype),
                v=roll_w(vs).astype(state.v.dtype))
            if cfg.kv_quant:
                state = dataclasses.replace(
                    state, k_scale=roll_w(k_sc), v_scale=roll_w(v_sc))
        else:
            dus = lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0, 0, 0, 0, 0))
            state = dataclasses.replace(state, k=dus(state.k, ks),
                                        v=dus(state.v, vs))
            if cfg.kv_quant:
                state = dataclasses.replace(
                    state, k_scale=dus(state.k_scale, k_sc),
                    v_scale=dus(state.v_scale, v_sc))
    if state.ssm is not None:
        state = dataclasses.replace(state, ssm=ssms.astype(state.ssm.dtype))
    state = dataclasses.replace(state, length=jnp.asarray(S, jnp.int32))
    return logits, state
