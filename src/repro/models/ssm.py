"""Mamba2 / SSD (state-space duality) mixer, chunked for TPU.

TPU adaptation of the paper's SSD algorithm (arXiv:2405.21060): instead of a
CUDA selective-scan, the sequence is split into chunks; within-chunk terms
become dense (MXU-friendly) matmuls via decay-weighted attention-like
matrices, and cross-chunk state is carried by a lax.scan over chunk states.
Decode is the O(1) recurrent update h = a*h + dt*x B^T, y = h C + D*x.

Head layout (ngroups=1): x: [B, S, H, P]; B/C shared across heads [B, S, N].
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def ssm_dims(cfg: ModelConfig):
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
    else:  # hybrid: ssm branch mirrors the attention width
        d_inner = cfg.n_heads * cfg.resolved_head_dim
    n_heads = max(1, d_inner // cfg.ssm_head_dim)
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    kin, kout, ka = jax.random.split(key, 3)
    in_dim = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "w_in": dense_init(kin, (d, in_dim), dtype=dtype),
        "w_out": dense_init(kout, (d_inner, d), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "d_skip": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
    }


def _project(params, cfg: ModelConfig, u):
    d_inner, H, P, N = ssm_dims(cfg)
    zxbcdt = u @ params["w_in"].astype(u.dtype)
    z, x, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # [H], negative
    x = x.reshape(*x.shape[:-1], H, P)
    return z, x, Bm, Cm, dt, A


def _segsum(log_a):
    """log_a: [..., T] -> cumulative segment sums L[..., i, j] = sum_{j<s<=i}."""
    T = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [..., i, j] = sum_{j<s<=i}
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, d_skip, chunk: int, h0=None):
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H]; A: [H]; Bm, Cm: [B, S, N]; d_skip: [H].
    Returns (y [B, S, H, P], h_final [B, H, P, N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    log_a = dtc * A  # [B, nc, T, H]
    log_a_t = log_a.transpose(0, 1, 3, 2)  # [B, nc, H, T]
    seg = _segsum(log_a_t)  # [B, nc, H, T, T]

    # 1) intra-chunk (diagonal block): y[i] = sum_{j<=i} exp(seg[i,j]) dt_j (C_i.B_j) x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                    preferred_element_type=jnp.float32)  # [B, nc, T, T]
    att = jnp.exp(seg) * cb[:, :, None, :, :]  # [B, nc, H, i, j]
    att = att * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # 2) chunk state: S_c = sum_j exp(cum(T)-cum(j)) dt_j x_j B_j^T  [B,nc,H,P,N]
    cum = jnp.cumsum(log_a_t, axis=-1)  # [B, nc, H, T]
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # [B, nc, H, T]
    w = (decay_to_end * dtc.transpose(0, 1, 3, 2)).astype(x.dtype)
    s_chunk = jnp.einsum("bchj,bcjhp,bcjn->bchpn", w, xc, Bc,
                         preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence over chunk states
    a_chunk = jnp.exp(cum[..., -1])  # [B, nc, H] total decay of each chunk

    def scan_fn(h, inp):
        a_c, s_c = inp  # [B,H], [B,H,P,N]
        h_new = h * a_c[..., None, None] + s_c
        return h_new, h  # emit state ENTERING this chunk

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_last, h_in = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (a_chunk.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # [B, nc, H, P, N]

    # 4) inter-chunk contribution: y[i] += exp(cum(i)) * C_i . h_in
    decay_in = jnp.exp(cum).transpose(0, 1, 3, 2)  # [B, nc, T, H]
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, h_in.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * decay_in[..., None]

    y = (y_intra + y_inter).astype(x.dtype) + xc * d_skip[:, None].astype(x.dtype)
    y = y.reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y, h_last


def ssm_forward(params, cfg: ModelConfig, u, h0=None):
    """Full-sequence SSD mixer. u: [B, S, d] -> (y [B, S, d], h_final)."""
    d_inner, H, P, N = ssm_dims(cfg)
    z, x, Bm, Cm, dt, A = _project(params, cfg, u)
    y, h_last = ssd_chunked(x, dt, A, Bm, Cm, params["d_skip"],
                            cfg.ssm_chunk, h0=h0)
    y = y.reshape(*u.shape[:-1], d_inner)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"].astype(u.dtype), h_last


def ssm_decode_step(params, cfg: ModelConfig, u, h):
    """Single-token recurrent update. u: [B, 1, d]; h: [B, H, P, N]."""
    d_inner, H, P, N = ssm_dims(cfg)
    z, x, Bm, Cm, dt, A = _project(params, cfg, u)
    x1 = x[:, 0]          # [B, H, P]
    B1 = Bm[:, 0]         # [B, N]
    C1 = Cm[:, 0]         # [B, N]
    dt1 = dt[:, 0]        # [B, H]
    a = jnp.exp(dt1 * A)  # [B, H]
    upd = jnp.einsum("bhp,bn->bhpn", (dt1[..., None] * x1).astype(jnp.float32),
                     B1.astype(jnp.float32))
    h_new = h * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C1.astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[:, None] * x1.astype(jnp.float32)
    y = y.reshape(u.shape[0], 1, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"].astype(u.dtype), h_new


def ssm_reference(params, cfg: ModelConfig, u):
    """Naive step-by-step recurrence (oracle for ssd_chunked)."""
    d_inner, H, P, N = ssm_dims(cfg)
    B, S, _ = u.shape
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, h = ssm_decode_step(params, cfg, u[:, t : t + 1], h)
        ys.append(y_t)
    return jnp.concatenate(ys, axis=1)
