"""Uniform model API over all families: init / loss / prefill / decode.

Launchers, tests and the DFL layer use only this facade, so the Cached-DFL
protocol stays model-agnostic (it sees opaque parameter pytrees).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.losses import next_token_loss


def init_params(cfg: ModelConfig, key):
    if cfg.enc_dec:
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            scan_layers: bool = True, kv_chunk: int = 512,
            remat: bool = False, aux_weight: float = 0.01):
    """batch: {tokens, [image_embeds | frames]} -> scalar loss."""
    if cfg.enc_dec:
        logits, aux = encdec.forward(params, cfg, batch["frames"],
                                     batch["tokens"], scan_layers=scan_layers,
                                     kv_chunk=kv_chunk, remat=remat)
        return next_token_loss(logits, batch["tokens"])
    logits, aux = transformer.forward(
        params, cfg, batch["tokens"], batch.get("image_embeds"),
        scan_layers=scan_layers, kv_chunk=kv_chunk, remat=remat)
    prefix = cfg.image_tokens if cfg.family == "vlm" else 0
    loss = next_token_loss(logits, batch["tokens"], ignore_prefix=prefix)
    return loss + aux_weight * aux


def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            max_len: Optional[int] = None, scan_layers: bool = True,
            kv_chunk: int = 512):
    if cfg.enc_dec:
        enc_out = encdec.encode(params, cfg, batch["frames"],
                                scan_layers=scan_layers, kv_chunk=kv_chunk)
        B = batch["frames"].shape[0]
        state = encdec.init_serve_state(params, cfg, enc_out, B,
                                        max_len or 512)
        return None, state
    return transformer.prefill(params, cfg, batch["tokens"],
                               batch.get("image_embeds"), max_len=max_len,
                               scan_layers=scan_layers, kv_chunk=kv_chunk)


def decode_step(params, cfg: ModelConfig, state, tokens, *,
                use_kernel: bool = False, scan_layers: bool = True):
    if cfg.enc_dec:
        return encdec.decode_step(params, cfg, state, tokens,
                                  use_kernel=use_kernel)
    return transformer.decode_step(params, cfg, state, tokens,
                                   use_kernel=use_kernel,
                                   scan_layers=scan_layers)


def init_decode_state(params, cfg: ModelConfig, batch: int, max_len: int,
                      frames=None):
    """Allocate a decode state with `max_len` capacity (no prefill)."""
    if cfg.enc_dec:
        if frames is None:
            frames = jnp.zeros((batch, cfg.enc_context, cfg.d_model),
                               jnp.dtype(cfg.compute_dtype))
        enc_out = encdec.encode(params, cfg, frames)
        return encdec.init_serve_state(params, cfg, enc_out, batch, max_len)
    return transformer.init_decode_state(cfg, batch, max_len)
