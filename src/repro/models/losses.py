"""Loss functions for LM training."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits, tokens, *, ignore_prefix: int = 0):
    """Causal LM loss. logits: [B, S, V]; tokens: [B, S_text].

    When the model prepends non-text positions (VLM image tokens), logits
    has S = ignore_prefix + S_text and the loss is computed on text only.
    """
    if ignore_prefix:
        logits = logits[:, ignore_prefix:]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
