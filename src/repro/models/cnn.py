"""The paper's own models (Tables 4-6): MNIST CNN, FashionMNIST CNN, and a
mini-ResNet stand-in for CIFAR — pure-functional JAX with params pytrees.

These are the models the Cached-DFL fleet trains in the reproduction
benchmarks; they must be small enough for a 100-vehicle CPU simulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import CNNConfig


def _conv_init(key, k, cin, cout):
    scale = 1.0 / np.sqrt(k * k * cin)
    return scale * jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout),
                                               jnp.float32)


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def init_params(cfg: CNNConfig, key) -> dict:
    keys = jax.random.split(key, len(cfg.conv_channels) + 3)
    params = {"conv": [], "scale": [], "bias": []}
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.conv_channels):
        params["conv"].append(_conv_init(keys[i], cfg.kernel, cin, cout))
        params["scale"].append(jnp.ones((cout,)))
        params["bias"].append(jnp.zeros((cout,)))
        cin = cout
    hw = cfg.image_hw // (2 ** len(cfg.conv_channels))
    flat = hw * hw * cfg.conv_channels[-1]
    if cfg.fc_hidden:
        params["fc1"] = 1 / np.sqrt(flat) * jax.random.normal(
            keys[-3], (flat, cfg.fc_hidden))
        params["fc1_b"] = jnp.zeros((cfg.fc_hidden,))
        params["fc2"] = 1 / np.sqrt(cfg.fc_hidden) * jax.random.normal(
            keys[-2], (cfg.fc_hidden, cfg.num_classes))
    else:
        params["fc2"] = 1 / np.sqrt(flat) * jax.random.normal(
            keys[-2], (flat, cfg.num_classes))
    params["fc2_b"] = jnp.zeros((cfg.num_classes,))
    return params


def _norm(x, scale, bias, enabled):
    if not enabled:
        return x + bias
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def forward(params, cfg: CNNConfig, images) -> jax.Array:
    """images: [B, H, W, C] -> logits [B, num_classes]."""
    x = images
    for i in range(len(cfg.conv_channels)):
        x = _conv(x, params["conv"][i])
        x = _norm(x, params["scale"][i], params["bias"][i], cfg.batch_norm)
        x = jax.nn.relu(x)
        x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    if cfg.fc_hidden:
        x = jax.nn.relu(x @ params["fc1"] + params["fc1_b"])
    return x @ params["fc2"] + params["fc2_b"]


def loss_fn(params, cfg: CNNConfig, images, labels):
    logits = forward(params, cfg, images)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def accuracy(params, cfg: CNNConfig, images, labels):
    logits = forward(params, cfg, images)
    return jnp.mean(jnp.argmax(logits, -1) == labels)
