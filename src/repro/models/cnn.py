"""The paper's own models (Tables 4-6): MNIST CNN, FashionMNIST CNN, and a
mini-ResNet stand-in for CIFAR — pure-functional JAX with params pytrees.

These are the models the Cached-DFL fleet trains in the reproduction
benchmarks; they must be small enough for a 100-vehicle CPU simulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import CNNConfig


def _conv_init(key, k, cin, cout):
    scale = 1.0 / np.sqrt(k * k * cin)
    return scale * jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout),
                                               jnp.float32)


def _conv(x, w, stride=1, padding="SAME", impl="fast"):
    """2-D convolution, x: [B, H, W, Cin], w: [k, k, Cin, Cout].

    impl="fast" runs the stride-1 SAME case (every conv in these models) as
    im2col + einsum rather than ``lax.conv``: the fleet trains per-agent
    *weights* under ``vmap``, and a batched-kernel conv lowers to grouped
    convolution, which XLA CPU executes an order of magnitude slower than
    the equivalent batched matmul. The einsum form is also MXU-friendly on
    TPU. impl="reference" keeps the plain XLA conv as the numerical oracle.
    """
    k = w.shape[0]
    if impl != "fast" or stride != 1 or padding != "SAME" or k % 2 == 0:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    pad = k // 2
    B, H, W, cin = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = [xp[:, ki:ki + H, kj:kj + W, :]
            for ki in range(k) for kj in range(k)]
    patches = jnp.stack(cols, axis=-2)               # [B, H, W, k*k, cin]
    return jnp.einsum("bhwpc,pcf->bhwf", patches,
                      w.reshape(k * k, cin, w.shape[-1]))


def _maxpool(x, impl="fast"):
    """2×2/stride-2 max pool (VALID semantics).

    impl="fast" pools via reshape — equivalent to ``reduce_window`` but its
    gradient is an argmax mask instead of XLA select-and-scatter, which
    dominates the fleet's local update on CPU. impl="reference" keeps the
    ``reduce_window`` formulation.
    """
    if impl != "fast":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    B, H, W, C = x.shape
    x = x[:, : H // 2 * 2, : W // 2 * 2, :]
    x = x.reshape(B, H // 2, 2, W // 2, 2, C)
    return x.max(axis=(2, 4))


def init_params(cfg: CNNConfig, key) -> dict:
    keys = jax.random.split(key, len(cfg.conv_channels) + 3)
    params = {"conv": [], "scale": [], "bias": []}
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.conv_channels):
        params["conv"].append(_conv_init(keys[i], cfg.kernel, cin, cout))
        params["scale"].append(jnp.ones((cout,)))
        params["bias"].append(jnp.zeros((cout,)))
        cin = cout
    hw = cfg.image_hw // (2 ** len(cfg.conv_channels))
    flat = hw * hw * cfg.conv_channels[-1]
    if cfg.fc_hidden:
        params["fc1"] = 1 / np.sqrt(flat) * jax.random.normal(
            keys[-3], (flat, cfg.fc_hidden))
        params["fc1_b"] = jnp.zeros((cfg.fc_hidden,))
        params["fc2"] = 1 / np.sqrt(cfg.fc_hidden) * jax.random.normal(
            keys[-2], (cfg.fc_hidden, cfg.num_classes))
    else:
        params["fc2"] = 1 / np.sqrt(flat) * jax.random.normal(
            keys[-2], (flat, cfg.num_classes))
    params["fc2_b"] = jnp.zeros((cfg.num_classes,))
    return params


def _norm(x, scale, bias, enabled):
    if not enabled:
        return x + bias
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def forward(params, cfg: CNNConfig, images, impl: str = "fast") -> jax.Array:
    """images: [B, H, W, C] -> logits [B, num_classes]."""
    x = images
    for i in range(len(cfg.conv_channels)):
        x = _conv(x, params["conv"][i], impl=impl)
        x = _norm(x, params["scale"][i], params["bias"][i], cfg.batch_norm)
        x = jax.nn.relu(x)
        x = _maxpool(x, impl=impl)
    x = x.reshape(x.shape[0], -1)
    if cfg.fc_hidden:
        x = jax.nn.relu(x @ params["fc1"] + params["fc1_b"])
    return x @ params["fc2"] + params["fc2_b"]


def loss_fn(params, cfg: CNNConfig, images, labels, impl: str = "fast"):
    logits = forward(params, cfg, images, impl=impl)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def accuracy(params, cfg: CNNConfig, images, labels, impl: str = "fast"):
    logits = forward(params, cfg, images, impl=impl)
    return jnp.mean(jnp.argmax(logits, -1) == labels)
