"""GQA attention: memory-efficient full-sequence (flash-style, chunked KV)
and single-token decode against a KV cache. Optional sliding window.

Shapes use the grouped layout to avoid materializing repeated KV heads:
    q: [B, S, KV, G, hd]   (G = n_heads // n_kv_heads)
    k,v: [B, S, KV, hd]
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def qkv_project(params, cfg: ModelConfig, x, positions, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, hd)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      kv_chunk: int = 512, q_offset: int = 0):
    """Flash-style attention: scan over KV chunks with online softmax.

    q: [B, S, KV, G, hd]; k, v: [B, T, KV, hd]. Memory is O(S * kv_chunk)
    instead of O(S * T). `window` > 0 restricts to a sliding window.
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    kv_chunk = min(kv_chunk, T)
    n_chunks = (T + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        idx, k_blk, v_blk = inp
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        # scores: [B, S, KV, G, C]
        s = jnp.einsum("bskgh,bckh->bskgc", q, k_blk,
                       preferred_element_type=jnp.float32) * scale
        valid = jnp.broadcast_to(k_pos[None, :] < T, (S, kv_chunk))
        if causal:
            valid &= k_pos[None, :] <= q_pos[:, None]
        if window:
            valid &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgc,bckh->bskgh", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def full_attention_forward(params, cfg: ModelConfig, x, positions=None, *,
                           causal: bool = True, kv_chunk: int = 512):
    """Complete attention block forward for train/prefill (returns y, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = qkv_project(params, cfg, x, positions, rope=not cfg.enc_dec)
    out = chunked_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window, kv_chunk=kv_chunk)
    out = out.reshape(B, S, cfg.n_heads * cfg.resolved_head_dim)
    return out @ params["wo"].astype(x.dtype), (k, v)


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token GQA attention against a KV cache (pure-jnp oracle).

    q: [B, KV, G, hd]; caches: [B, Smax, KV, hd]; cache_len: [] int32 —
    number of valid cache positions (the new token's K/V already written).
    """
    B, Smax, KV, hd = k_cache.shape
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    valid = pos < cache_len
    if window:
        valid &= pos > (cache_len - 1 - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


@dataclasses.dataclass
class AttnKVCache:
    """Static-shape KV cache for autoregressive decode."""
    k: jax.Array  # [L, B, Smax, KV, hd]
    v: jax.Array
    length: jax.Array  # [] int32: #valid positions

jax.tree_util.register_dataclass(
    AttnKVCache, data_fields=["k", "v", "length"], meta_fields=[])


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers=None,
                  dtype=jnp.bfloat16) -> AttnKVCache:
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    return AttnKVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32))


def decode_attention_block(params, cfg: ModelConfig, x, layer_k, layer_v,
                           length, *, use_kernel: bool = False):
    """One-token attention for a single layer.

    x: [B, 1, d]; layer_k/v: [B, Smax, KV, hd]; length: cache entries already
    valid BEFORE this token. Returns (y [B,1,d], new_k, new_v).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = length[None, None] * jnp.ones((B, 1), jnp.int32)
    q, k, v = qkv_project(params, cfg, x, pos, rope=not cfg.enc_dec)
    layer_k = jax.lax.dynamic_update_slice(
        layer_k, k.astype(layer_k.dtype), (0, length, 0, 0))
    layer_v = jax.lax.dynamic_update_slice(
        layer_v, v.astype(layer_v.dtype), (0, length, 0, 0))
    q1 = q[:, 0]  # [B, KV, G, hd]
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q1, layer_k, layer_v, length + 1,
                                    window=cfg.sliding_window)
    else:
        out = decode_attention_ref(q1, layer_k, layer_v, length + 1,
                                   window=cfg.sliding_window)
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ params["wo"].astype(x.dtype), layer_k, layer_v


def cross_attention_forward(params, cfg: ModelConfig, x, enc_k, enc_v):
    """Decoder cross-attention over (precomputed) encoder K/V. x: [B,S,d]."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(
        B, S, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, hd)
    out = chunked_attention(q, enc_k, enc_v, causal=False)
    out = out.reshape(B, S, cfg.n_heads * hd)
    return out @ params["wo"].astype(x.dtype)


def encode_kv(params, cfg: ModelConfig, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(
        B, T, cfg.n_kv_heads, hd)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(
        B, T, cfg.n_kv_heads, hd)
    return k, v
