"""Top-k Mixture-of-Experts FFN with capacity-based sort dispatch.

TPU adaptation: instead of torch-style per-expert python loops we use a
sort-based fixed-capacity dispatch (gather -> dense expert matmuls ->
scatter-add), the MaxText-style "dropping" formulation. Compute cost is
proportional to top_k/E * capacity_factor (active experts), which is what
the roofline analysis should see for MoE archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, e), dtype=dtype),
        "w_gate": dense_init(kg, (e, d, f), dtype=dtype),
        "w_up": dense_init(ku, (e, d, f), dtype=dtype),
        "w_down": dense_init(kd, (e, f, d), dtype=dtype),
    }


def _dispatch_one_group(x, expert_ids, gate_w, capacity, num_experts):
    """x: [S, d]; expert_ids/gate_w: [S, k]. Returns MoE output [S, d]."""
    S, d = x.shape
    k = expert_ids.shape[1]
    flat_e = expert_ids.reshape(-1)          # [S*k]
    flat_w = gate_w.reshape(-1)              # [S*k]
    tok = jnp.arange(S * k) // k             # token index per assignment

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = tok[order]
    w_sorted = flat_w[order]

    # position within expert: running index minus expert start offset
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(S * k) - starts[e_sorted]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, e_sorted * capacity + pos_in_e, num_experts * capacity)

    # gather tokens into expert buffers [E*C(+1 overflow), d]
    buf = jnp.zeros((num_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(x[tok_sorted])
    return buf, slot, tok_sorted, w_sorted, keep


def moe_ffn(params, cfg: ModelConfig, x):
    """x: [B, S, d] -> [B, S, d]; router softmax -> top-k -> capacity FFN.

    With cfg.moe_shard_map and an installed mesh (sharding.context), the
    dispatch runs inside shard_map so the sort/scatter stays local to each
    data shard — without this, GSPMD cannot shard the sort and all-gathers
    the GLOBAL batch per device (measured: 64 GiB of all-gather per MoE
    layer on grok-1; see EXPERIMENTS.md §Perf).
    """
    if cfg.moe_shard_map:
        from repro.sharding.context import current_mesh
        mesh = current_mesh()
        if mesh is not None and "data" in mesh.axis_names:
            return _moe_ffn_shard_map(params, cfg, x, mesh)
    return _moe_ffn_gspmd(params, cfg, x)


def _moe_ffn_shard_map(params, cfg: ModelConfig, x, mesh):
    """Manually partitioned MoE: local dispatch per data shard, TP expert
    matmuls over "model" with an explicit psum."""
    from jax.sharding import PartitionSpec as P

    batch_axes = tuple(a for a in mesh.axis_names if a != "model")

    def local_moe(router, w_gate, w_up, w_down, x_local):
        # x_local: [B/data, S, d] (replicated over "model")
        y, aux = _moe_compute(
            {"router": router, "w_gate": w_gate, "w_up": w_up,
             "w_down": w_down}, cfg, x_local,
            psum_axis="model")
        return y, jax.lax.pmean(aux, batch_axes[-1])

    import inspect
    try:
        from jax import shard_map as shard_map_fn  # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map as shard_map_fn
    # the replication-check kwarg was renamed check_rep -> check_vma; key
    # off the actual signature, not the import location
    sig = inspect.signature(shard_map_fn).parameters
    check_kw = ({"check_vma": False} if "check_vma" in sig
                else {"check_rep": False})
    shard = shard_map_fn(
        local_moe, mesh=mesh,
        in_specs=(P(), P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None), P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        **check_kw)
    return shard(params["router"], params["w_gate"], params["w_up"],
                 params["w_down"], x)


def _moe_ffn_gspmd(params, cfg: ModelConfig, x):
    return _moe_compute(params, cfg, x, psum_axis=None)


def _moe_compute(params, cfg: ModelConfig, x, *, psum_axis):
    """Shared MoE body. psum_axis: reduce partial w_down outputs over this
    mesh axis (shard_map path) or None (GSPMD path)."""
    B, S, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    capacity = max(1, int(S * k / e * cfg.moe_capacity_factor))

    logits = x @ params["router"].astype(x.dtype)  # [B, S, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)  # renormalize

    def per_group(xg, eg, wg):
        buf, slot, tok_sorted, w_sorted, keep = _dispatch_one_group(
            xg, eg, wg.astype(xg.dtype), capacity, e)
        ebuf = buf[: e * capacity].reshape(e, capacity, d)
        if cfg.moe_token_shard:
            # beyond-paper sharding variant: shard the expert token buffer
            # over "model" (token-parallel experts) instead of TP-ing d_ff —
            # trades the per-layer activation all-reduce for a dispatch
            # gather (see EXPERIMENTS.md §Perf). No-op without a mesh.
            try:
                from jax.sharding import PartitionSpec as P
                ebuf = jax.lax.with_sharding_constraint(
                    ebuf, P(None, "model", None))
            except Exception:
                pass
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf,
                                      params["w_gate"].astype(xg.dtype)))
        up = jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"].astype(xg.dtype))
        out = jnp.einsum("ecf,efd->ecd", gate * up,
                         params["w_down"].astype(xg.dtype))
        out_flat = jnp.concatenate(
            [out.reshape(e * capacity, d), jnp.zeros((1, d), xg.dtype)], axis=0)
        y = jnp.zeros((S, d), xg.dtype)
        contrib = out_flat[slot] * (w_sorted * keep)[:, None]
        y = y.at[tok_sorted].add(contrib)
        if psum_axis is not None:
            # TP partial over d_ff shards; psum AFTER the (linear) combine
            # so the payload is [S, d], not [E, C, d] (2.5x smaller)
            y = jax.lax.psum(y, psum_axis)
        return y

    y = jax.vmap(per_group)(x, top_e, top_w)

    # router load-balance auxiliary loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y, aux
