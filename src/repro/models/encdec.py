"""Encoder-decoder transformer (whisper-small backbone).

The mel-spectrogram + conv frontend is a STUB per the assignment:
callers provide precomputed frame embeddings [B, frames, d_model].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (dense_init, embed_init, init_mlp, mlp,
                                 rms_norm, sinusoidal_positions)


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "self_attn": attn.init_attention(k1, cfg, dtype),
        "norm_x": jnp.ones((cfg.d_model,), dtype),
        "cross_attn": attn.init_attention(k2, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "token_embed": embed_init(kt, cfg.vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab), dtype=dtype),
    }


def encode(params, cfg: ModelConfig, frames, *, kv_chunk: int = 512,
           scan_layers: bool = True, remat: bool = False):
    """frames: [B, T, d_model] (stubbed conv frontend output) -> [B, T, d]."""
    dtype = jnp.dtype(cfg.compute_dtype)
    T = frames.shape[1]
    x = frames.astype(dtype) + sinusoidal_positions(T, cfg.d_model).astype(dtype)
    positions = jnp.arange(T)[None, :]

    def body(x, bp):
        h = rms_norm(x, bp["norm1"].astype(x.dtype), cfg.norm_eps)
        a, _ = attn.full_attention_forward(bp["attn"], cfg, h, positions,
                                           causal=False, kv_chunk=kv_chunk)
        x = x + a
        h = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
        return x + mlp(bp["mlp"], h), None

    if remat:
        body = jax.checkpoint(body)
    if scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.enc_layers):
            bp = jax.tree_util.tree_map(lambda t, i=i: t[i], params["enc_blocks"])
            x, _ = body(x, bp)
    return rms_norm(x, params["enc_norm"].astype(x.dtype), cfg.norm_eps)


def _dec_block(bp, cfg, x, positions, enc_kv, kv_chunk):
    h = rms_norm(x, bp["norm1"].astype(x.dtype), cfg.norm_eps)
    a, kv = attn.full_attention_forward(bp["self_attn"], cfg, h, positions,
                                        kv_chunk=kv_chunk)
    x = x + a
    h = rms_norm(x, bp["norm_x"].astype(x.dtype), cfg.norm_eps)
    x = x + attn.cross_attention_forward(bp["cross_attn"], cfg, h, *enc_kv)
    h = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
    return x + mlp(bp["mlp"], h), kv


def decode_train(params, cfg: ModelConfig, tokens, enc_out, *,
                 kv_chunk: int = 512, scan_layers: bool = True,
                 remat: bool = False):
    """Teacher-forced decoder forward -> logits [B, S, vocab]."""
    dtype = jnp.dtype(cfg.compute_dtype)
    S = tokens.shape[1]
    x = params["token_embed"][tokens].astype(dtype)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(dtype)
    positions = jnp.arange(S)[None, :]

    def body(x, bp):
        enc_kv = attn.encode_kv(bp["cross_attn"], cfg, enc_out)
        x, _ = _dec_block(bp, cfg, x, positions, enc_kv, kv_chunk)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    if scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    else:
        for i in range(cfg.n_layers):
            bp = jax.tree_util.tree_map(lambda t, i=i: t[i], params["dec_blocks"])
            x, _ = body(x, bp)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return x @ params["lm_head"].astype(x.dtype)


def forward(params, cfg: ModelConfig, frames, tokens, **kw):
    """Full enc-dec forward for training. Returns (logits, aux=0)."""
    enc_out = encode(params, cfg, frames,
                     scan_layers=kw.get("scan_layers", True),
                     kv_chunk=kw.get("kv_chunk", 512),
                     remat=kw.get("remat", False))
    logits = decode_train(params, cfg, tokens, enc_out,
                          scan_layers=kw.get("scan_layers", True),
                          kv_chunk=kw.get("kv_chunk", 512),
                          remat=kw.get("remat", False))
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncDecState:
    k: jax.Array                 # [L, B, Smax, KV, hd] decoder self-attn
    v: jax.Array
    cross_k: jax.Array           # [L, B, T, KV, hd] precomputed from encoder
    cross_v: jax.Array
    length: jax.Array

jax.tree_util.register_dataclass(
    EncDecState, data_fields=["k", "v", "cross_k", "cross_v", "length"],
    meta_fields=[])


def init_serve_state(params, cfg: ModelConfig, enc_out, batch: int,
                     max_len: int, dtype=None) -> EncDecState:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)

    def per_layer(bp):
        return attn.encode_kv(bp["cross_attn"], cfg, enc_out)

    ck, cv = jax.vmap(per_layer)(params["dec_blocks"])
    return EncDecState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        cross_k=ck.astype(dtype), cross_v=cv.astype(dtype),
        length=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ModelConfig, state: EncDecState, tokens, *,
                use_kernel: bool = False):
    """One decoder token against self KV cache + fixed encoder context."""
    dtype = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    length = state.length
    x = params["token_embed"][tokens].astype(dtype)
    pos_emb = sinusoidal_positions(state.k.shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(pos_emb, length, 1)[None].astype(dtype)

    def layer(x, xs):
        bp, kc, vc, ck, cv = xs
        h = rms_norm(x, bp["norm1"].astype(x.dtype), cfg.norm_eps)
        pos = length[None, None] * jnp.ones((B, 1), jnp.int32)
        q, k, v = attn.qkv_project(bp["self_attn"], cfg, h, pos, rope=False)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, length, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, length, 0, 0))
        if use_kernel:
            from repro.kernels import ops as kops
            a = kops.decode_attention(q[:, 0], kc, vc, length + 1)
        else:
            a = attn.decode_attention_ref(q[:, 0], kc, vc, length + 1)
        a = a.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
        x = x + a @ bp["self_attn"]["wo"].astype(x.dtype)

        h = rms_norm(x, bp["norm_x"].astype(x.dtype), cfg.norm_eps)
        q = (h @ bp["cross_attn"]["wq"].astype(h.dtype)).reshape(
            B, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, hd)
        T = ck.shape[1]
        if use_kernel:
            from repro.kernels import ops as kops
            ca = kops.decode_attention(q, ck, cv, jnp.asarray(T, jnp.int32))
        else:
            ca = attn.decode_attention_ref(q, ck, cv, jnp.asarray(T, jnp.int32))
        ca = ca.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
        x = x + ca @ bp["cross_attn"]["wo"].astype(x.dtype)

        h = rms_norm(x, bp["norm2"].astype(x.dtype), cfg.norm_eps)
        x = x + mlp(bp["mlp"], h)
        return x, (kc, vc)

    x, (nk, nv) = jax.lax.scan(
        layer, x,
        (params["dec_blocks"], state.k, state.v, state.cross_k, state.cross_v))
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, dataclasses.replace(state, k=nk, v=nv, length=length + 1)
