"""Streaming scenario service: queue ``Scenario`` specs, batch
compatible specs onto one compiled fleet engine, stream results as JSONL.

The Scenario API was built so a long-running service could accept
serializable experiment specs and amortize compilation across them; this
module is that service. Specs arrive over :meth:`ScenarioService.submit`
(dicts or raw JSONL lines — the ``fleet_serve`` CLI feeds it from a file
or stdin); each is resolved immediately and grouped by its *engine-cache
key* (``repro.fl.runner.engine_cache_key``), so :meth:`drain` runs
same-key specs as consecutive **waves** sharing one live
:class:`~repro.core.rounds.FleetEngine` — the wave-batching idiom of
``serve/scheduler.py`` applied to fleet runs: compile once per key,
retraces across a wave pinned at 0.

Results stream as JSON Lines (``SERVICE_SCHEMA``), one object per line:

    {"schema": ..., "kind": "result", "rid": str, "wave": int,
     "status": "ok" | "error", "attempts": int,
     "result": {config_hash, best_acc, final_acc, epoch, acc, traces,
                wall_s} | "error": str}

followed by one terminal ``{"kind": "summary", ...}`` line with
``runs_ok`` / ``runs_failed`` / ``waves`` / ``num_engines`` /
``retraces``. A malformed or failing spec produces a structured
``status="error"`` line (after ``retries`` bounded re-attempts) and the
queue keeps draining — a bad spec never kills the service.

Per-run queue lifecycle also rides the ``repro-telemetry-v1`` event
stream (``run_queued`` / ``run_batched`` / ``run_failed``) against one
service-session hash, so the standard ``validate_events`` gate applies
to a service session's log unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import uuid
from typing import Any, Callable, Dict, IO, Iterable, List, Mapping, Optional

from repro.fl import presets as presets_lib
from repro.fl import runner as runner_lib
from repro.fl.scenario import Scenario
from repro.telemetry import events as events_lib

SERVICE_SCHEMA = "repro-fleet-serve-v1"

#: compact RunResult fields carried on each streamed result line
RESULT_FIELDS = ("config_hash", "best_acc", "final_acc", "epoch", "acc",
                 "traces", "wall_s")


def parse_spec(spec: Mapping[str, Any]) -> Scenario:
    """One submitted spec object -> Scenario.

    Two accepted shapes: a bare ``Scenario.to_dict()`` payload (has an
    ``experiment`` key), or a wrapper ``{"rid"?, "preset" | "scenario",
    "overrides"?}`` naming a registered preset or embedding a scenario
    dict, with dotted-path overrides applied on top.
    """
    if "experiment" in spec:
        return Scenario.from_dict(spec)
    if "preset" in spec:
        base = presets_lib.get_preset(spec["preset"])
    elif "scenario" in spec:
        base = Scenario.from_dict(spec["scenario"])
    else:
        raise ValueError(
            "spec needs 'experiment' (a Scenario dict), 'preset' (a "
            "registered preset name) or 'scenario' (a nested Scenario "
            f"dict); got keys {sorted(spec)}")
    overrides = spec.get("overrides") or {}
    if overrides:
        base = base.with_overrides(overrides)
    return base


@dataclasses.dataclass
class _Queued:
    rid: str
    scenario: Scenario
    engine_key: Any


class ScenarioService:
    """The streaming run queue (see module docstring).

    ``out`` is an optional writable text stream each JSONL line is pushed
    to as it is produced; lines are always also collected on
    ``self.results`` (parsed objects). ``run_fn(scenario, engines)`` is
    injectable for tests; the default is ``runner.run`` with this
    service's shared engine cache.
    """

    def __init__(self, *, out: Optional[IO[str]] = None, max_wave: int = 8,
                 retries: int = 1, force_traced_budget: bool = False,
                 run_fn: Optional[Callable[[Scenario, Dict], Any]] = None):
        if max_wave < 1:
            raise ValueError(f"max_wave must be >= 1, got {max_wave}")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.out = out
        self.max_wave = max_wave
        self.retries = retries
        self.engines: Dict[Any, Any] = {}   # engine key -> live FleetEngine
        self.queue: List[_Queued] = []
        self.results: List[Dict[str, Any]] = []
        self.events = events_lib.EventLog(f"serve-{uuid.uuid4().hex[:12]}")
        self.runs_ok = 0
        self.runs_failed = 0
        self.waves = 0
        self._auto_rid = 0
        if run_fn is None:
            run_fn = lambda scenario, engines: runner_lib.run(  # noqa: E731
                scenario, engines=engines,
                force_traced_budget=force_traced_budget)
        self._run_fn = run_fn
        self._force_traced_budget = force_traced_budget

    # -- submission ---------------------------------------------------------

    def _next_rid(self) -> str:
        self._auto_rid += 1
        return f"run-{self._auto_rid}"

    def _stream(self, obj: Dict[str, Any]) -> Dict[str, Any]:
        obj = {"schema": SERVICE_SCHEMA, **obj}
        self.results.append(obj)
        if self.out is not None:
            self.out.write(json.dumps(obj, sort_keys=True,
                                      allow_nan=False) + "\n")
            self.out.flush()
        return obj

    def _reject(self, rid: str, error: str) -> str:
        self.events.emit("run_failed", rid=rid, error=error)
        self._stream({"kind": "result", "rid": rid, "wave": -1,
                      "status": "error", "attempts": 0, "error": error})
        self.runs_failed += 1
        return rid

    def submit(self, spec: Mapping[str, Any],
               rid: Optional[str] = None) -> str:
        """Queue one spec; returns its rid. A spec that fails to parse or
        resolve is rejected *now* with a structured error line + a
        ``run_failed`` event — it never reaches a wave."""
        if rid is None:
            rid = (str(spec.get("rid")) if isinstance(spec, Mapping)
                   and spec.get("rid") else self._next_rid())
        try:
            if not isinstance(spec, Mapping):
                raise ValueError(f"spec must be a JSON object, "
                                 f"got {type(spec).__name__}")
            scenario = parse_spec(spec)
            engine_key = runner_lib.engine_cache_key(
                scenario, force_traced_budget=self._force_traced_budget)
        except Exception as e:  # noqa: BLE001 — survive any bad spec
            return self._reject(rid, f"{type(e).__name__}: {e}")
        self.queue.append(_Queued(rid=rid, scenario=scenario,
                                  engine_key=engine_key))
        self.events.emit("run_queued", rid=rid,
                         config=scenario.content_hash())
        return rid

    def submit_lines(self, lines: Iterable[str]) -> List[str]:
        """Feed raw JSONL spec lines (blank lines skipped); returns rids.
        An unparseable line is rejected in place — the queue survives."""
        rids: List[str] = []
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                spec = json.loads(line)
            except json.JSONDecodeError as e:
                rids.append(self._reject(self._next_rid(),
                                         f"invalid JSON: {e}"))
                continue
            rids.append(self.submit(spec))
        return rids

    # -- draining -----------------------------------------------------------

    def _next_wave(self) -> List[_Queued]:
        """Dequeue up to ``max_wave`` runs sharing the oldest queued
        engine key — those runs reuse one compiled engine."""
        if not self.queue:
            return []
        key = self.queue[0].engine_key
        wave = [q for q in self.queue if q.engine_key == key][:self.max_wave]
        taken = {id(q) for q in wave}
        self.queue = [q for q in self.queue if id(q) not in taken]
        return wave

    def _run_one(self, q: _Queued, wave_idx: int) -> None:
        self.events.emit("run_batched", rid=q.rid, wave=wave_idx)
        err = "unknown error"
        for attempt in range(1, self.retries + 2):
            try:
                result = self._run_fn(q.scenario, self.engines)
            except Exception as e:  # noqa: BLE001 — keep the queue alive
                err = f"{type(e).__name__}: {e}"
                self.events.emit("run_failed", rid=q.rid, error=err,
                                 attempt=attempt)
                continue
            payload = result.to_dict() if hasattr(result, "to_dict") \
                else dict(result)
            metrics = payload.get("metrics") or {}
            compact = {k: payload.get(k, metrics.get(k))
                       for k in RESULT_FIELDS}
            self._stream({"kind": "result", "rid": q.rid, "wave": wave_idx,
                          "status": "ok", "attempts": attempt,
                          "result": compact})
            self.runs_ok += 1
            return
        self._stream({"kind": "result", "rid": q.rid, "wave": wave_idx,
                      "status": "error", "attempts": self.retries + 1,
                      "error": err})
        self.runs_failed += 1

    def drain(self) -> Dict[str, Any]:
        """Run every queued spec wave by wave; returns (and streams) the
        terminal summary line."""
        while True:
            wave = self._next_wave()
            if not wave:
                break
            wave_idx = self.waves
            self.waves += 1
            for q in wave:
                self._run_one(q, wave_idx)
        return self._stream({"kind": "summary", "runs_ok": self.runs_ok,
                             "runs_failed": self.runs_failed,
                             "waves": self.waves, **self.engine_stats()})

    def engine_stats(self) -> Dict[str, int]:
        """Compile accounting over the shared engine cache: ``retraces``
        is traces beyond the guaranteed one-per-engine (0 = every wave
        reused its key's compiled executable)."""
        traces = sum(e.traces for e in self.engines.values())
        return {"num_engines": len(self.engines),
                "retraces": traces - len(self.engines)}


# ---------------------------------------------------------------------------
# JSONL validation
# ---------------------------------------------------------------------------

def validate_service_jsonl(lines: Iterable[Any]) -> List[str]:
    """Problems with a service result stream (empty list = valid).

    Accepts parsed objects or raw JSONL strings. Checks the
    ``SERVICE_SCHEMA`` tag, per-kind required keys, that exactly one
    terminal summary line exists, and that its counts match the result
    lines.
    """
    problems: List[str] = []
    rows: List[Mapping[str, Any]] = []
    for i, line in enumerate(lines):
        if isinstance(line, str):
            line = line.strip()
            if not line:
                continue
            try:
                line = json.loads(line)
            except json.JSONDecodeError as e:
                problems.append(f"line {i}: invalid JSON ({e})")
                continue
        if not isinstance(line, Mapping):
            problems.append(f"line {i}: not an object: {line!r}")
            continue
        rows.append(line)
    ok = failed = summaries = 0
    for i, row in enumerate(rows):
        if row.get("schema") != SERVICE_SCHEMA:
            problems.append(f"row {i}: schema={row.get('schema')!r}, "
                            f"expected {SERVICE_SCHEMA!r}")
        kind = row.get("kind")
        if kind == "result":
            missing = [k for k in ("rid", "wave", "status", "attempts")
                       if k not in row]
            if missing:
                problems.append(f"row {i}: result missing {missing}")
            status = row.get("status")
            if status == "ok":
                ok += 1
                if not isinstance(row.get("result"), Mapping):
                    problems.append(f"row {i}: status=ok needs a 'result' "
                                    "object")
            elif status == "error":
                failed += 1
                if not row.get("error"):
                    problems.append(f"row {i}: status=error needs a "
                                    "non-empty 'error'")
            else:
                problems.append(f"row {i}: status={status!r} not in "
                                "('ok', 'error')")
        elif kind == "summary":
            summaries += 1
            missing = [k for k in ("runs_ok", "runs_failed", "waves",
                                   "num_engines", "retraces")
                       if k not in row]
            if missing:
                problems.append(f"row {i}: summary missing {missing}")
        else:
            problems.append(f"row {i}: kind={kind!r} not in "
                            "('result', 'summary')")
    if not rows:
        problems.append("empty service stream")
    if summaries != 1:
        problems.append(f"expected exactly 1 summary line, got {summaries}")
    elif rows and rows[-1].get("kind") != "summary":
        problems.append("summary must be the terminal line")
    else:
        summary = rows[-1]
        if (summary.get("runs_ok") != ok
                or summary.get("runs_failed") != failed):
            problems.append(
                f"summary counts ({summary.get('runs_ok')} ok / "
                f"{summary.get('runs_failed')} failed) disagree with the "
                f"stream ({ok} ok / {failed} failed)")
    return problems
