"""Batched serving scheduler: length-bucketed wave batching over the
static decode state.

TPU adaptation: vLLM-style paged/continuous batching relies on dynamic KV
allocation that does not map onto static SPMD shapes, so this scheduler
uses the honest static alternative real TPU serving stacks start from:

  * requests are bucketed by prompt length (equal-length waves batch
    together without padding-semantics hacks);
  * a wave of ≤ `slots` requests prefills as ONE batch, then decodes in
    lockstep with the compiled decode step (the same program the dry-run
    lowers for decode_32k);
  * finished sequences ride along until the wave drains (their outputs
    are frozen) — the classic static-batching trade-off; per-slot refill
    would need per-slot attention masks (paged attention), noted as the
    next step in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as models


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never stops early
    tokens_out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.tokens_out) >= self.max_new_tokens:
            return True
        return bool(self.tokens_out) and self.tokens_out[-1] == self.eos_id


class BatchScheduler:
    """Length-bucketed wave scheduler over the static decode state."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int,
                 max_len: int, use_kernel: bool = False):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.queue: Dict[int, List[Request]] = defaultdict(list)
        self.finished: Dict[int, Request] = {}
        self.ticks = 0
        self._decode = jax.jit(
            lambda p, s, t: models.decode_step(p, cfg, s, t,
                                               use_kernel=use_kernel))
        self._prefill = jax.jit(
            lambda p, b: models.prefill(p, cfg, b, max_len=max_len))

    def submit(self, req: Request) -> None:
        self.queue[len(req.prompt)].append(req)

    def _next_wave(self) -> List[Request]:
        for length in sorted(self.queue):
            bucket = self.queue[length]
            if bucket:
                wave, self.queue[length] = (bucket[: self.slots],
                                            bucket[self.slots:])
                return wave
        return []

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        prompts = np.stack([r.prompt for r in wave])
        pad = self.slots - B
        if pad:  # keep the compiled batch shape
            prompts = np.concatenate(
                [prompts, np.zeros((pad, prompts.shape[1]), np.int32)])
        logits, state = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i, r in enumerate(wave):
            r.tokens_out.append(int(tok[i, 0]))
        budget = max(r.max_new_tokens for r in wave) - 1
        for _ in range(budget):
            if all(r.done for r in wave):
                break
            logits, state = self._decode(self.params, state, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            self.ticks += 1
            for i, r in enumerate(wave):
                if not r.done:
                    r.tokens_out.append(int(tok[i, 0]))
        for r in wave:
            self.finished[r.rid] = r

    def run(self) -> Dict[int, Request]:
        while True:
            wave = self._next_wave()
            if not wave:
                break
            self._run_wave(wave)
        return self.finished
