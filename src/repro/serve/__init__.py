from repro.serve.scheduler import BatchScheduler, Request  # noqa: F401
from repro.serve.service import (  # noqa: F401
    SERVICE_SCHEMA, ScenarioService, parse_spec, validate_service_jsonl)
