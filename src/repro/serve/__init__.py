from repro.serve.scheduler import BatchScheduler, Request  # noqa: F401
