"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n: int):
    """Inverse of tree_stack: split along axis 0 into n pytrees."""
    return [jax.tree_util.tree_map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_take(tree, idx, axis: int = 0):
    """Index every leaf along `axis` (gather, supports traced idx)."""
    return jax.tree_util.tree_map(lambda x: jnp.take(x, idx, axis=axis), tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_where(pred, a, b):
    """Leafwise jnp.where with a scalar/broadcastable predicate."""
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(_expand(pred, x.ndim), x, y), a, b
    )


def _expand(pred, ndim):
    p = jnp.asarray(pred)
    while p.ndim < ndim:
        p = p[..., None]
    return p


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    if len(leaves_a) != len(leaves_b):
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )


def tree_flatten_concat(tree, dtype=jnp.float32):
    """Flatten a pytree into a single 1-D vector (for kernels / checksums)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([x.reshape(-1).astype(dtype) for x in leaves])


def tree_unflatten_concat(flat, tree_like):
    """Inverse of tree_flatten_concat given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    out, off = [], 0
    for x in leaves:
        n = int(np.prod(x.shape))
        out.append(flat[off : off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)
