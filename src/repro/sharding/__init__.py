from repro.sharding.rules import (  # noqa: F401
    ShardingRules, param_specs, batch_specs, decode_state_specs,
)
