"""Ambient mesh context for model-internal shard_map regions.

Models are mesh-agnostic; launchers that want manually-partitioned
subgraphs (e.g. the MoE local dispatch) install the mesh here.
"""
from __future__ import annotations

import contextlib

_MESH = None


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def current_mesh():
    return _MESH
