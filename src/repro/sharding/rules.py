"""PartitionSpec rules for every architecture on the production mesh.

Divisibility-checked with explicit fallbacks (DESIGN.md §4):
  * attention heads shard over "model" when n_heads % axis == 0, else the
    QKV projections shard their INPUT (d_model) dim — GSPMD then reduces
    the projection instead of splitting heads (qwen2 28H, hymba 25H,
    whisper 12H);
  * KV-head projections replicate when n_kv % axis != 0 (cheap: GQA KV
    weights are small);
  * FFN always shards d_ff; MoE experts are tensor-parallel (8 experts do
    not divide a 16-way axis), experts dim replicated;
  * embeddings/lm_head shard vocab when divisible, else d_model;
  * `fsdp=True` additionally shards the largest remaining dim over "data"
    (used for ≥10B-param archs so parameters fit per-chip HBM).

Stacked layer params have a leading [L] axis -> specs get None prepended.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    model_axis: str = "model"
    data_axis: str = "data"
    model_size: int = 16
    data_size: int = 16
    fsdp: bool = False
    # pure_fsdp: no tensor parallelism — batch shards over BOTH axes and
    # every weight shards its first divisible dim over "model" (GSPMD then
    # all-gathers weights per layer instead of all-reducing activations).
    # The §Perf winner for small/medium models at large batch.
    pure_fsdp: bool = False

    def div(self, dim: int, axis_size: Optional[int] = None) -> bool:
        return dim % (axis_size or self.model_size) == 0


def _maybe_fsdp(rules: ShardingRules, spec_dims, shape):
    """Shard the first free divisible dim over "data" when fsdp is on."""
    if not rules.fsdp:
        return spec_dims
    out = list(spec_dims)
    for i, (s, dim) in enumerate(zip(out, shape)):
        if s is None and dim % rules.data_size == 0:
            out[i] = rules.data_axis
            break
    return out


def param_specs(cfg: ModelConfig, params, rules: ShardingRules):
    """Pytree of PartitionSpec matching `params` (from models.registry)."""
    m = rules.model_axis

    def spec_for(path: str, x) -> P:
        shape = x.shape
        layered = any(seg in path for seg in ("blocks",))
        dims: list = [None] * len(shape)
        core = shape[1:] if layered else shape
        off = 1 if layered else 0

        if rules.pure_fsdp:
            # storage-only sharding: first core dim divisible by BOTH axes
            # shards over ("data","model") jointly (267 GB of deepseek-67b
            # f32 params -> ~1 GB/chip); else over "model" alone
            both = rules.model_size * rules.data_size
            for i, d_ in enumerate(core):
                if d_ % both == 0:
                    dims[off + i] = (rules.data_axis, m)
                    return P(*dims)
            for i, d_ in enumerate(core):
                if d_ % rules.model_size == 0:
                    dims[off + i] = m
                    break
            return P(*dims)

        def set_core(i, axis):
            dims[off + i] = axis

        if path.endswith(("embed", "token_embed")):
            if rules.div(shape[-2]):
                dims[-2] = m
            elif rules.div(shape[-1]):
                dims[-1] = m
        elif path.endswith("lm_head"):
            if rules.div(shape[-1]):
                dims[-1] = m
            elif rules.div(shape[-2]):
                dims[-2] = m
        elif path.endswith("img_proj"):
            if rules.div(shape[-1]):
                dims[-1] = m
        elif "/wq" in path or "/wk" in path or "/wv" in path:
            heads = cfg.n_kv_heads if ("/wk" in path or "/wv" in path) \
                else cfg.n_heads
            if rules.div(heads):
                set_core(1, m)
            elif rules.div(cfg.n_heads) and rules.div(core[0]):
                # q heads shard, kv replicate: shard nothing for k/v
                if "/wq" in path:
                    set_core(1, m)
            elif rules.div(core[0]):
                set_core(0, m)  # contraction-dim shard fallback
        elif "/wo" in path:
            if rules.div(cfg.n_heads):
                set_core(0, m)
            elif rules.div(core[-1]):
                set_core(1, m)
        elif "/bq" in path:
            if rules.div(cfg.n_heads):
                set_core(0, m)
        elif "/bk" in path or "/bv" in path:
            if rules.div(cfg.n_kv_heads):
                set_core(0, m)
        elif "moe/router" in path:
            pass  # replicate
        elif "moe/w_gate" in path or "moe/w_up" in path:
            set_core(2, m)
        elif "moe/w_down" in path:
            set_core(1, m)
        elif "/w_gate" in path or "/w_up" in path:
            set_core(1, m)
        elif "/w_down" in path:
            set_core(0, m)
        elif "ssm/w_in" in path:
            if rules.div(core[0]):
                set_core(0, m)
        elif "ssm/w_out" in path:
            if rules.div(core[1]):
                set_core(1, m)
        # norms, gates, scalars: replicated
        dims = _maybe_fsdp(rules, dims, shape)
        return P(*dims)

    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    specs = {path_str(kp): spec_for(path_str(kp), x) for kp, x in flat}
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(
        treedef, [specs[path_str(kp)] for kp, x in flat])


def fleet_specs(tree, num_agents: int, axis: str = "agents"):
    """Agent-axis PartitionSpec tree for DFL fleet pytrees (the sharded
    fleet engine's 1-D ``agents`` mesh): leaves with a leading
    [num_agents] dimension shard along ``axis``; everything else — scalars
    like ``FleetState.t``, replicated mobility state — stays replicated.
    Fleet leaves are always agent-leading ([N], [N, C, ...], [N, N]), so
    the leading-dim test is exact for FleetState/data/counts trees."""

    def spec_for(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == num_agents:
            return P(axis, *([None] * (x.ndim - 1)))
        return P()

    return jax.tree_util.tree_map(spec_for, tree)


def batch_specs(cfg: ModelConfig, batch, rules: ShardingRules):
    """Batch dim over "data"; sequence/replicated otherwise."""
    d = rules.data_axis
    if rules.pure_fsdp:
        d = (rules.data_axis, rules.model_axis)
        rules = dataclasses.replace(
            rules, data_size=rules.data_size * rules.model_size)

    def spec_for(x):
        if x.shape[0] % rules.data_size == 0:
            return P(d, *([None] * (x.ndim - 1)))
        if x.ndim > 1 and x.shape[1] % rules.data_size == 0:
            return P(None, d, *([None] * (x.ndim - 2)))
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map(spec_for, batch)


def decode_state_specs(cfg: ModelConfig, state, rules: ShardingRules):
    """KV caches [L, B, S, KV, hd]: B over "data" when divisible, else S
    (long_500k B=1); KV heads over "model" when divisible, else hd."""
    m, d = rules.model_axis, rules.data_axis

    def spec_for(x):
        if x.ndim == 0:
            return P()
        if x.ndim == 5:  # [L, B, S, KV, hd] or ssm [L, B, H, P, N]
            dims = [None] * 5
            if x.shape[1] % rules.data_size == 0:
                dims[1] = d
            elif x.shape[2] % rules.data_size == 0:
                dims[2] = d
            if x.shape[3] % rules.model_size == 0:
                dims[3] = m
            elif x.shape[2] % rules.model_size == 0 and dims[2] is None:
                dims[2] = m
            return P(*dims)
        dims = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % rules.data_size == 0:
            dims[0] = d
        return P(*dims)

    return jax.tree_util.tree_map(spec_for, state)
