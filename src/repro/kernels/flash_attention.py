"""Pallas TPU kernel: flash (chunked, online-softmax) causal attention for
the prefill hot-spot — full-sequence GQA with optional sliding window.

Grid: (batch, kv_head, q_blocks, kv_blocks); the innermost kv dimension is
sequential so the running (m, l, acc) live in VMEM scratch, exactly as in
decode_attention but with a [BLOCK_Q, hd] query tile per cell. Causality
is enforced by masking; with a sliding window the mask also cuts the
lower-left corner. Tiles: q (BLOCK_Q=256) x k/v (BLOCK_K=256) x hd≤128 →
~128 KB each in bf16; scores are [G*BLOCK_Q, BLOCK_K] on the MXU.

ref.py oracle: repro.models.attention.chunked_attention (pure jnp),
itself validated against dense softmax in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, seq_len: int, window: int,
            causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)    # [BQ, G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)    # [BK, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)    # [BK, hd]
    BQ, G, hd = q.shape
    BK = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    qf = q.reshape(BQ * G, hd)
    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(BQ, G, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (BQ, G, BK), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (BQ, G, BK), 2)
    valid = k_pos < seq_len
    if causal:
        valid &= k_pos <= q_pos
    if window:
        valid &= k_pos > (q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                      # [BQ, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    p = jnp.exp(s - m_new[..., None])        # [BQ, G, BK]
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=2)
    pf = p.reshape(BQ * G, BK)
    pv = jax.lax.dot_general(pf, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv.reshape(BQ, G, hd)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0, :, 0] = out.astype(out_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True):
    """q: [B, S, KV, G, hd]; k, v: [B, S, KV, hd] -> [B, S, KV, G, hd] f32.

    Full-sequence GQA attention with online softmax over KV blocks.
    """
    B, S, KV, G, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (S + pad_q) // block_q
    nk = (S + pad_k) // block_k

    grid = (B, KV, nq, nk)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               seq_len=S, window=window, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, 1, G, hd),
                             lambda b, h, i, j: (b, i, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, i, j: (b, j, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, i, j: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, G, hd),
                                   lambda b, h, i, j: (b, i, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, G), jnp.float32),
                pltpu.VMEM((block_q, G), jnp.float32),
                pltpu.VMEM((block_q, G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S + pad_q, KV, G, hd),
                                       jnp.float32),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
