"""jit'd public wrappers around the Pallas kernels.

``interpret`` is auto-detected from the backend: compiled kernels on TPU,
interpreter everywhere else (the kernels use TPU-specific Pallas
features). Override with REPRO_PALLAS_COMPILED=1 (force compiled) or =0
(force interpreter) — see
:func:`repro.kernels.cache_aggregate.default_interpret`.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import cache_aggregate as _ca
from repro.kernels import decode_attention as _da
from repro.kernels.cache_aggregate import default_interpret as _interpret


@functools.partial(jax.jit, static_argnames=("block_d",))
def cache_aggregate(cache, weights, valid, *, block_d: int = 65536):
    """Masked weighted reduction over the cache axis: [C, D] -> [D] f32."""
    return _ca.cache_aggregate(cache, weights, valid, block_d=block_d,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_d",))
def gather_cache_aggregate(src, idx, weights, *, block_d: int = 65536):
    """Fused winner-gather + weighted reduction:
    out[d] = Σ_c weights[c] · src[idx[c], d]; src [M, D] -> [D] f32."""
    return _ca.gather_cache_aggregate(src, idx, weights, block_d=block_d,
                                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_s"))
def decode_attention(q, k, v, length, *, window: int = 0, block_s: int = 512):
    """Flash-style single-token GQA attention: [B,KV,G,hd] out (f32)."""
    return _da.decode_attention(q, k, v, length, window=window,
                                block_s=block_s, interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256):
    """Full-sequence flash GQA attention (prefill hot-spot):
    [B,S,KV,G,hd] -> f32."""
    from repro.kernels import flash_attention as _fa
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())
