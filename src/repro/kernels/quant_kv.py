"""Int8-quantized KV cache decode attention (beyond-paper §Perf lever).

decode_32k/long_500k are memory-bound: each step streams the whole KV
cache from HBM. Per-(position, head) symmetric int8 quantization halves
that traffic (2 bytes -> 1 byte + 1/hd scale overhead), cutting the
dominant roofline term ~2x at <1e-2 attention-output error.

The kernel is the flash decode kernel with an in-VMEM dequant fused before
the dot; scales ride in the same [S, KV] layout. Oracle: dequantize with
jnp then run the f32 reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def quantize_kv(x):
    """x: [B, S, KV, hd] float -> (int8 values, f32 scales [B, S, KV, 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale


def _kernel(meta_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, out_ref,
            m_ref, l_ref, acc_ref, *, block_s: int, window: int):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)
    length = meta_ref[0]

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qv = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0]  # dequant [BS, hd]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0]
    hd = qv.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    s = jax.lax.dot_general(qv, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < length
    if window:
        valid &= pos > (length - 1 - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def decode_attention_quant(q, k_q, k_scale, v_q, v_scale, length, *,
                           window: int = 0, block_s: int = 512,
                           interpret: bool = True):
    """q: [B, KV, G, hd]; k_q/v_q: int8 [B, S, KV, hd];
    k_scale/v_scale: f32 [B, S, KV, 1]. Returns [B, KV, G, hd] f32."""
    B, KV, G, hd = q.shape
    S = k_q.shape[1]
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        padkv = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_q = jnp.pad(k_q, padkv)
        v_q = jnp.pad(v_q, padkv)
        k_scale = jnp.pad(k_scale, padkv)
        v_scale = jnp.pad(v_scale, padkv)
    n_s = (S + pad) // block_s
    meta = jnp.asarray([length], jnp.int32)

    grid = (B, KV, n_s)
    kv_spec = pl.BlockSpec((1, block_s, 1, hd),
                           lambda b, h, s, meta: (b, s, h, 0))
    sc_spec = pl.BlockSpec((1, block_s, 1, 1),
                           lambda b, h, s, meta: (b, s, h, 0))
    kernel = functools.partial(_kernel, block_s=block_s, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, s, meta: (b, h, 0, 0)),
                kv_spec, sc_spec, kv_spec, sc_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, s, meta: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(meta, q, k_q, k_scale, v_q, v_scale)
    return out
