"""Pallas TPU kernel: single-token GQA decode attention with online softmax.

The decode-shape hot spot: one query token per sequence attends over a
[S, KV, hd] KV cache. Memory-bound (the whole cache is read once per
step); the kernel streams K/V through VMEM in (BLOCK_S, hd) tiles per
(batch, kv-head) grid cell with flash-style running (m, l, acc) carried in
VMEM scratch across the sequential innermost grid dimension. Supports a
sliding-window mask and a dynamic valid length (scalar prefetch).

Block sizing: BLOCK_S=512 rows × hd≤128 lanes ≈ 128 KB per K tile (bf16) —
K + V + scratch stay well under VMEM; scores are [G, BLOCK_S] with G ≤ 8
(GQA group fan-out), so the dot runs on the MXU with hd as the contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(meta_ref, q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, window: int):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)
    length = meta_ref[0]

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)          # [BS, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)          # [BS, hd]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < length
    if window:
        valid &= pos > (length - 1 - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                              # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [G, BS]
    corr = jnp.exp(m_prev - m_new)                   # [G, 1]
    l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def decode_attention(q, k, v, length, *, window: int = 0,
                     block_s: int = 512, interpret: bool = True):
    """q: [B, KV, G, hd]; k, v: [B, S, KV, hd]; length: [] int32.

    Returns [B, KV, G, hd] float32 attention output.
    """
    B, KV, G, hd = q.shape
    S = k.shape[1]
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_s = (S + pad) // block_s
    meta = jnp.asarray([length], jnp.int32)

    grid = (B, KV, n_s)
    kernel = functools.partial(_kernel, block_s=block_s, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, s, meta: (b, h, 0, 0)),
                pl.BlockSpec((1, block_s, 1, hd),
                             lambda b, h, s, meta: (b, s, h, 0)),
                pl.BlockSpec((1, block_s, 1, hd),
                             lambda b, h, s, meta: (b, s, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, s, meta: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(meta, q, k, v)
    return out
