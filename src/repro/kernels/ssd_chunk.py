"""Pallas TPU kernel: Mamba2/SSD intra-chunk block (arXiv:2405.21060).

The chunked SSD algorithm splits work into (a) dense intra-chunk terms and
(b) a cheap inter-chunk state recurrence. (a) is the MXU-heavy hot spot:

    att[i,j] = exp(Σ_{j<s≤i} log a_s) · (C_i·B_j) · dt_j   (j ≤ i)
    y[i]     = Σ_j att[i,j] · x[j]                          [T,T]·[T,P]
    S_chunk  = Σ_j exp(cum(T)-cum(j)) dt_j · x_j ⊗ B_j      [P,N] state

One grid cell = one (batch, head, chunk): x [T,P], B/C [T,N], log-decay
cumsum [T] all fit VMEM for T=chunk ≤ 256, P=64, N≤128; the segment-sum
decay matrix is built in-register from the cumsum differences. The
inter-chunk scan (sequential, tiny) stays in jnp — fusing a sequential
recurrence into the kernel would serialize the grid.

Oracle: the pure-jnp intra-chunk math in repro.models.ssm.ssd_chunked.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, cum_ref, out_ref, state_ref):
    x = x_ref[0, 0].astype(jnp.float32)      # [T, P]
    dt = dt_ref[0, 0].astype(jnp.float32)    # [T]
    B = b_ref[0, 0].astype(jnp.float32)      # [T, N]
    C = c_ref[0, 0].astype(jnp.float32)      # [T, N]
    cum = cum_ref[0, 0].astype(jnp.float32)  # [T] cumulative log-decay
    T = x.shape[0]

    # intra-chunk decay matrix: L[i,j] = exp(cum[i]-cum[j]) for j<=i else 0
    seg = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    decay = jnp.where(mask, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [T, T]
    att = decay * cb * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [T, P]
    out_ref[0, 0] = y.astype(out_ref.dtype)

    # chunk state: S = Σ_j w_j x_j ⊗ B_j with w_j = exp(cum[T-1]-cum[j])·dt_j
    w = jnp.exp(cum[T - 1] - cum) * dt                             # [T]
    xw = x * w[:, None]                                            # [T, P]
    state = jax.lax.dot_general(xw, B, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [P, N]
    state_ref[0, 0] = state


def ssd_chunk_intra(x, dt, Bm, Cm, log_a, *, interpret: bool = True):
    """Intra-chunk SSD terms for all chunks at once.

    x: [B, nc, T, H, P]; dt: [B, nc, T, H]; Bm, Cm: [B, nc, T, N];
    log_a: [B, nc, T, H] per-step log decay.
    Returns (y_intra [B, nc, T, H, P] f32, s_chunk [B, nc, H, P, N] f32).
    """
    Bsz, nc, T, H, P = x.shape
    N = Bm.shape[-1]
    cum = jnp.cumsum(log_a, axis=2)          # [B, nc, T, H]

    # layout: one grid cell per (batch, chunk, head)
    xt = x.transpose(0, 1, 3, 2, 4)          # [B, nc, H, T, P]
    dtt = dt.transpose(0, 1, 3, 2)           # [B, nc, H, T]
    cumt = cum.transpose(0, 1, 3, 2)         # [B, nc, H, T]
    bt = jnp.broadcast_to(Bm[:, :, None], (Bsz, nc, H, T, N))
    ct = jnp.broadcast_to(Cm[:, :, None], (Bsz, nc, H, T, N))

    flat = lambda a: a.reshape((Bsz * nc, H) + a.shape[3:])
    xt, dtt, cumt, bt, ct = map(flat, (xt, dtt, cumt, bt, ct))

    grid = (Bsz * nc, H)
    y, state = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, T, P), lambda b, h: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, T), lambda b, h: (b, h, 0)),
                pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, T), lambda b, h: (b, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, T, P), lambda b, h: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * nc, H, T, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, bt, ct, cumt)

    y = y.reshape(Bsz, nc, H, T, P).transpose(0, 1, 3, 2, 4)
    state = state.reshape(Bsz, nc, H, P, N)
    return y, state
