"""Pallas TPU kernel: Cached-DFL model aggregation.

The ModelAggregation step (paper Alg. 1 line 11) over a pod-resident cache
is a masked weighted reduction over C cached model vectors:

    out[d] = Σ_c (w[c] · valid[c]) · cache[c, d]

Arithmetic intensity ≈ 1 FLOP/byte — pure HBM bandwidth. The kernel
streams the flattened model through VMEM in (C, BLOCK_D) tiles; weights
ride along as scalar-prefetch (SMEM) so the VPU multiply-accumulate never
stalls on them. BLOCK_D is sized so a tile fits comfortably in VMEM
(C·BLOCK_D·itemsize ≤ ~8 MB), and is a multiple of 128 lanes.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """Backend auto-detection for the ``interpret`` flag.

    These kernels use TPU-specific Pallas features (scalar prefetch, VMEM
    block specs), so the compiled path is TPU-only; every other backend
    (CPU, GPU) runs the interpreter. ``REPRO_PALLAS_COMPILED=1`` forces
    the compiled path, ``=0`` forces the interpreter (both override the
    auto-detection, e.g. for debugging a TPU kernel in interpret mode).
    """
    env = os.environ.get("REPRO_PALLAS_COMPILED")
    if env is not None:
        return env != "1"
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def _kernel(w_ref, cache_ref, out_ref):
    # w_ref: [C] f32 in SMEM (scalar prefetch); cache_ref: [C, BD] in VMEM
    x = cache_ref[...].astype(jnp.float32)          # [C, BD]
    w = w_ref[...].astype(jnp.float32)              # [C]
    out_ref[...] = jax.lax.dot_general(
        w[None, :], x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


def cache_aggregate(cache, weights, valid, *, block_d: int = 65536,
                    interpret: Optional[bool] = None):
    """cache: [C, D]; weights, valid: [C] f32 -> out [D] f32.

    interpret=None auto-detects the backend (compiled kernel on TPU,
    interpreter elsewhere); pass an explicit bool to override.
    """
    interpret = _resolve_interpret(interpret)
    C, D = cache.shape
    block_d = min(block_d, max(128, D))
    pad = (-D) % block_d
    if pad:
        cache = jnp.pad(cache, ((0, 0), (0, pad)))
    Dp = D + pad
    w = (weights * valid).astype(jnp.float32)

    grid = (Dp // block_d,)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((C, block_d), lambda i, w: (0, i))],
            out_specs=pl.BlockSpec((block_d,), lambda i, w: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((Dp,), jnp.float32),
        interpret=interpret,
    )(w, cache)
    return out[:D]


# ---------------------------------------------------------------------------
# fused gather + aggregate
# ---------------------------------------------------------------------------

def _gather_kernel(idx_ref, w_ref, src_ref, out_ref):
    # idx_ref, w_ref: [C] in SMEM (scalar prefetch); src_ref: [1, BD] — the
    # block of source row idx_ref[c] selected by the index map.
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = src_ref[...].astype(jnp.float32)[0]         # [BD]
    out_ref[...] += w_ref[c].astype(jnp.float32) * x


def gather_cache_aggregate(src, idx, weights, *, block_d: int = 65536,
                           interpret: Optional[bool] = None):
    """Fused CacheUpdate-gather + ModelAggregation reduction.

    out[d] = Σ_c weights[c] · src[idx[c], d]

    src: [M, D] candidate model pool (cache rows + fresh models);
    idx: [C] int32 winning-row indices from the metadata phase;
    weights: [C] f32 aggregation weights (0 for invalid slots).

    Instead of materializing the gathered [C, D] winner set in HBM and
    re-reading it for the weighted reduction, the index map DMAs each
    winning row's tile straight into VMEM (row id rides along as scalar
    prefetch) and the reduction accumulates in the output tile — the cache
    makes exactly one HBM trip between CacheUpdate and ModelAggregation.
    """
    M, D = src.shape
    C = idx.shape[0]
    block_d = min(block_d, max(128, D))
    pad = (-D) % block_d
    if pad:
        src = jnp.pad(src, ((0, 0), (0, pad)))
    Dp = D + pad
    idx = jnp.clip(idx.astype(jnp.int32), 0, M - 1)
    w = weights.astype(jnp.float32)

    grid = (Dp // block_d, C)   # c innermost: out tile accumulates in VMEM
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((1, block_d),
                                   lambda i, c, idx_ref, w_ref:
                                   (idx_ref[c], i))],
            out_specs=pl.BlockSpec((block_d,),
                                   lambda i, c, idx_ref, w_ref: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((Dp,), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx, w, src)
    return out[:D]
