"""Pallas TPU kernel: Cached-DFL model aggregation.

The ModelAggregation step (paper Alg. 1 line 11) over a pod-resident cache
is a masked weighted reduction over C cached model vectors:

    out[d] = Σ_c (w[c] · valid[c]) · cache[c, d]

Arithmetic intensity ≈ 1 FLOP/byte — pure HBM bandwidth. The kernel
streams the flattened model through VMEM in (C, BLOCK_D) tiles; weights
ride along as scalar-prefetch (SMEM) so the VPU multiply-accumulate never
stalls on them. BLOCK_D is sized so a tile fits comfortably in VMEM
(C·BLOCK_D·itemsize ≤ ~8 MB), and is a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, cache_ref, out_ref):
    # w_ref: [C] f32 in SMEM (scalar prefetch); cache_ref: [C, BD] in VMEM
    x = cache_ref[...].astype(jnp.float32)          # [C, BD]
    w = w_ref[...].astype(jnp.float32)              # [C]
    out_ref[...] = jax.lax.dot_general(
        w[None, :], x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


def cache_aggregate(cache, weights, valid, *, block_d: int = 65536,
                    interpret: bool = True):
    """cache: [C, D]; weights, valid: [C] f32 -> out [D] f32.

    On CPU we always run interpret=True (the kernel body executes in
    Python); on TPU set interpret=False for the compiled path.
    """
    C, D = cache.shape
    block_d = min(block_d, max(128, D))
    pad = (-D) % block_d
    if pad:
        cache = jnp.pad(cache, ((0, 0), (0, pad)))
    Dp = D + pad
    w = (weights * valid).astype(jnp.float32)

    grid = (Dp // block_d,)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((C, block_d), lambda i, w: (0, i))],
            out_specs=pl.BlockSpec((block_d,), lambda i, w: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((Dp,), jnp.float32),
        interpret=interpret,
    )(w, cache)
    return out[:D]
