"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def cache_aggregate_ref(cache, weights, valid):
    """out[d] = Σ_c (weights[c] * valid[c]) * cache[c, d].

    cache: [C, D] (any float dtype); weights, valid: [C] float32.
    Returns float32 [D].
    """
    w = (weights * valid).astype(jnp.float32)
    return jnp.einsum("c,cd->d", w, cache.astype(jnp.float32))


def gather_cache_aggregate_ref(src, idx, weights):
    """out[d] = Σ_c weights[c] * src[idx[c], d].

    src: [M, D]; idx: [C] int32 (clamped); weights: [C] float32.
    """
    idx = jnp.clip(idx.astype(jnp.int32), 0, src.shape[0] - 1)
    gathered = src[idx].astype(jnp.float32)          # [C, D]
    return jnp.einsum("c,cd->d", weights.astype(jnp.float32), gathered)


def decode_attention_ref(q, k, v, length, *, window: int = 0):
    """Single-token GQA attention oracle.

    q: [B, KV, G, hd]; k, v: [B, S, KV, hd]; length: [] int32 valid rows.
    Returns [B, KV, G, hd] float32.
    """
    B, S, KV, hd = k.shape
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    valid = pos < length
    if window:
        valid &= pos > (length - 1 - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
