"""Deterministic synthetic datasets.

Real MNIST / FashionMNIST / CIFAR-10 cannot be downloaded in this offline
container, so the reproduction benchmarks use procedurally generated
class-prototype image datasets with matching shapes and cardinalities.
Each class has a smooth random prototype; samples add jitter, shift and
noise — linearly non-trivial but learnable by the paper's CNNs, which is
what the convergence-ordering claims need.
"""
from __future__ import annotations


import numpy as np


def _smooth_prototype(rng, hw: int, channels: int, grid: int = 7):
    """Low-frequency random pattern upsampled to hw×hw."""
    coarse = rng.normal(size=(grid, grid, channels))
    # bilinear upsample
    xs = np.linspace(0, grid - 1, hw)
    xi = np.clip(xs.astype(int), 0, grid - 2)
    xf = xs - xi
    rows = (coarse[xi] * (1 - xf)[:, None, None]
            + coarse[xi + 1] * xf[:, None, None])
    cols = (rows[:, xi] * (1 - xf)[None, :, None]
            + rows[:, xi + 1] * xf[None, :, None])
    return cols


def make_image_dataset(seed: int, *, num_classes: int = 10, n_train: int,
                       n_test: int, hw: int = 28, channels: int = 1,
                       noise: float = 0.35, shift: int = 3):
    """Returns (train_x [n,h,w,c] f32, train_y [n] i32, test_x, test_y)."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_prototype(rng, hw, channels)
                       for _ in range(num_classes)])
    protos = protos / np.abs(protos).max(axis=(1, 2, 3), keepdims=True)

    def sample(n):
        y = rng.integers(0, num_classes, size=n).astype(np.int32)
        x = protos[y].copy()
        # random shift
        sx = rng.integers(-shift, shift + 1, size=n)
        sy = rng.integers(-shift, shift + 1, size=n)
        for i in range(n):  # vectorizable; n is small enough
            x[i] = np.roll(x[i], (sx[i], sy[i]), axis=(0, 1))
        x += noise * rng.normal(size=x.shape)
        return x.astype(np.float32), y

    train_x, train_y = sample(n_train)
    test_x, test_y = sample(n_test)
    return train_x, train_y, test_x, test_y


def make_lm_dataset(seed: int, *, vocab: int, seq_len: int, n_seq: int):
    """Synthetic token sequences from a sparse random bigram chain —
    a real next-token signal for LM fine-tuning examples."""
    rng = np.random.default_rng(seed)
    fanout = 4
    table = rng.integers(0, vocab, size=(vocab, fanout)).astype(np.int32)
    toks = np.zeros((n_seq, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seq)
    for t in range(seq_len):
        toks[:, t] = state
        nxt = table[state, rng.integers(0, fanout, size=n_seq)]
        # occasional random jump for entropy
        jump = rng.random(n_seq) < 0.05
        state = np.where(jump, rng.integers(0, vocab, size=n_seq), nxt)
    return toks
