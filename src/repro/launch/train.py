"""Training launcher.

Two modes:
  fleet — the paper's vehicular Cached-DFL simulation, driven entirely by
          the declarative Scenario API (``repro.api``). The flag surface
          is generated from the config dataclasses, so EVERY
          ``ExperimentConfig`` / ``DFLConfig`` / ``MobilityConfig`` field
          is reachable — either through a generated flag
          (``--dfl-cache-size 8``, ``--mobility-levy-alpha 1.2``) or the
          dotted ``--set`` override (``--set dfl.cache_size=8``):
            python -m repro.launch.train --mode fleet --algorithm cached \
                --distribution noniid --agents 20 --epochs 30
            python -m repro.launch.train --preset paper-noniid \
                --set dfl.policy=mobility_aware --set epochs=100
            python -m repro.launch.train --scenario spec.json --out out.json
  pod   — the production path on CPU: a reduced --arch transformer trained
          with Cached-DFL rounds (local SGD + cache aggregation + agent
          exchange) on synthetic LM data:
            python -m repro.launch.train --mode pod --arch mixtral-8x7b \
                --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import typing

import jax
import jax.numpy as jnp

from repro.configs import registry as cfg_registry
from repro.configs.base import DFLConfig, MobilityConfig

# the fleet CLI's historical defaults (kept so bare invocations behave as
# before the Scenario API); every other field follows the dataclass default
_CLI_BASE_OVERRIDES = {
    "dfl.num_agents": 20, "epochs": 30, "lr_plateau": True,
}

# convenience aliases: historical flag name -> dotted override path
_FLAG_ALIASES = {
    "agents": "dfl.num_agents",
    "cache-size": "dfl.cache_size",
    "tau-max": "dfl.tau_max",
    "local-steps": "dfl.local_steps",
    "lr": "dfl.lr",
    "batch-size": "dfl.batch_size",
    "epoch-seconds": "dfl.epoch_seconds",
    "policy": "dfl.policy",
    "transfer-budget": "dfl.transfer_budget",
    "link-entries-per-step": "dfl.link_entries_per_step",
    "speed": "mobility.speed",
    "grid-w": "mobility.grid_w",
    "grid-h": "mobility.grid_h",
    "mobility-model": "mobility.model",
}


def _add_generated_flags(ap: argparse.ArgumentParser) -> dict:
    """Generate one flag per scalar config field from the dataclasses.

    Returns ``dest -> dotted path``; flags default to ``SUPPRESS`` so
    only explicitly-passed ones override the base scenario / preset.
    """
    from repro.fl.scenario import ExperimentConfig
    dest_to_path = {}
    group = ap.add_argument_group(
        "scenario fields (generated from the config dataclasses; "
        "equivalently --set PATH=VALUE)")

    def add(flag: str, path: str, ftype, help_text: str):
        dest = "ov_" + flag.replace("-", "_")
        kwargs = dict(default=argparse.SUPPRESS, dest=dest, help=help_text)
        if ftype is bool:
            kwargs["type"] = lambda v: v  # coerced by with_overrides
            kwargs["metavar"] = "BOOL"
        elif ftype in (int, float, str):
            kwargs["type"] = ftype
        else:
            kwargs["type"] = str
        group.add_argument(f"--{flag}", **kwargs)
        dest_to_path[dest] = path

    for prefix, cls in (("", ExperimentConfig), ("dfl-", DFLConfig),
                        ("mobility-", MobilityConfig)):
        hints = typing.get_type_hints(cls)
        for f in dataclasses.fields(cls):
            if f.name in ("dfl", "mobility", "policy_params"):
                continue  # nested configs / structured knobs: use --set
            path = (f"{prefix[:-1]}.{f.name}" if prefix else f.name)
            add(prefix + f.name.replace("_", "-"), path, hints[f.name],
                f"Scenario override for {path}")
    for flag, path in _FLAG_ALIASES.items():
        if "ov_" + flag.replace("-", "_") in dest_to_path:
            continue
        leaf = path.split(".")[-1]
        cls = DFLConfig if path.startswith("dfl.") else MobilityConfig
        add(flag, path, typing.get_type_hints(cls)[leaf],
            f"alias for --set {path}=VALUE")
    # Scenario-level run knobs (not ExperimentConfig fields)
    add("engine", "engine", str,
        "fleet engine: fused (default) | legacy | sharded")
    add("mesh", "mesh", int,
        "sharded engine device count (0 = all visible; on CPU force "
        "devices with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return dest_to_path


def collect_overrides(args, dest_to_path: dict) -> dict:
    """Merge generated-flag values, --policy-param and --set pairs into
    one dotted-override mapping (later --set wins)."""
    overrides = {}
    for dest, path in dest_to_path.items():
        if hasattr(args, dest):
            overrides[path] = getattr(args, dest)
    if args.policy_param:
        # string form: with_overrides' policy_params coercion parses it
        overrides["dfl.policy_params"] = ",".join(args.policy_param)
    for item in args.set or []:
        path, sep, value = item.partition("=")
        if not sep or not path:
            raise SystemExit(f"--set expects PATH=VALUE, got {item!r}")
        overrides[path.strip()] = value
    return overrides


def scenario_from_args(args, dest_to_path: dict):
    """Build the fleet Scenario: preset/file/CLI-default base + overrides."""
    from repro import api
    if args.scenario:
        with open(args.scenario) as f:
            base = api.Scenario.from_json(f.read())
    elif args.preset:
        base = api.get_preset(args.preset)
    else:
        base = api.Scenario().with_overrides(_CLI_BASE_OVERRIDES)
    base = dataclasses.replace(base, verbose=True)
    if args.telemetry or args.telemetry_out:
        base = dataclasses.replace(base, telemetry=True)
    return base.with_overrides(collect_overrides(args, dest_to_path))


def run_fleet(args, dest_to_path: dict) -> dict:
    from repro import api
    try:
        scenario = scenario_from_args(args, dest_to_path)
        scenario.resolve()       # clean CLI error, not a traceback
    except (ValueError, KeyError) as e:
        raise SystemExit(f"error: {e}") from None
    result = api.run(scenario)
    print(f"\nbest acc {result.best_acc:.4f} "
          f"final {result.final_acc:.4f} in {result.wall_s:.1f}s "
          f"[config {result.config_hash}]")
    if result.telemetry is not None:
        print(api.telemetry_line(result))
        if args.telemetry_out:
            from repro.telemetry import events as events_lib
            events_lib.write_jsonl(args.telemetry_out,
                                   result.telemetry["events"])
            print(f"telemetry events -> {args.telemetry_out}")
    return result.to_dict()


def run_pod(args, overrides: dict) -> dict:
    """Cached-DFL rounds over pod-scale agents with a reduced transformer."""
    from repro import api
    from repro.data.synthetic import make_lm_dataset
    from repro.launch import steps as steps_lib
    from repro.models import registry as models

    # validate + coerce through the Scenario override machinery, so a
    # misspelled --set path fails loudly here exactly as in fleet mode
    try:
        exp = api.Scenario().with_overrides(overrides).experiment
    except (ValueError, KeyError) as e:
        raise SystemExit(f"error: {e}") from None
    dfl = exp.dfl

    cfg = cfg_registry.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(exp.seed)
    agents = min(dfl.num_agents, 4)
    batch_size = min(dfl.batch_size, 4)
    cache_size = min(dfl.cache_size, 3)
    toks = make_lm_dataset(exp.seed, vocab=cfg.vocab, seq_len=args.seq_len,
                           n_seq=agents * batch_size * 4)
    toks = jnp.asarray(toks)

    kinit = jax.random.split(key, agents + 1)
    params = jax.vmap(lambda k: models.init_params(cfg, k))(
        kinit[:agents])
    key = kinit[agents]   # keep the loop's stream disjoint from init
    cache = steps_lib.init_pod_cache(
        cfg, models.init_params(cfg, key), cache_size, agents=agents)
    # same unlimited-sentinel normalization as the fleet path
    budget = dfl.resolved_transfer_budget
    step = jax.jit(steps_lib.make_train_step(
        cfg, lr=dfl.lr, multi_pod=True, tau_max=dfl.tau_max,
        policy=dfl.policy, scan_layers=True, transfer_budget=budget))

    def make_batch(k):
        idx = jax.random.randint(k, (agents, batch_size), 0,
                                 toks.shape[0])
        batch = {"tokens": toks[idx]}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (agents, batch_size, cfg.image_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.enc_dec:
            batch["frames"] = jnp.zeros(
                (agents, batch_size, cfg.enc_context, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return batch

    losses = []
    t0 = time.time()
    for t in range(args.steps):
        key, k1 = jax.random.split(key)
        params, cache, loss = step(params, cache, make_batch(k1),
                                   jnp.asarray(t, jnp.int32))
        losses.append(float(loss))
        print(f"round {t:3d} loss={losses[-1]:.4f} "
              f"cache_valid={int(jnp.sum(cache.valid))}")
    print(f"\n{args.steps} Cached-DFL rounds on {agents} pod-agents "
          f"({args.arch} reduced) in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return {"losses": losses}


def build_parser():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--mode", choices=["fleet", "pod"], default="fleet")
    # scenario sources (fleet mode)
    ap.add_argument("--scenario", default="",
                    help="load a Scenario JSON spec (see Scenario.to_json)")
    ap.add_argument("--preset", default="",
                    help="start from a named preset (see --list-presets)")
    ap.add_argument("--list-presets", action="store_true",
                    help="list registered scenario presets and exit")
    ap.add_argument("--set", action="append", default=[], metavar="PATH=VALUE",
                    help="dotted scenario override, repeatable (e.g. "
                         "--set dfl.cache_size=8 --set mobility.levy_alpha=1.2)")
    ap.add_argument("--policy-param", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="score knob for the chosen policy, repeatable "
                         "(e.g. --policy-param mobility_bias=8); "
                         "shorthand for --set dfl.policy_params=...")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable fleet telemetry (staleness/spread/traffic "
                         "metrics, phase spans, structured events); "
                         "bit-exact with a non-telemetry run")
    ap.add_argument("--telemetry-out", default="", metavar="PATH",
                    help="write the structured run-event stream as JSONL "
                         "(implies --telemetry)")
    dest_to_path = _add_generated_flags(ap)
    # pod args
    ap.add_argument("--arch", choices=cfg_registry.ARCH_IDS,
                    default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--out", default="")
    return ap, dest_to_path


def main() -> None:
    ap, dest_to_path = build_parser()
    args = ap.parse_args()
    if args.list_presets:
        from repro import api
        for name in api.available_presets():
            print(f"{name:>20}  {api.preset_doc(name)}")
        return
    if args.mode == "pod":
        hist = run_pod(args, collect_overrides(args, dest_to_path))
    else:
        hist = run_fleet(args, dest_to_path)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
