"""Training launcher.

Two modes:
  fleet — the paper's vehicular Cached-DFL simulation (N vehicles, Manhattan
          mobility, CNN models, synthetic MNIST-like data):
            python -m repro.launch.train --mode fleet --algorithm cached \
                --distribution noniid --agents 20 --epochs 30
  pod   — the production path on CPU: a reduced --arch transformer trained
          with Cached-DFL rounds (local SGD + cache aggregation + agent
          exchange) on synthetic LM data:
            python -m repro.launch.train --mode pod --arch mixtral-8x7b \
                --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as cfg_registry
from repro.configs.base import DFLConfig, MobilityConfig


def run_fleet(args) -> dict:
    from repro.fl.experiment import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(
        model=args.model,
        distribution=args.distribution,
        algorithm=args.algorithm,
        dfl=DFLConfig(num_agents=args.agents, cache_size=args.cache_size,
                      tau_max=args.tau_max, local_steps=args.local_steps,
                      lr=args.lr, batch_size=args.batch_size,
                      epoch_seconds=args.epoch_seconds, policy=args.policy,
                      policy_params=tuple(args.policy_param),
                      transfer_budget=args.transfer_budget,
                      link_entries_per_step=args.link_entries_per_step),
        mobility=MobilityConfig(speed=args.speed, grid_w=args.grid_w,
                                grid_h=args.grid_h),
        epochs=args.epochs,
        seed=args.seed,
        n_train=args.n_train,
        n_test=args.n_test,
        image_hw=args.image_hw,
        overlap=args.overlap,
    )
    hist = run_experiment(cfg, verbose=True)
    print(f"\nbest acc {hist['best_acc']:.4f} "
          f"final {hist['final_acc']:.4f} in {hist['wall_s']:.1f}s")
    return hist


def run_pod(args) -> dict:
    """Cached-DFL rounds over pod-scale agents with a reduced transformer."""
    from repro.data.synthetic import make_lm_dataset
    from repro.launch import steps as steps_lib
    from repro.models import registry as models

    cfg = cfg_registry.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    agents = args.agents
    toks = make_lm_dataset(args.seed, vocab=cfg.vocab, seq_len=args.seq_len,
                           n_seq=agents * args.batch_size * 4)
    toks = jnp.asarray(toks)

    params = jax.vmap(lambda k: models.init_params(cfg, k))(
        jax.random.split(key, agents))
    cache = steps_lib.init_pod_cache(
        cfg, models.init_params(cfg, key), args.cache_size, agents=agents)
    # same unlimited-sentinel normalization as the fleet path
    budget = DFLConfig(
        transfer_budget=args.transfer_budget).resolved_transfer_budget
    step = jax.jit(steps_lib.make_train_step(
        cfg, lr=args.lr, multi_pod=True, tau_max=args.tau_max,
        policy=args.policy, scan_layers=True, transfer_budget=budget))

    def make_batch(k):
        idx = jax.random.randint(k, (agents, args.batch_size), 0,
                                 toks.shape[0])
        batch = {"tokens": toks[idx]}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (agents, args.batch_size, cfg.image_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.enc_dec:
            batch["frames"] = jnp.zeros(
                (agents, args.batch_size, cfg.enc_context, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return batch

    losses = []
    t0 = time.time()
    for t in range(args.steps):
        key, k1 = jax.random.split(key)
        params, cache, loss = step(params, cache, make_batch(k1),
                                   jnp.asarray(t, jnp.int32))
        losses.append(float(loss))
        print(f"round {t:3d} loss={losses[-1]:.4f} "
              f"cache_valid={int(jnp.sum(cache.valid))}")
    print(f"\n{args.steps} Cached-DFL rounds on {agents} pod-agents "
          f"({args.arch} reduced) in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return {"losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["fleet", "pod"], default="fleet")
    # fleet args
    ap.add_argument("--model", default="paper-mnist-cnn")
    ap.add_argument("--distribution", default="noniid",
                    choices=["iid", "noniid", "dirichlet", "grouped"])
    ap.add_argument("--algorithm", default="cached",
                    choices=["cached", "dfl", "cfl"])
    from repro.policies import registry as policy_registry

    def policy_param(arg: str):
        name, sep, value = arg.partition("=")
        if not sep or not name:
            raise argparse.ArgumentTypeError(
                f"expected NAME=VALUE, got {arg!r}")
        try:
            return name, float(value)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"value for {name!r} must be a number, got {value!r}")

    ap.add_argument("--policy", default="lru",
                    choices=policy_registry.available())
    ap.add_argument("--policy-param", action="append", default=[],
                    type=policy_param, metavar="NAME=VALUE",
                    help="score knob for the chosen policy, repeatable "
                         "(e.g. --policy-param mobility_bias=8)")
    ap.add_argument("--transfer-budget", type=float, default=float("inf"),
                    help="max cache entries one contact can move per link "
                         "per epoch (inf = unlimited, 0 = metadata only; "
                         "cached algorithm / pod exchange only)")
    ap.add_argument("--link-entries-per-step", type=float, default=0.0,
                    help="entries admitted per simulation step of measured "
                         "contact duration (0 = link speed unconstrained; "
                         "fleet mode, cached algorithm only)")
    ap.add_argument("--agents", type=int, default=20)
    ap.add_argument("--cache-size", type=int, default=10)
    ap.add_argument("--tau-max", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--epoch-seconds", type=float, default=120.0)
    ap.add_argument("--speed", type=float, default=13.89)
    ap.add_argument("--grid-w", type=int, default=10)
    ap.add_argument("--grid-h", type=int, default=30)
    ap.add_argument("--n-train", type=int, default=6000)
    ap.add_argument("--n-test", type=int, default=1000)
    ap.add_argument("--image-hw", type=int, default=0)
    ap.add_argument("--overlap", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    # pod args
    ap.add_argument("--arch", choices=cfg_registry.ARCH_IDS,
                    default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.mode == "pod":
        args.batch_size = min(args.batch_size, 4)
        args.agents = min(args.agents, 4)
        args.cache_size = min(args.cache_size, 3)
        hist = run_pod(args)
    else:
        hist = run_fleet(args)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
