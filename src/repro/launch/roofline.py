"""Roofline-term derivation from compiled dry-run artifacts.

Terms per (arch × shape × mesh), TPU v5e constants:
    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips × 819e9 B/s HBM)
    collective = coll_bytes  / (chips × 50e9 B/s ICI per link)

IMPORTANT measurement detail (verified in this container): after GSPMD
partitioning, ``compiled.cost_analysis()`` and the optimized HLO text
describe the PER-DEVICE program — FLOPs, bytes and collective shapes are
already divided by the mesh. The terms below therefore use per-device
numerators over per-chip rates; the global MODEL_FLOPS comparison
multiplies back by `chips`.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (measured in
this container: a lax.scan of N matmuls reports 1/N of the unrolled
FLOPs), so scan-based lowerings undercount. The dry-run therefore compiles
1-layer and 2-layer UNROLLED variants of each config and extrapolates:
    total(L) = base(1) + (L-1) · [cost(2) - cost(1)]
which is exact for homogeneous layer stacks. Collective bytes are parsed
from the optimized HLO (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes) and extrapolated the same
way. MODEL_FLOPS uses 6·N_active·tokens (train) / 2·N_active·tokens
(prefill/decode), the standard MFU numerator.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    """Sum bytes over every typed array literal in an HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective output bytes summed over the optimized HLO module.

    Accounting: each op contributes its OUTPUT tensor size (all-reduce
    twice: ring reduce+broadcast moves ~2× the payload).
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # match "= TYPE coll(" — HLO result line for this collective
            m = re.search(r"=\s*(.+?)\s+%?" + coll + r"(-start|-done)?\(",
                          stripped)
            if m:
                if coll + "-done(" in stripped:
                    continue  # counted at -start
                nbytes = _tensor_bytes(m.group(1))
                if coll == "all-reduce":
                    nbytes *= 2
                out[coll] += nbytes
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float        # PER-DEVICE (post-SPMD module)
    hlo_bytes: float        # PER-DEVICE
    coll_bytes: float       # PER-DEVICE
    coll_breakdown: Dict[str, float]
    model_flops: float      # GLOBAL (6·N·D style)
    bytes_per_device: float

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Standard MFU numerator: 6·N_active·tokens (train) /
    2·N_active·tokens (prefill) / 2·N_active·batch (one decode step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def extrapolate(base_lo: Dict[str, float], base_hi: Dict[str, float],
                n_layers: int, lo: int = 2, hi: int = 3) -> Dict[str, float]:
    """total(L) = cost(lo) + (L-lo)·(cost(hi) - cost(lo)) per metric.

    We extrapolate from (2, 3) layers rather than (1, 2): single-layer
    programs can be partitioned degenerately by GSPMD (observed on the MoE
    archs: the 1L module replicated the expert einsums, inflating FLOPs
    ~6×), while 2→3 deltas are stable per-layer costs.
    """
    out = {}
    for k in base_lo:
        per_layer = (base_hi[k] - base_lo[k]) / (hi - lo)
        out[k] = max(base_lo[k] + (n_layers - lo) * per_layer, 0.0)
    return out


def summarize_memory(mem_analysis) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = getattr(mem_analysis, k, None)
    try:
        out["total_bytes_per_device"] = (
            (out.get("argument_size_in_bytes") or 0)
            + (out.get("output_size_in_bytes") or 0)
            + (out.get("temp_size_in_bytes") or 0))
    except TypeError:
        out["total_bytes_per_device"] = None
    return out


def format_row(t: RooflineTerms) -> str:
    return (f"{t.arch:<20} {t.shape:<12} {t.mesh:<7} "
            f"comp={t.compute_s*1e3:9.3f}ms mem={t.memory_s*1e3:9.3f}ms "
            f"coll={t.collective_s*1e3:9.3f}ms -> {t.bottleneck:<10} "
            f"useful={t.useful_flops_ratio:6.1%} "
            f"dev_bytes={t.bytes_per_device/2**30:7.2f}GiB")
