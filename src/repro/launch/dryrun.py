import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (test hook — must still run before jax initializes its backends)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers and compiles on the production mesh, and extract the
roofline terms from the compiled artifacts.

Per combination this produces:
  1. the full scan-based step compiled on the target mesh
     (memory_analysis proves residency; the collective schedule is real);
  2. 1-layer / 2-layer UNROLLED compiles whose cost_analysis diff gives
     exact per-layer FLOPs/bytes/collective-bytes, extrapolated to L
     (cost_analysis counts while-loop bodies once — see roofline.py).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry as cfg_registry
from repro.configs.base import ModelConfig, get_shape, INPUT_SHAPES
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (RooflineTerms, collective_bytes,
                                   extrapolate, format_row, model_flops,
                                   summarize_memory)
from repro.sharding.rules import (ShardingRules, batch_specs,
                                  decode_state_specs, param_specs)

FSDP_THRESHOLD = 10e9  # params; above this, shard params over "data" too


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _prepend(spec_tree, axis):
    return jax.tree_util.tree_map(
        lambda s: P(axis, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def make_rules(cfg: ModelConfig, mesh) -> ShardingRules:
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardingRules(
        model_size=ax["model"], data_size=ax["data"],
        fsdp=cfg.param_count() > FSDP_THRESHOLD)


def _cache_specs(pspecs, multi_pod: bool):
    mspecs = jax.tree_util.tree_map(
        lambda s: P(None, *s), pspecs, is_leaf=lambda x: isinstance(x, P))
    meta = P(None) if not multi_pod else P("pod", None)
    if multi_pod:
        mspecs = _prepend(mspecs, "pod")
    from repro.core.cache import ModelCache
    return ModelCache(models=mspecs, ts=meta, origin=meta, samples=meta,
                      group=meta, arrival=meta)


def build_lowering(cfg: ModelConfig, shape_name: str, mesh, *,
                   scan_layers: bool = True, cache_size: int = 3,
                   kv_chunk: int = 512, rules: ShardingRules = None,
                   microbatches: int = 1):
    """Returns (lowered, meta dict). Lowers the step matching shape.kind."""
    shape = get_shape(shape_name)
    rules = rules or make_rules(cfg, mesh)
    multi_pod = "pod" in mesh.axis_names
    pshapes = specs_lib.param_shapes(cfg)
    pspecs = param_specs(cfg, pshapes, rules)

    if shape.kind == "train":
        agents = mesh.devices.shape[0] if multi_pod else 0
        batch = specs_lib.train_batch_specs(cfg, shape, agents=agents)
        if multi_pod:
            per_agent = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), batch)
            bspecs = _prepend(batch_specs(cfg, per_agent, rules), "pod")
            pshapes = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct((agents,) + x.shape, x.dtype),
                pshapes)
            pspecs = _prepend(pspecs, "pod")
        else:
            bspecs = batch_specs(cfg, batch, rules)
        cache_shapes = jax.eval_shape(
            lambda: steps_lib.init_pod_cache(
                cfg, specs_lib.param_shapes(cfg), cache_size,
                agents=agents))
        cspecs = _cache_specs(param_specs(cfg, specs_lib.param_shapes(cfg),
                                          rules), multi_pod)
        step = steps_lib.make_train_step(
            cfg, scan_layers=scan_layers, multi_pod=multi_pod,
            microbatches=microbatches, kv_chunk=kv_chunk)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                          _named(mesh, bspecs), None),
            out_shardings=(_named(mesh, pspecs), _named(mesh, cspecs), None))
        from repro.sharding.context import use_mesh as _use_ctx
        with mesh, _use_ctx(mesh):
            lowered = jitted.lower(pshapes, cache_shapes, batch,
                                   jnp.zeros((), jnp.int32))
        return lowered, {"kind": "train"}

    if shape.kind == "prefill":
        batch = specs_lib.prefill_batch_specs(cfg, shape)
        rules2 = dataclasses.replace(
            rules, data_size=rules.data_size * (mesh.devices.shape[0]
                                                if multi_pod else 1))
        bspecs = batch_specs(cfg, batch, rules2)
        if multi_pod:
            bspecs = _split_leading(bspecs)
        step = steps_lib.make_prefill_step(
            cfg, max_len=shape.seq_len if not cfg.enc_dec else 512,
            scan_layers=scan_layers, kv_chunk=kv_chunk)
        jitted = jax.jit(step, in_shardings=(_named(mesh, pspecs),
                                             _named(mesh, bspecs)))
        from repro.sharding.context import use_mesh as _use_ctx
        with mesh, _use_ctx(mesh):
            lowered = jitted.lower(pshapes, batch)
        return lowered, {"kind": "prefill"}

    # decode
    batch = specs_lib.decode_token_specs(cfg, shape)
    state = specs_lib.decode_state_shapes(cfg, shape)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    data_size = rules.data_size * (mesh.devices.shape[0] if multi_pod else 1)
    rules2 = dataclasses.replace(rules, data_axis=data_axes, data_size=data_size)
    sspecs = decode_state_specs(cfg, state, rules2)
    bspecs = batch_specs(cfg, batch, rules2)
    step = steps_lib.make_decode_step(cfg, scan_layers=scan_layers)
    jitted = jax.jit(
        step,
        in_shardings=(_named(mesh, pspecs), _named(mesh, sspecs),
                      _named(mesh, bspecs)),
        out_shardings=(None, _named(mesh, sspecs)),
        donate_argnums=(1,))
    lowered = jitted.lower(pshapes, state, batch)
    return lowered, {"kind": "decode"}


def _split_leading(bspecs):
    """Shard the leading batch dim over ("pod","data") jointly."""
    return jax.tree_util.tree_map(
        lambda s: P(("pod", "data"), *list(s)[1:]) if len(s) and s[0] is not None
        else s,
        bspecs, is_leaf=lambda x: isinstance(x, P))


def _cost_metrics(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    coll = collective_bytes(text)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(coll.values())),
        **{f"coll_{k}": float(v) for k, v in coll.items()},
    }


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            cache_size: int = 3, layers_override: int = 0,
            extrapolate_layers: bool = True, out_dir: str = "",
            verbose: bool = True, force_window: int = 0) -> dict:
    cfg = cfg_registry.get_config(arch)
    if layers_override:
        cfg = dataclasses.replace(
            cfg, n_layers=layers_override,
            enc_layers=layers_override if cfg.enc_dec else 0)
    if force_window:
        cfg = dataclasses.replace(cfg, sliding_window=force_window)
    shape = get_shape(shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}

    if not cfg_registry.supports_shape(cfg, shape_name):
        result["status"] = "skip"
        result["reason"] = cfg_registry.skip_reason(cfg, shape_name)
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {result['reason']}")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch}_{shape_name}_{mesh_kind}.json"),
                    "w") as f:
                json.dump(result, f, indent=1)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        # 1) full scan-based compile: lowering + memory + schedule proof
        lowered, meta = build_lowering(cfg, shape_name, mesh,
                                       scan_layers=True,
                                       cache_size=cache_size)
        compiled = lowered.compile()
        mem = summarize_memory(compiled.memory_analysis())
        full_metrics = _cost_metrics(compiled)
        result.update(status="ok", compile_s=round(time.time() - t0, 1),
                      memory=mem, scan_cost=full_metrics)
        if verbose:
            print(f"[ok] {arch} × {shape_name} × {mesh_kind}: compiled in "
                  f"{result['compile_s']}s; "
                  f"dev bytes={mem['total_bytes_per_device']/2**30:.2f}GiB")

        # 2) per-layer extrapolation with unrolled 2-/3-layer variants
        # (1L programs can partition degenerately — see roofline.extrapolate)
        if extrapolate_layers:
            full_rules = make_rules(cfg, mesh)  # fsdp from the FULL size
            bases = {}
            for L in (2, 3):
                cfg_l = dataclasses.replace(
                    cfg, n_layers=L, enc_layers=L if cfg.enc_dec else 0)
                low_l, _ = build_lowering(cfg_l, shape_name, mesh,
                                          scan_layers=False,
                                          cache_size=cache_size,
                                          rules=full_rules)
                bases[L] = _cost_metrics(low_l.compile())
            total = extrapolate(bases[2], bases[3], cfg.n_layers)
            result["layer_extrapolation"] = {
                "base_2l": bases[2], "base_3l": bases[3], "total": total}
            terms = RooflineTerms(
                arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
                hlo_flops=total["flops"], hlo_bytes=total["bytes"],
                coll_bytes=total["coll_bytes"],
                coll_breakdown={k[5:]: v for k, v in total.items()
                                if k.startswith("coll_")},
                model_flops=model_flops(cfg, shape),
                bytes_per_device=mem["total_bytes_per_device"] or 0)
            result["roofline"] = terms.to_dict()
            if verbose:
                print("      " + format_row(terms))
    except Exception as e:  # noqa: BLE001 — dry-run reports failures
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch} × {shape_name} × {mesh_kind}: {e}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=cfg_registry.ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in INPUT_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) pair")
    ap.add_argument("--cache-size", type=int, default=3)
    ap.add_argument("--layers", type=int, default=0,
                    help="override n_layers (debug)")
    ap.add_argument("--force-window", type=int, default=0,
                    help="opt-in SWA variant: overrides sliding_window, "
                         "unlocking long_500k for dense archs")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    pairs = ([(a, s.name) for a in cfg_registry.ARCH_IDS
              for s in INPUT_SHAPES] if args.all
             else [(args.arch, args.shape)])
    results = []
    for arch, shape in pairs:
        for mk in meshes:
            results.append(run_one(
                arch, shape, mk, cache_size=args.cache_size,
                layers_override=args.layers,
                extrapolate_layers=not args.no_extrapolate,
                out_dir=args.out, force_window=args.force_window))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {ok} ok, {skip} skip, {err} error "
          f"of {len(results)}")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
