"""LLM-serving smoke demo: batched prefill + autoregressive decode for
any --arch (reduced smoke variant on CPU; full config on a real mesh).

This exercises the *model-serving* path (prefill/decode over the model
registry) and is unrelated to the fleet scenario service — to stream
federated-learning Scenario specs through a run queue, use
``python -m repro.launch.fleet_serve`` (``repro.serve.service``).

    python -m repro.launch.serve --arch mixtral-8x7b --batch 4 \
        --prompt-len 64 --decode-tokens 32 --use-kernel
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as cfg_registry
from repro.models import registry as models


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=cfg_registry.ARCH_IDS,
                    default="internlm2-1.8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (mesh required)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route decode attention through the Pallas kernel")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (cfg_registry.get_config(args.arch) if args.full
           else cfg_registry.get_smoke_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = models.init_params(cfg, key)

    B, S = args.batch, args.prompt_len
    max_len = S + args.decode_tokens
    # distinct streams: `key` already seeded the params above
    k_tok, k_img, k_frames = jax.random.split(
        jax.random.fold_in(key, 1), 3)
    batch = {"tokens": jax.random.randint(k_tok, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k_img, (B, cfg.image_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.enc_dec:
        batch = {"frames": jax.random.normal(
            k_frames, (B, cfg.enc_context, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))}

    t0 = time.time()
    prefill = jax.jit(lambda p, b: models.prefill(p, cfg, b,
                                                  max_len=max_len))
    out = prefill(params, batch)
    logits, state = (None, out) if cfg.enc_dec else out
    jax.block_until_ready(state)
    t_prefill = time.time() - t0
    print(f"prefill[{B}x{S}] in {t_prefill:.2f}s (incl. compile)")

    decode = jax.jit(lambda p, s, t: models.decode_step(
        p, cfg, s, t, use_kernel=args.use_kernel))
    if logits is not None:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(args.decode_tokens):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    total = B * args.decode_tokens
    print(f"decoded {args.decode_tokens} steps x {B} seqs in {dt:.2f}s "
          f"-> {total / dt:.1f} tok/s "
          f"(kernel={'pallas' if args.use_kernel else 'jnp'})")
    print("sample tokens:", np.asarray(jnp.concatenate(toks, 1))[0][:16])


if __name__ == "__main__":
    main()
