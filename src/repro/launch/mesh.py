"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
process force-creates 512 host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) over ("data", "model").
    Multi-pod: 2 pods = 512 chips (2, 16, 16) over ("pod", "data", "model");
    the "pod" axis carries the DFL agent dimension (one mobile mega-agent
    per pod) and its collectives ride the inter-pod DCN links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices: int = 8, *, multi_pod: bool = False):
    """Scaled-down mesh with the same axis structure for CI-sized tests."""
    if multi_pod:
        assert devices % 2 == 0
        return jax.make_mesh((2, devices // 4, 2), ("pod", "data", "model"))
    return jax.make_mesh((devices // 2, 2), ("data", "model"))


def make_fleet_mesh(num_devices=None):
    """1-D mesh over the ``agents`` axis for the sharded fleet engine.

    Uses the first ``num_devices`` visible devices (None/0 = all), so on a
    CPU container ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    makes meshes of 1/2/4/8 forced host devices sweepable in one process.
    """
    import numpy as np

    devs = jax.devices()
    n = num_devices or len(devs)
    if n > len(devs):
        raise ValueError(
            f"mesh={n} devices requested but only {len(devs)} visible "
            "(on CPU, force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("agents",))
