"""Pod-scale step functions lowered by the dry-run and launchers.

train (train_4k)      — one Cached-DFL round step for pod-scale agents:
                        local SGD step(s) + cache aggregation; in multi-pod
                        mode additionally the DTN-style model exchange
                        across the "pod" axis (collective-permute) and the
                        LRU cache insert.
prefill (prefill_32k) — full-prompt forward producing the decode state.
decode (decode_32k, long_500k) — one token against the KV/SSM state.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.core.aggregate import aggregate as aggregate_models
from repro.models import registry as models


# ---------------------------------------------------------------------------
# training / DFL round
# ---------------------------------------------------------------------------

def local_sgd_step(params, batch, cfg: ModelConfig, *, lr: float,
                   scan_layers: bool = True, remat: bool = False,
                   microbatches: int = 1, kv_chunk: int = 512):
    """One SGD step on the local loss (K steps scale this linearly).

    microbatches > 1 splits the batch and accumulates gradients in a
    lax.scan — the standard activation-memory lever (§Perf)."""
    if microbatches == 1:
        loss, grads = jax.value_and_grad(models.loss_fn)(
            params, cfg, batch, scan_layers=scan_layers, remat=remat,
            kv_chunk=kv_chunk)
    else:
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def acc_fn(carry, b):
            loss_i, g_i = jax.value_and_grad(models.loss_fn)(
                params, cfg, b, scan_layers=scan_layers, remat=remat,
                kv_chunk=kv_chunk)
            loss, grads = carry
            grads = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), grads, g_i)
            return (loss + loss_i, grads), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            acc_fn, (jnp.zeros(()), zeros), mb)
        loss = loss / microbatches
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_params, loss


def make_train_step(cfg: ModelConfig, *, lr: float = 0.1,
                    scan_layers: bool = True, remat: bool = True,
                    multi_pod: bool = False, tau_max: int = 10,
                    policy: str = "lru",
                    own_samples: float = 1.0, microbatches: int = 1,
                    kv_chunk: int = 512,
                    transfer_budget: Optional[float] = None):
    """Build the Cached-DFL round step lowered for the train shape.

    Single-pod signature:  (params, cache, batch, t) -> (params, cache, loss)
    Multi-pod: identical but every input has a leading agent axis [A] and
    the step performs the cross-pod model exchange under the configured
    cache ``policy`` (same registry as the fleet path, including the
    policy's aggregation staleness decay). ``transfer_budget`` mirrors the
    fleet path's per-link entry cap: each round's exchange moves one model
    per link, so a budget below 1 suppresses the insert (the cache still
    ages/evicts) — the pod analogue of a contact too short to transfer.
    """
    from repro.policies import base as policy_base
    from repro.policies import registry as policy_registry
    pol = policy_registry.resolve(policy)
    decay = policy_base.effective_staleness_decay(pol)

    def single(params, cache: cache_lib.ModelCache, batch, t):
        tilde, loss = local_sgd_step(params, batch, cfg, lr=lr,
                                     scan_layers=scan_layers, remat=remat,
                                     microbatches=microbatches,
                                     kv_chunk=kv_chunk)
        new_params = aggregate_models(tilde, own_samples, cache, t=t,
                                      staleness_decay=decay)
        return tilde, new_params, loss

    if not multi_pod:
        def step(params, cache, batch, t):
            _, new_params, loss = single(params, cache, batch, t)
            return new_params, cache, loss
        return step

    def step(params, cache, batch, t):
        A = jax.tree_util.tree_leaves(params)[0].shape[0]
        tilde, _, loss = jax.vmap(single, in_axes=(0, 0, 0, None))(
            params, cache, batch, t)
        # DTN model hand-off between pods: neighbor exchange over "pod"
        partner = jax.tree_util.tree_map(
            lambda x: jnp.roll(x, 1, axis=0), tilde)
        partner_ids = jnp.roll(jnp.arange(A, dtype=jnp.int32), 1)
        insert = functools.partial(cache_lib.insert, tau_max=tau_max,
                                   policy=pol,
                                   transfer_budget=transfer_budget)
        cache = jax.vmap(insert)(
            cache, partner,
            jnp.full((A,), t, jnp.int32), partner_ids,
            jnp.full((A,), own_samples, jnp.float32),
            jnp.zeros((A,), jnp.int32))
        new_params = jax.vmap(
            lambda p, c: aggregate_models(p, own_samples, c, t=t,
                                          staleness_decay=decay))(
            tilde, cache)
        return new_params, cache, jnp.mean(loss)

    return step


def init_pod_cache(cfg: ModelConfig, params, cache_size: int,
                   agents: int = 0):
    """Device-resident cache for pod-scale agents (leaves [C, ...] or
    [A, C, ...])."""
    cache = cache_lib.init_cache(params, cache_size)
    if agents:
        cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (agents,) + x.shape), cache)
    return cache


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, max_len: Optional[int] = None,
                      scan_layers: bool = True, kv_chunk: int = 512):
    def step(params, batch):
        logits, state = models.prefill(params, cfg, batch, max_len=max_len,
                                       scan_layers=scan_layers,
                                       kv_chunk=kv_chunk)
        if logits is None:  # enc-dec: no token logits at prefill
            return state
        # serving returns only the last position's logits
        return logits[:, -1], state
    return step


def make_decode_step(cfg: ModelConfig, *, use_kernel: bool = False,
                     scan_layers: bool = True):
    def step(params, state, tokens):
        if isinstance(tokens, dict):
            tokens = tokens["tokens"]
        logits, new_state = models.decode_step(
            params, cfg, state, tokens, use_kernel=use_kernel,
            scan_layers=scan_layers)
        return logits, new_state
    return step
