"""Fleet scenario service CLI: stream Scenario specs through the run
queue (``repro.serve.service``), batching compatible specs onto shared
compiled engines and emitting results as JSON Lines.

Input is JSONL, one spec per line (``--specs FILE``, or ``-`` for
stdin). Each line is either a full ``Scenario.to_dict()`` payload or a
wrapper ``{"rid": ..., "preset": NAME | "scenario": {...},
"overrides": {dotted: value}}``. Results stream to stdout (or
``--out``) as they complete — one ``kind=result`` line per spec plus a
terminal ``kind=summary`` line (schema ``repro-fleet-serve-v1``); a
malformed spec yields a structured ``status=error`` line and the queue
keeps draining. ``--events-out`` additionally writes the service's
``repro-telemetry-v1`` event stream (``run_queued`` / ``run_batched`` /
``run_failed``).

    echo '{"preset": "churn-city", "overrides": {"epochs": 4}}' | \
        python -m repro.launch.fleet_serve --specs -

Not to be confused with ``repro.launch.serve``, the LLM prefill/decode
smoke demo.
"""
from __future__ import annotations

import argparse
import sys

from repro.serve import service as service_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.fleet_serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--specs", default="-",
                    help="JSONL spec file, '-' = stdin (default)")
    ap.add_argument("--out", default="-",
                    help="JSONL result stream, '-' = stdout (default)")
    ap.add_argument("--events-out", default=None, metavar="FILE",
                    help="also write the service event stream as JSONL")
    ap.add_argument("--max-wave", type=int, default=8,
                    help="max same-engine runs per wave (default 8)")
    ap.add_argument("--retries", type=int, default=1,
                    help="re-attempts per failing run (default 1)")
    ap.add_argument("--traced-budget", action="store_true",
                    help="thread transfer budgets as traced scalars so "
                         "budget-only spec variations share one engine")
    args = ap.parse_args(argv)

    out = sys.stdout if args.out == "-" else open(args.out, "w")
    try:
        svc = service_lib.ScenarioService(
            out=out, max_wave=args.max_wave, retries=args.retries,
            force_traced_budget=args.traced_budget)
        if args.specs == "-":
            svc.submit_lines(sys.stdin)
        else:
            with open(args.specs) as f:
                svc.submit_lines(f)
        summary = svc.drain()
        if args.events_out:
            svc.events.write_jsonl(args.events_out)
    finally:
        if out is not sys.stdout:
            out.close()
    return 0 if summary["runs_failed"] == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
