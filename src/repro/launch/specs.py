"""ShapeDtypeStruct stand-ins for every (architecture × input shape) —
weak-type-correct, shardable, no device allocation (the dry-run pattern).

Decode shapes lower `serve_step` (ONE token against a seq_len KV cache);
modality frontends are stubs: VLM gets patch embeddings, audio gets frame
embeddings (assignment carve-out).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import registry as models

WHISPER_DEC_LEN = 448  # whisper's decoder context (arXiv:2212.04356)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: models.init_params(cfg, k), jax.random.PRNGKey(0))


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      agents: int = 0) -> Dict[str, Any]:
    """Training inputs. agents > 0 prepends the multi-pod agent axis and
    splits the global batch across agents."""
    B = shape.global_batch // max(agents, 1)
    lead: Tuple[int, ...] = (agents,) if agents else ()
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        s_text = shape.seq_len - cfg.image_tokens
        return {
            "tokens": _sds(lead + (B, s_text), jnp.int32),
            "image_embeds": _sds(lead + (B, cfg.image_tokens, cfg.d_model),
                                 cdt),
        }
    if cfg.enc_dec:
        return {
            "frames": _sds(lead + (B, shape.seq_len, cfg.d_model), cdt),
            "tokens": _sds(lead + (B, WHISPER_DEC_LEN), jnp.int32),
        }
    return {"tokens": _sds(lead + (B, shape.seq_len), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        s_text = shape.seq_len - cfg.image_tokens
        return {
            "tokens": _sds((B, s_text), jnp.int32),
            "image_embeds": _sds((B, cfg.image_tokens, cfg.d_model), cdt),
        }
    if cfg.enc_dec:
        return {"frames": _sds((B, shape.seq_len, cfg.d_model), cdt)}
    return {"tokens": _sds((B, shape.seq_len), jnp.int32)}


def decode_state_shapes(cfg: ModelConfig, shape: InputShape):
    """Shape stand-ins for the decode state at seq_len cache capacity."""
    B = shape.global_batch
    if cfg.enc_dec:
        from repro.models import encdec
        hd = cfg.resolved_head_dim
        kv_shape = (cfg.n_layers, B, shape.seq_len, cfg.n_kv_heads, hd)
        cross = (cfg.n_layers, B, cfg.enc_context, cfg.n_kv_heads, hd)
        cdt = jnp.dtype(cfg.compute_dtype)
        return encdec.EncDecState(
            k=_sds(kv_shape, cdt), v=_sds(kv_shape, cdt),
            cross_k=_sds(cross, cdt), cross_v=_sds(cross, cdt),
            length=_sds((), jnp.int32))
    from repro.models import transformer
    return jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, B, shape.seq_len))


def decode_token_specs(cfg: ModelConfig, shape: InputShape):
    return {"tokens": _sds((shape.global_batch, 1), jnp.int32)}
