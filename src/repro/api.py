"""The public experiment API: declarative scenarios, typed results, and a
compile-aware sweep runner.

Everything a downstream consumer (CLI, benchmarks, examples, tools,
notebooks) needs rides behind this facade:

    from repro import api

    # spec -> resolve -> run
    scenario = api.get_preset("paper-noniid").with_overrides(
        {"dfl.policy": "mobility_aware", "epochs": 100})
    result = api.run(scenario)          # typed RunResult
    print(result.best_acc, result.config_hash)

    # serializable round trip
    spec = scenario.to_json()
    assert api.Scenario.from_json(spec) == scenario

    # compile-aware grid: traced knobs (lr / transfer_budget / epochs)
    # share one fused engine per (algorithm, shape) — no retraces
    sw = api.sweep(scenario, {"dfl.transfer_budget": [0.0, 2.0],
                              "dfl.lr": [0.1, 0.05]})
    sw.write_bench("BENCH_budget.json", name="budget")
"""
from repro.configs.base import DFLConfig, MobilityConfig  # noqa: F401
from repro.fl.presets import (  # noqa: F401
    available_presets, get_preset, preset_doc, register_preset)
from repro.fl.runner import (  # noqa: F401
    TRACED_AXES, RunResult, SweepCell, SweepResult, engine_cache_key, run,
    sweep, telemetry_line)
from repro.fl.scenario import (  # noqa: F401
    Fleet, ExperimentConfig, ResolvedScenario, Scenario,
    valid_override_paths)
from repro.serve.service import (  # noqa: F401
    SERVICE_SCHEMA, ScenarioService, validate_service_jsonl)
from repro.telemetry import (  # noqa: F401
    FleetMetrics, SCHEMA_VERSION as TELEMETRY_SCHEMA, validate_events,
    validate_jsonl)

__all__ = [
    "DFLConfig", "MobilityConfig", "ExperimentConfig",
    "Scenario", "ResolvedScenario", "Fleet",
    "RunResult", "SweepCell", "SweepResult", "run", "sweep", "TRACED_AXES",
    "engine_cache_key",
    "available_presets", "get_preset", "preset_doc", "register_preset",
    "valid_override_paths",
    "ScenarioService", "SERVICE_SCHEMA", "validate_service_jsonl",
    "telemetry_line", "FleetMetrics", "TELEMETRY_SCHEMA",
    "validate_events", "validate_jsonl",
]
