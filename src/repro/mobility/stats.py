"""Encounter statistics — the mobility quantities that govern Cached-DFL.

The paper's convergence bound is driven by how often agents meet (meeting
rate), how long they stay apart (inter-contact time) and how long a
contact lasts (contact duration / transfer budget). This module computes
all of them on-device from a per-step contact sequence ``[T, N, N]`` with
fixed shapes, so the whole pipeline jits.

Conventions: ``seq[t, i, j]`` is True when i and j are in contact during
step ``t``. An *encounter* is a rising edge (contact after no contact);
an *inter-contact gap* is the time between a falling edge and the pair's
next rising edge (leading/trailing censored gaps are excluded).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig
from repro.mobility.base import MobilityModel


def collect_contacts(model: MobilityModel, state, key,
                     cfg: MobilityConfig, n_steps: int):
    """Roll a model for ``n_steps`` recording per-step contact matrices.

    Returns ``(state, seq)`` with ``seq`` of shape [n_steps, N, N] bool.
    """
    keys = jax.random.split(key, n_steps)

    def body(st, k):
        st = model.step(st, k, cfg)
        return st, model.contacts_now(st, cfg)

    return jax.lax.scan(body, state, keys)


def encounter_stats(seq: jax.Array, step_seconds: float = 1.0
                    ) -> Dict[str, jax.Array]:
    """Summary statistics of a contact sequence [T, N, N] bool.

    ``mean_contact_duration`` averages over *completed* contacts only
    (those that ended with a falling edge inside the window). Contacts
    still in progress at the final frame are right-censored — their true
    length is unknown — so they are excluded from the mean and reported
    separately instead of skewing it (the old behaviour put their steps
    in the numerator without a matching completed encounter in the
    denominator). This matters now that measured durations drive the
    transfer budget.

    Returns (all device arrays):
      meeting_rate           — encounters per agent per second
      contact_fraction       — mean fraction of time a pair is in contact
      mean_contact_duration  — seconds, averaged over completed contacts
      completed_contacts     — # contacts that ended inside the window
      censored_contacts      — # contacts still in progress at frame T-1
      censored_contact_steps — total steps belonging to censored contacts
      mean_inter_contact     — seconds, averaged over interior gaps
      encounter_counts       — [N, N] per-pair encounter counts
      inter_contact_hist     — [T+1] gap-length histogram (steps)
      inter_contact_cdf      — [T+1] empirical CDF over gap lengths
    """
    seq = seq.astype(bool)
    T, N = seq.shape[0], seq.shape[1]
    off = ~jnp.eye(N, dtype=bool)
    seq = seq & off[None]
    prev = jnp.concatenate([jnp.zeros((1, N, N), bool), seq[:-1]], axis=0)
    starts = seq & ~prev                 # rising edges
    ends = prev & ~seq                   # falling edges (first False frame)
    encounter_counts = starts.sum(0)     # [N, N]
    total_enc = encounter_counts.sum()   # counts each pair twice = per-agent
    contact_steps = seq.sum(0)

    meeting_rate = total_enc / (N * T * step_seconds)
    contact_fraction = contact_steps.sum() / (T * jnp.maximum(off.sum(), 1))

    # one scan over time carrying, per pair: the last falling edge (for
    # inter-contact gaps) and the current contact run length (for
    # censoring-aware durations — a run is credited only when it ends)
    def body(carry, x):
        last_end, hist, run, dur_sum, n_done = carry
        seq_t, s_t, e_t, t = x
        valid = s_t & (last_end >= 0)
        gap = jnp.clip(t - last_end, 0, T)
        hist = hist.at[gap].add(valid.astype(jnp.int32))
        last_end = jnp.where(e_t, t, last_end)
        dur_sum = dur_sum + jnp.sum(jnp.where(e_t, run, 0))
        n_done = n_done + jnp.sum(e_t.astype(jnp.int32))
        run = jnp.where(seq_t, run + 1, 0)
        return (last_end, hist, run, dur_sum, n_done), None

    last0 = jnp.full((N, N), -1, jnp.int32)
    hist0 = jnp.zeros((T + 1,), jnp.int32)
    run0 = jnp.zeros((N, N), jnp.int32)
    (_, hist, run, dur_sum, n_done), _ = jax.lax.scan(
        body, (last0, hist0, run0, jnp.int32(0), jnp.int32(0)),
        (seq, starts, ends, jnp.arange(T, dtype=jnp.int32)))
    mean_contact_duration = (dur_sum * step_seconds
                             / jnp.maximum(n_done, 1))
    n_gaps = hist.sum()
    mean_inter_contact = (jnp.sum(hist * jnp.arange(T + 1)) * step_seconds
                          / jnp.maximum(n_gaps, 1))
    cdf = jnp.cumsum(hist) / jnp.maximum(n_gaps, 1)
    return {
        "meeting_rate": meeting_rate,
        "contact_fraction": contact_fraction,
        "mean_contact_duration": mean_contact_duration,
        "completed_contacts": n_done,
        "censored_contacts": jnp.sum((run > 0).astype(jnp.int32)),
        "censored_contact_steps": jnp.sum(run),
        "mean_inter_contact": mean_inter_contact,
        "encounter_counts": encounter_counts,
        "inter_contact_hist": hist,
        "inter_contact_cdf": cdf,
    }


def summarize(stats: Dict[str, jax.Array]) -> str:
    """One-line human-readable digest of :func:`encounter_stats` output."""
    return (f"meet_rate={float(stats['meeting_rate']):.4f}/s "
            f"contact_frac={float(stats['contact_fraction']):.4f} "
            f"dur={float(stats['mean_contact_duration']):.1f}s "
            f"(censored={int(stats['censored_contacts'])}) "
            f"ict={float(stats['mean_inter_contact']):.1f}s")
