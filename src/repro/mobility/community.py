"""Community / RPGM group mobility (Reference Point Group Mobility).

``cfg.num_bands`` group centers each do a random waypoint over the whole
area; members orbit their group's moving center, re-sampling a local
target inside ``community_radius`` whenever they reach the previous one.
With probability ``roam_prob`` a member's next leg targets a uniform
point anywhere (inter-community roaming — the contact bridge that lets
models spread between communities). Free agents (band == -1) always roam.

This maps naturally onto the paper's grouped data distribution and
group-cache policy: band IS the community id, so the same ``make_bands``
assignment drives both the data partition and the motion.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig
from repro.mobility.base import (
    MobilityModel, advance_toward, contacts_from_positions,
    generic_simulate_epoch, generic_simulate_epoch_rows)
from repro.mobility.registry import register


@dataclasses.dataclass
class CommunityState:
    pos: jax.Array          # [N, 2] member positions (meters)
    target: jax.Array       # [N, 2] absolute current member target
    speed: jax.Array        # [N] member speed for the current leg
    roaming: jax.Array      # [N] bool — current leg ignores the community
    band: jax.Array         # [N] int32 community id (-1 = free roamer)
    centers: jax.Array      # [G, 2] group-center positions
    center_dest: jax.Array  # [G, 2] group-center waypoints

jax.tree_util.register_dataclass(
    CommunityState,
    data_fields=["pos", "target", "speed", "roaming", "band", "centers",
                 "center_dest"],
    meta_fields=[])


def _uniform_area(key, n: int, cfg: MobilityConfig) -> jax.Array:
    return jax.random.uniform(key, (n, 2)) * jnp.array(
        [cfg.area_w, cfg.area_h])


def _disc_offsets(key, n: int, radius: float) -> jax.Array:
    kr, kt = jax.random.split(key)
    r = radius * jnp.sqrt(jax.random.uniform(kr, (n,)))
    t = jax.random.uniform(kt, (n,), maxval=2.0 * jnp.pi)
    return jnp.stack([r * jnp.cos(t), r * jnp.sin(t)], axis=1)


def _member_targets(key, state_band, centers, cfg: MobilityConfig):
    """Sample fresh member targets + roam flags + speeds."""
    n = state_band.shape[0]
    ko, ku, kr, ks = jax.random.split(key, 4)
    g = jnp.clip(state_band, 0, centers.shape[0] - 1)
    local = centers[g] + _disc_offsets(ko, n, cfg.community_radius)
    anywhere = _uniform_area(ku, n, cfg)
    roam = (state_band < 0) | (jax.random.uniform(kr, (n,)) < cfg.roam_prob)
    target = jnp.where(roam[:, None], anywhere, local)
    target = jnp.clip(target, 0.0, jnp.array([cfg.area_w, cfg.area_h]))
    speed = jax.random.uniform(ks, (n,), minval=cfg.v_min, maxval=cfg.v_max)
    return target, roam, speed


def init_community(key, num_agents: int, cfg: MobilityConfig,
                   band: Optional[jax.Array] = None) -> CommunityState:
    if band is None:
        # without an explicit grouped assignment, spread agents round-robin
        band = jnp.arange(num_agents, dtype=jnp.int32) % max(cfg.num_bands, 1)
    band = band.astype(jnp.int32)
    g = max(cfg.num_bands, 1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    centers = _uniform_area(k1, g, cfg)
    center_dest = _uniform_area(k2, g, cfg)
    target, roam, speed = _member_targets(k3, band, centers, cfg)
    gi = jnp.clip(band, 0, g - 1)
    pos = jnp.where((band < 0)[:, None],
                    _uniform_area(k4, num_agents, cfg),
                    jnp.clip(centers[gi] + _disc_offsets(
                        k4, num_agents, cfg.community_radius),
                        0.0, jnp.array([cfg.area_w, cfg.area_h])))
    return CommunityState(pos=pos, target=target, speed=speed, roaming=roam,
                          band=band, centers=centers,
                          center_dest=center_dest)


def step(state: CommunityState, key, cfg: MobilityConfig) -> CommunityState:
    dt = cfg.step_seconds
    kc, km = jax.random.split(key)
    g = state.centers.shape[0]
    # group centers: plain waypoint over the full area
    c_travel = jnp.full((g,), cfg.center_speed * dt)
    centers, c_arrive = advance_toward(state.centers, state.center_dest,
                                      c_travel)
    center_dest = jnp.where(c_arrive[:, None], _uniform_area(kc, g, cfg),
                            state.center_dest)
    # members: walk toward their target; targets of non-roaming members
    # drift with the center so the community stays coherent
    drift = centers - state.centers
    gi = jnp.clip(state.band, 0, g - 1)
    target = jnp.where(state.roaming[:, None], state.target,
                       state.target + drift[gi])
    target = jnp.clip(target, 0.0, jnp.array([cfg.area_w, cfg.area_h]))
    pos, arrive = advance_toward(state.pos, target, state.speed * dt)
    new_target, new_roam, new_speed = _member_targets(km, state.band,
                                                      centers, cfg)
    return CommunityState(
        pos=pos,
        target=jnp.where(arrive[:, None], new_target, target),
        speed=jnp.where(arrive, new_speed, state.speed),
        roaming=jnp.where(arrive, new_roam, state.roaming),
        band=state.band, centers=centers, center_dest=center_dest)


def positions(state: CommunityState, cfg: MobilityConfig) -> jax.Array:
    return state.pos


def contacts_now(state: CommunityState, cfg: MobilityConfig) -> jax.Array:
    return contacts_from_positions(state.pos, cfg.comm_range)


simulate_epoch = generic_simulate_epoch(step, contacts_now)
simulate_epoch_rows = generic_simulate_epoch_rows(step, positions)

MODEL = register(MobilityModel(
    name="community", init=init_community, step=step, positions=positions,
    contacts_now=contacts_now, simulate_epoch=simulate_epoch,
    simulate_epoch_rows=simulate_epoch_rows))
