"""Random waypoint mobility on a continuous plane.

Each agent picks a uniform destination in the area, a per-leg speed in
[v_min, v_max], travels in a straight line, optionally pauses, repeats.
Area bands restrict an agent's destinations to a horizontal slice of the
plane (the continuous analogue of the Manhattan model's area bands), so
grouped data partitioning works unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig
from repro.mobility.base import (
    MobilityModel, advance_toward, band_limits_y, contacts_from_positions,
    default_band, generic_simulate_epoch, generic_simulate_epoch_rows)
from repro.mobility.registry import register


@dataclasses.dataclass
class WaypointState:
    pos: jax.Array    # [N, 2] float32 meters
    dest: jax.Array   # [N, 2] float32 current waypoint
    speed: jax.Array  # [N] float32 m/s for the current leg
    pause: jax.Array  # [N] float32 seconds of pause remaining
    band: jax.Array   # [N] int32 area restriction (-1 = free)

jax.tree_util.register_dataclass(
    WaypointState, data_fields=["pos", "dest", "speed", "pause", "band"],
    meta_fields=[])


def _sample_point(key, band, cfg: MobilityConfig) -> jax.Array:
    """[N, 2] uniform points, y restricted to each agent's band slice."""
    kx, ky = jax.random.split(key)
    n = band.shape[0]
    lo, hi = band_limits_y(cfg, band)
    x = jax.random.uniform(kx, (n,), minval=0.0, maxval=cfg.area_w)
    y = lo + jax.random.uniform(ky, (n,)) * (hi - lo)
    return jnp.stack([x, y], axis=1)


def _sample_leg(key, band, cfg: MobilityConfig):
    kd, ks, kp = jax.random.split(key, 3)
    n = band.shape[0]
    dest = _sample_point(kd, band, cfg)
    speed = jax.random.uniform(ks, (n,), minval=cfg.v_min, maxval=cfg.v_max)
    pause = jax.random.uniform(kp, (n,), maxval=max(cfg.pause_max, 1e-6))
    pause = jnp.where(cfg.pause_max > 0, pause, 0.0)
    return dest, speed, pause


def init_waypoint(key, num_agents: int, cfg: MobilityConfig,
                  band: Optional[jax.Array] = None) -> WaypointState:
    if band is None:
        band = default_band(num_agents)
    band = band.astype(jnp.int32)
    k1, k2 = jax.random.split(key)
    pos = _sample_point(k1, band, cfg)
    dest, speed, _ = _sample_leg(k2, band, cfg)
    return WaypointState(pos=pos, dest=dest, speed=speed,
                         pause=jnp.zeros((num_agents,), jnp.float32),
                         band=band)


def step(state: WaypointState, key, cfg: MobilityConfig) -> WaypointState:
    dt = cfg.step_seconds
    moving = state.pause <= 0.0
    moved, arrived = advance_toward(state.pos, state.dest, state.speed * dt)
    pos = jnp.where(moving[:, None], moved, state.pos)
    arrive = moving & arrived
    pause = jnp.where(moving, jnp.where(arrive, 0.0, state.pause),
                      jnp.maximum(state.pause - dt, 0.0))
    # agents that arrived start pausing; agents whose pause just ended get
    # a fresh leg
    new_dest, new_speed, new_pause = _sample_leg(key, state.band, cfg)
    need_leg = arrive | (~moving & (pause <= 0.0))
    return WaypointState(
        pos=pos,
        dest=jnp.where(need_leg[:, None], new_dest, state.dest),
        speed=jnp.where(need_leg, new_speed, state.speed),
        pause=jnp.where(arrive, new_pause, pause),
        band=state.band)


def positions(state: WaypointState, cfg: MobilityConfig) -> jax.Array:
    return state.pos


def contacts_now(state: WaypointState, cfg: MobilityConfig) -> jax.Array:
    return contacts_from_positions(state.pos, cfg.comm_range)


simulate_epoch = generic_simulate_epoch(step, contacts_now)
simulate_epoch_rows = generic_simulate_epoch_rows(step, positions)

MODEL = register(MobilityModel(
    name="random_waypoint", init=init_waypoint, step=step,
    positions=positions, contacts_now=contacts_now,
    simulate_epoch=simulate_epoch,
    simulate_epoch_rows=simulate_epoch_rows))
