"""Contact-trace replay mobility — precomputed contact schedules.

Drives the experiment loop from a recorded (or synthetic) contact
schedule instead of simulated motion, so real DTN traces and adversarial
stress schedules exercise exactly the same Cached-DFL code path.

Accepted ``.npz`` layouts (``cfg.trace_path``):
  * dense:      ``contacts`` [T, N, N] bool (symmetrized automatically),
                optional ``pos`` [T, N, 2] float32 for visualisation
  * edge list:  ``time``/``src``/``dst`` int arrays plus scalar
                ``num_steps``/``num_agents`` (each undirected contact
                listed once per frame it is active)

The schedule lives inside the state pytree, so ``simulate_epoch`` stays
fully jit-able; an epoch consumes ``trace_frames_per_epoch`` frames
(default: ``epoch_seconds / step_seconds``) and unions them, wrapping
around (``trace_loop``) or holding the last frame.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MobilityConfig
from repro.mobility.base import (MobilityModel, contact_envelope_active,
                                 epoch_step_times)
from repro.mobility.registry import register


@dataclasses.dataclass
class TraceState:
    contacts: jax.Array  # [T, N, N] bool schedule
    pos: jax.Array       # [T, N, 2] float32 (zeros if the trace has none)
    t: jax.Array         # [] int32 — current frame index

jax.tree_util.register_dataclass(
    TraceState, data_fields=["contacts", "pos", "t"], meta_fields=[])


def contacts_from_edges(time: np.ndarray, src: np.ndarray, dst: np.ndarray,
                        num_steps: int, num_agents: int) -> np.ndarray:
    """Dense [T, N, N] bool schedule from an undirected edge list."""
    seq = np.zeros((num_steps, num_agents, num_agents), bool)
    t = np.asarray(time, np.int64)
    i = np.asarray(src, np.int64)
    j = np.asarray(dst, np.int64)
    if t.size and (t.max() >= num_steps or max(i.max(), j.max()) >= num_agents
                   or min(t.min(), i.min(), j.min()) < 0):
        raise ValueError("edge list indices out of range "
                         "[0, num_steps/num_agents)")
    seq[t, i, j] = True
    seq[t, j, i] = True
    seq[:, np.arange(num_agents), np.arange(num_agents)] = False
    return seq


def save_trace(path: str, contacts: np.ndarray,
               pos: Optional[np.ndarray] = None) -> None:
    """Write a dense contact schedule the ``trace`` model can replay."""
    arrays = {"contacts": np.asarray(contacts, bool)}
    if pos is not None:
        arrays["pos"] = np.asarray(pos, np.float32)
    np.savez_compressed(path, **arrays)


def load_trace(path: str) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    with np.load(path) as z:
        if "contacts" in z:
            seq = np.asarray(z["contacts"], bool)
            pos = np.asarray(z["pos"], np.float32) if "pos" in z else None
        elif "time" in z:
            seq = contacts_from_edges(z["time"], z["src"], z["dst"],
                                      int(z["num_steps"]),
                                      int(z["num_agents"]))
            pos = None
        else:
            raise ValueError(
                f"{path}: expected 'contacts' [T,N,N] or an edge list "
                "('time','src','dst','num_steps','num_agents')")
    if seq.ndim != 3 or seq.shape[1] != seq.shape[2]:
        raise ValueError(f"{path}: contacts must be [T, N, N], got {seq.shape}")
    return seq, pos


def init_from_contacts(contacts, pos=None) -> TraceState:
    """Build a replay state from an in-memory [T, N, N] schedule."""
    seq = jnp.asarray(contacts, bool)
    seq = (seq | jnp.swapaxes(seq, 1, 2))   # symmetrize
    n = seq.shape[1]
    seq = seq & ~jnp.eye(n, dtype=bool)[None]
    if pos is None:
        pos = jnp.zeros((seq.shape[0], n, 2), jnp.float32)
    return TraceState(contacts=seq, pos=jnp.asarray(pos, jnp.float32),
                      t=jnp.asarray(0, jnp.int32))


def init_trace(key, num_agents: int, cfg: MobilityConfig,
               band: Optional[jax.Array] = None) -> TraceState:
    if not cfg.trace_path:
        raise ValueError("mobility model 'trace' needs cfg.trace_path "
                         "(or use trace.init_from_contacts directly)")
    seq, pos = load_trace(cfg.trace_path)
    if seq.shape[1] != num_agents:
        raise ValueError(
            f"trace {cfg.trace_path} has {seq.shape[1]} agents, "
            f"experiment expects {num_agents}")
    return init_from_contacts(seq, pos)


def _advance_t(state: TraceState, cfg: MobilityConfig) -> jax.Array:
    T = state.contacts.shape[0]
    if cfg.trace_loop:
        return (state.t + 1) % T
    return jnp.minimum(state.t + 1, T - 1)


def step(state: TraceState, key, cfg: MobilityConfig) -> TraceState:
    return dataclasses.replace(state, t=_advance_t(state, cfg))


def positions(state: TraceState, cfg: MobilityConfig) -> jax.Array:
    return state.pos[state.t]


def contacts_now(state: TraceState, cfg: MobilityConfig) -> jax.Array:
    return state.contacts[state.t]


def simulate_epoch(state: TraceState, key, cfg: MobilityConfig,
                   seconds: float):
    """Union + per-pair duration over the next ``frames`` schedule entries
    (read frame, then advance)."""
    frames = cfg.trace_frames_per_epoch or max(
        1, int(seconds / cfg.step_seconds))
    diurnal = cfg.diurnal_enabled   # static: off keeps the xs-free scan

    def body(carry, xs):
        st, met, dur = carry
        now = contacts_now(st, cfg)
        if diurnal:
            now = now & xs
        met = met | now
        dur = dur + now.astype(jnp.int32)
        st = step(st, None, cfg)
        return (st, met, dur), None

    n = state.contacts.shape[1]
    met0 = jnp.zeros((n, n), bool)
    dur0 = jnp.zeros((n, n), jnp.int32)
    if diurnal:
        active = contact_envelope_active(cfg, epoch_step_times(cfg, frames))
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0),
                                            active)
    else:
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0), None,
                                            length=frames)
    return state, met, dur


def simulate_epoch_rows(state: TraceState, key, cfg: MobilityConfig,
                        seconds: float, *, row_start, num_rows: int, col_ids):
    """Block-local replay for the sharded engine: the [num_rows, W] slice
    of each frame (rows ``row_start..`` against ``col_ids`` columns), same
    read-frame-then-advance order as :func:`simulate_epoch`."""
    frames = cfg.trace_frames_per_epoch or max(
        1, int(seconds / cfg.step_seconds))
    col_ids = jnp.asarray(col_ids, jnp.int32)
    W = col_ids.shape[0]
    diurnal = cfg.diurnal_enabled   # static; mirrors simulate_epoch

    def body(carry, xs):
        st, met, dur = carry
        frame = contacts_now(st, cfg)
        rows = jax.lax.dynamic_slice(
            frame, (row_start, 0), (num_rows, frame.shape[1]))
        now = jnp.take(rows, col_ids, axis=1)
        if diurnal:
            now = now & xs
        met = met | now
        dur = dur + now.astype(jnp.int32)
        st = step(st, None, cfg)
        return (st, met, dur), None

    met0 = jnp.zeros((num_rows, W), bool)
    dur0 = jnp.zeros((num_rows, W), jnp.int32)
    if diurnal:
        active = contact_envelope_active(cfg, epoch_step_times(cfg, frames))
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0),
                                            active)
    else:
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0), None,
                                            length=frames)
    return state, met, dur


MODEL = register(MobilityModel(
    name="trace", init=init_trace, step=step, positions=positions,
    contacts_now=contacts_now, simulate_epoch=simulate_epoch,
    simulate_epoch_rows=simulate_epoch_rows))
