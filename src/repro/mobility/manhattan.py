"""Manhattan grid mobility model (paper §4.4, after Bai et al. IMPORTANT).

Vehicles move along a W×H grid of streets. At an intersection a vehicle
continues straight with probability 0.5 and turns into each other valid
road with equal share of the remainder (no U-turns; U-turn only at a
dead-end). Contacts = pairwise distance below `comm_range`.

The INRIX Manhattan map is not redistributable; we use a uniform grid with
realistic Manhattan block dimensions (~274 m between avenues, ~80 m between
streets) — the mobility statistics the paper relies on (meeting rate vs
speed/epoch time) are reproduced by the grid topology.

Fully vectorized + jit-able; an epoch of simulation is one lax.scan.
Optional area bands (uptown/midtown/downtown) restrict vehicles for the
group-based caching case study (§5.5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig

# direction encoding: 0=+x (E), 1=+y (N), 2=-x (W), 3=-y (S)
_DX = jnp.array([1, 0, -1, 0], jnp.int32)
_DY = jnp.array([0, 1, 0, -1], jnp.int32)


@dataclasses.dataclass
class MobilityState:
    node: jax.Array    # [N, 2] int32 — intersection the vehicle came from
    dirn: jax.Array    # [N] int32 — current direction of travel
    frac: jax.Array    # [N] float32 — fraction of current edge traversed
    band: jax.Array    # [N] int32 — area restriction (-1 = free vehicle)

jax.tree_util.register_dataclass(
    MobilityState, data_fields=["node", "dirn", "frac", "band"],
    meta_fields=[])


def make_bands(num_agents: int, num_bands: int, free_per_band: int = 3,
               key=None):
    """Assign agents to area bands; a few 'free' vehicles roam anywhere.

    Mirrors the paper's 3-area setup (30 restricted + 3-4 free per area).
    Returns band assignment [N] (-1 = free) and data-group [N] (free
    vehicles still have a home data group).
    """
    per = num_agents // num_bands
    group = jnp.repeat(jnp.arange(num_bands, dtype=jnp.int32), per)
    if group.shape[0] < num_agents:
        extra = jnp.arange(num_agents - group.shape[0], dtype=jnp.int32) % num_bands
        group = jnp.concatenate([group, extra])
    band = group.copy()
    # first `free_per_band` agents of each band are free-roaming
    idx = jnp.arange(num_agents)
    start = (group * per)
    band = jnp.where(idx - start < free_per_band, -1, band)
    return band, group


def _band_limits(cfg: MobilityConfig, band, num_bands: int = 3):
    """y-node range [lo, hi) for a band; free vehicles get the whole grid."""
    h = cfg.grid_h // num_bands
    lo = jnp.where(band < 0, 0, band * h)
    hi = jnp.where(band < 0, cfg.grid_h, jnp.where(
        band == num_bands - 1, cfg.grid_h, (band + 1) * h))
    return lo, hi


def init_mobility(key, num_agents: int, cfg: MobilityConfig,
                  band: Optional[jax.Array] = None) -> MobilityState:
    if band is None:
        band = jnp.full((num_agents,), -1, jnp.int32)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = _band_limits(cfg, band)
    nx = jax.random.randint(k1, (num_agents,), 0, cfg.grid_w)
    ny = lo + jax.random.randint(k2, (num_agents,), 0, 1_000_000) % jnp.maximum(hi - lo, 1)
    node = jnp.stack([nx, ny], axis=1).astype(jnp.int32)
    dirn = jax.random.randint(k3, (num_agents,), 0, 4).astype(jnp.int32)
    state = MobilityState(node=node, dirn=dirn,
                          frac=jnp.zeros((num_agents,), jnp.float32),
                          band=band.astype(jnp.int32))
    # ensure initial directions are valid
    return dataclasses.replace(
        state, dirn=_choose_direction(key, state, cfg, force=True))


def _valid_dirs(node, band, cfg: MobilityConfig):
    """[N, 4] bool — which directions stay on the grid (and in the band)."""
    x, y = node[:, 0], node[:, 1]
    lo, hi = _band_limits(cfg, band)
    tx = x[:, None] + _DX[None, :]
    ty = y[:, None] + _DY[None, :]
    ok = (tx >= 0) & (tx < cfg.grid_w) & (ty >= lo[:, None]) & (ty < hi[:, None])
    return ok


def _choose_direction(key, state: MobilityState, cfg: MobilityConfig,
                      force: bool = False):
    """Sample the next direction at an intersection (paper's turn rule)."""
    N = state.dirn.shape[0]
    ok = _valid_dirs(state.node, state.band, cfg)
    straight = state.dirn
    reverse = (state.dirn + 2) % 4
    # candidate probabilities
    p = jnp.where(ok, 1.0, 0.0)
    # exclude reverse unless it is the only option
    only_reverse = jnp.sum(p, axis=1) <= p[jnp.arange(N), reverse]
    p = p.at[jnp.arange(N), reverse].set(
        jnp.where(only_reverse, p[jnp.arange(N), reverse], 0.0))
    straight_ok = ok[jnp.arange(N), straight] & ~only_reverse
    # straight gets p_straight; others share the remainder
    n_turns = jnp.maximum(jnp.sum(p, axis=1) - straight_ok, 1e-9)
    turn_p = jnp.where(straight_ok, (1 - cfg.p_straight) / n_turns,
                       1.0 / jnp.maximum(jnp.sum(p, axis=1), 1e-9))
    probs = p * turn_p[:, None]
    probs = probs.at[jnp.arange(N), straight].set(
        jnp.where(straight_ok, cfg.p_straight, probs[jnp.arange(N), straight]))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=1, keepdims=True), 1e-9)
    return jax.random.categorical(key, jnp.log(probs + 1e-12), axis=1).astype(
        jnp.int32)


def _edge_len(dirn, cfg: MobilityConfig):
    return jnp.where((dirn % 2) == 0, cfg.block_w, cfg.block_h)


def step(state: MobilityState, key, cfg: MobilityConfig) -> MobilityState:
    """Advance all vehicles by cfg.step_seconds."""
    dist = cfg.speed * cfg.step_seconds
    frac = state.frac + dist / _edge_len(state.dirn, cfg)
    arrived = frac >= 1.0
    new_node = jnp.where(
        arrived[:, None],
        state.node + jnp.stack([_DX[state.dirn], _DY[state.dirn]], 1),
        state.node)
    state = dataclasses.replace(state, node=new_node,
                                frac=jnp.where(arrived, 0.0, frac))
    new_dir = _choose_direction(key, state, cfg)
    return dataclasses.replace(
        state, dirn=jnp.where(arrived, new_dir, state.dirn))


def positions(state: MobilityState, cfg: MobilityConfig) -> jax.Array:
    """[N, 2] positions in meters."""
    base = state.node.astype(jnp.float32) * jnp.array(
        [cfg.block_w, cfg.block_h])
    off = state.frac[:, None] * _edge_len(state.dirn, cfg)[:, None]
    dvec = jnp.stack([_DX[state.dirn], _DY[state.dirn]], 1).astype(jnp.float32)
    return base + off * dvec


def contacts_now(state: MobilityState, cfg: MobilityConfig) -> jax.Array:
    """[N, N] bool symmetric contact matrix (diag False)."""
    pos = positions(state, cfg)
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    within = d2 <= cfg.comm_range ** 2
    return within & ~jnp.eye(pos.shape[0], dtype=bool)


def simulate_epoch(state: MobilityState, key, cfg: MobilityConfig,
                   seconds: float) -> Tuple[MobilityState, jax.Array]:
    """Run one epoch; returns union contact matrix over all sub-steps."""
    n_steps = max(1, int(seconds / cfg.step_seconds))
    keys = jax.random.split(key, n_steps)

    def body(carry, k):
        st, met = carry
        st = step(st, k, cfg)
        met = met | contacts_now(st, cfg)
        return (st, met), None

    N = state.dirn.shape[0]
    met0 = jnp.zeros((N, N), bool)
    (state, met), _ = jax.lax.scan(body, (state, met0), keys)
    return state, met


def partners_from_contacts(met: jax.Array, max_partners: int) -> jax.Array:
    """[N, D] partner ids from a contact matrix, -1 padded.

    Deterministic: lowest agent ids first (matches a fixed D2D pairing
    order); capped at D contacts per epoch (radio budget).
    """
    N = met.shape[0]
    # rank contacts: non-contacts pushed to the end
    key = jnp.where(met, jnp.arange(N)[None, :], N + 1)
    order = jnp.sort(key, axis=1)[:, :max_partners]
    return jnp.where(order <= N, order, -1).astype(jnp.int32)
