"""Manhattan grid mobility model (paper §4.4, after Bai et al. IMPORTANT).

Vehicles move along a W×H grid of streets. At an intersection a vehicle
continues straight with probability 0.5 and turns into each other valid
road with equal share of the remainder (no U-turns; U-turn only at a
dead-end). Contacts = pairwise distance below `comm_range`.

The INRIX Manhattan map is not redistributable; we use a uniform grid with
realistic Manhattan block dimensions (~274 m between avenues, ~80 m between
streets) — the mobility statistics the paper relies on (meeting rate vs
speed/epoch time) are reproduced by the grid topology.

Fully vectorized + jit-able; an epoch of simulation is one lax.scan.
Optional area bands (uptown/midtown/downtown) restrict vehicles for the
group-based caching case study (§5.5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig
from repro.mobility.base import (  # noqa: F401  (re-exported for back-compat)
    MobilityModel, contacts_from_positions, generic_simulate_epoch,
    generic_simulate_epoch_rows, make_bands, partners_from_contacts)
from repro.mobility.registry import register

# direction encoding: 0=+x (E), 1=+y (N), 2=-x (W), 3=-y (S)
_DX = jnp.array([1, 0, -1, 0], jnp.int32)
_DY = jnp.array([0, 1, 0, -1], jnp.int32)


@dataclasses.dataclass
class MobilityState:
    node: jax.Array    # [N, 2] int32 — intersection the vehicle came from
    dirn: jax.Array    # [N] int32 — current direction of travel
    frac: jax.Array    # [N] float32 — fraction of current edge traversed
    band: jax.Array    # [N] int32 — area restriction (-1 = free vehicle)

jax.tree_util.register_dataclass(
    MobilityState, data_fields=["node", "dirn", "frac", "band"],
    meta_fields=[])


def _band_limits(cfg: MobilityConfig, band):
    """y-node range [lo, hi) for a band; free vehicles get the whole grid.

    The band count comes from ``cfg.num_bands`` (threaded from
    ``ExperimentConfig.num_groups`` by the experiment harness), so grouped
    runs with ≠3 groups restrict vehicles correctly.
    """
    num_bands = max(cfg.num_bands, 1)
    # proportional integer bounds: never empty (hi > lo) and always inside
    # the grid, even when num_bands > grid_h
    lo = jnp.where(band < 0, 0, (band * cfg.grid_h) // num_bands)
    hi = jnp.where(band < 0, cfg.grid_h,
                   jnp.maximum(((band + 1) * cfg.grid_h) // num_bands,
                               lo + 1))
    return lo, hi


def init_mobility(key, num_agents: int, cfg: MobilityConfig,
                  band: Optional[jax.Array] = None) -> MobilityState:
    if band is None:
        band = jnp.full((num_agents,), -1, jnp.int32)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = _band_limits(cfg, band)
    nx = jax.random.randint(k1, (num_agents,), 0, cfg.grid_w)
    # per-agent [lo, hi) bounds sample uniformly — no modulo bias
    ny = jax.random.randint(k2, (num_agents,), lo, jnp.maximum(hi, lo + 1))
    node = jnp.stack([nx, ny], axis=1).astype(jnp.int32)
    dirn = jax.random.randint(k3, (num_agents,), 0, 4).astype(jnp.int32)
    state = MobilityState(node=node, dirn=dirn,
                          frac=jnp.zeros((num_agents,), jnp.float32),
                          band=band.astype(jnp.int32))
    # ensure initial directions are valid
    return dataclasses.replace(
        state, dirn=_choose_direction(key, state, cfg, force=True))


def _valid_dirs(node, band, cfg: MobilityConfig):
    """[N, 4] bool — which directions stay on the grid (and in the band)."""
    x, y = node[:, 0], node[:, 1]
    lo, hi = _band_limits(cfg, band)
    tx = x[:, None] + _DX[None, :]
    ty = y[:, None] + _DY[None, :]
    ok = (tx >= 0) & (tx < cfg.grid_w) & (ty >= lo[:, None]) & (ty < hi[:, None])
    return ok


def _choose_direction(key, state: MobilityState, cfg: MobilityConfig,
                      force: bool = False):
    """Sample the next direction at an intersection (paper's turn rule)."""
    N = state.dirn.shape[0]
    ok = _valid_dirs(state.node, state.band, cfg)
    straight = state.dirn
    reverse = (state.dirn + 2) % 4
    # candidate probabilities
    p = jnp.where(ok, 1.0, 0.0)
    # exclude reverse unless it is the only option
    only_reverse = jnp.sum(p, axis=1) <= p[jnp.arange(N), reverse]
    p = p.at[jnp.arange(N), reverse].set(
        jnp.where(only_reverse, p[jnp.arange(N), reverse], 0.0))
    straight_ok = ok[jnp.arange(N), straight] & ~only_reverse
    # straight gets p_straight; others share the remainder
    n_turns = jnp.maximum(jnp.sum(p, axis=1) - straight_ok, 1e-9)
    turn_p = jnp.where(straight_ok, (1 - cfg.p_straight) / n_turns,
                       1.0 / jnp.maximum(jnp.sum(p, axis=1), 1e-9))
    probs = p * turn_p[:, None]
    probs = probs.at[jnp.arange(N), straight].set(
        jnp.where(straight_ok, cfg.p_straight, probs[jnp.arange(N), straight]))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=1, keepdims=True), 1e-9)
    return jax.random.categorical(key, jnp.log(probs + 1e-12), axis=1).astype(
        jnp.int32)


def _edge_len(dirn, cfg: MobilityConfig):
    return jnp.where((dirn % 2) == 0, cfg.block_w, cfg.block_h)


def step(state: MobilityState, key, cfg: MobilityConfig) -> MobilityState:
    """Advance all vehicles by cfg.step_seconds."""
    dist = cfg.speed * cfg.step_seconds
    frac = state.frac + dist / _edge_len(state.dirn, cfg)
    arrived = frac >= 1.0
    new_node = jnp.where(
        arrived[:, None],
        state.node + jnp.stack([_DX[state.dirn], _DY[state.dirn]], 1),
        state.node)
    state = dataclasses.replace(state, node=new_node,
                                frac=jnp.where(arrived, 0.0, frac))
    new_dir = _choose_direction(key, state, cfg)
    return dataclasses.replace(
        state, dirn=jnp.where(arrived, new_dir, state.dirn))


def positions(state: MobilityState, cfg: MobilityConfig) -> jax.Array:
    """[N, 2] positions in meters."""
    base = state.node.astype(jnp.float32) * jnp.array(
        [cfg.block_w, cfg.block_h])
    off = state.frac[:, None] * _edge_len(state.dirn, cfg)[:, None]
    dvec = jnp.stack([_DX[state.dirn], _DY[state.dirn]], 1).astype(jnp.float32)
    return base + off * dvec


def contacts_now(state: MobilityState, cfg: MobilityConfig) -> jax.Array:
    """[N, N] bool symmetric contact matrix (diag False)."""
    return contacts_from_positions(positions(state, cfg), cfg.comm_range)


# one epoch of simulation; returns the union contact matrix over sub-steps
simulate_epoch = generic_simulate_epoch(step, contacts_now)
simulate_epoch_rows = generic_simulate_epoch_rows(step, positions)


MODEL = register(MobilityModel(
    name="manhattan", init=init_mobility, step=step, positions=positions,
    contacts_now=contacts_now, simulate_epoch=simulate_epoch,
    simulate_epoch_rows=simulate_epoch_rows))
