"""Mobility subsystem protocol + shared helpers.

Every mobility model is a :class:`MobilityModel` bundle of pure, jit-able
functions over an opaque pytree state:

    init(key, num_agents, cfg, band=None)   -> state
    step(state, key, cfg)                   -> state      (advance step_seconds)
    positions(state, cfg)                   -> [N, 2] f32 (meters)
    contacts_now(state, cfg)                -> [N, N] bool (symmetric, diag F)
    simulate_epoch(state, key, cfg, seconds)-> (state, [N, N] bool union,
                                                [N, N] int32 durations)

``durations[i, j]`` counts the simulation steps pair (i, j) spent in
contact during the epoch — the measured contact time that a
bandwidth-limited link can actually use (``gossip.exchange`` converts it
into a per-link transfer budget via ``DFLConfig.link_entries_per_step``).

The fleet loop in ``fl/experiment.py`` only consumes the
``simulate_epoch -> (union contacts, durations) -> partners_from_contacts``
contract, so any registered model slots in unchanged. Models with
community structure honour ``band`` ([N] int32, -1 = unrestricted) so the
grouped data partition / group-cache case study works for all of them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig


@dataclasses.dataclass(frozen=True)
class MobilityModel:
    """A named mobility model: pure functions over an opaque state pytree."""
    name: str
    init: Callable[..., Any]
    step: Callable[..., Any]
    positions: Callable[..., Any]
    contacts_now: Callable[..., Any]
    simulate_epoch: Callable[..., Any]
    # block-local variant for the sharded fleet engine: same trajectory as
    # simulate_epoch (mobility state is replicated per shard), but only the
    # [num_rows, len(col_ids)] contact/duration block for the shard's agent
    # rows against a window of candidate columns is materialized —
    # simulate_epoch_rows(state, key, cfg, seconds, row_start=, num_rows=,
    # col_ids=) -> (state, met, dur). None = model has no block variant.
    simulate_epoch_rows: Optional[Callable[..., Any]] = None


# ---------------------------------------------------------------------------
# shared geometry / contact helpers
# ---------------------------------------------------------------------------

def contacts_from_positions(pos: jax.Array, comm_range: float) -> jax.Array:
    """[N, N] bool symmetric contact matrix (diag False) from positions."""
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    within = d2 <= comm_range ** 2
    return within & ~jnp.eye(pos.shape[0], dtype=bool)


def contacts_block_from_positions(pos: jax.Array, comm_range: float,
                                  row_start: jax.Array, num_rows: int,
                                  col_ids: jax.Array) -> jax.Array:
    """[num_rows, W] bool contact block: fleet rows [row_start,
    row_start+num_rows) against the ``col_ids`` ([W] global agent ids)
    columns. Elementwise identical to the matching slice of
    :func:`contacts_from_positions` (same distance arithmetic), so the
    sharded engine's full-window mode stays bit-exact with the dense path.
    """
    rows = jax.lax.dynamic_slice(pos, (row_start, 0), (num_rows, pos.shape[1]))
    cols = jnp.take(pos, col_ids, axis=0)
    d2 = jnp.sum((rows[:, None] - cols[None, :]) ** 2, axis=-1)
    within = d2 <= comm_range ** 2
    row_ids = row_start + jnp.arange(num_rows, dtype=col_ids.dtype)
    return within & (col_ids[None, :] != row_ids[:, None])


def band_limits_y(cfg: MobilityConfig, band: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Continuous-plane y-range [lo, hi) in meters for an area band.

    Free vehicles (band == -1) get the whole area. The plane analogue of
    ``manhattan._band_limits``.
    """
    h = cfg.area_h / max(cfg.num_bands, 1)
    b = band.astype(jnp.float32)
    lo = jnp.where(band < 0, 0.0, b * h)
    hi = jnp.where(band < 0, cfg.area_h, (b + 1.0) * h)
    return lo, hi


def default_band(num_agents: int) -> jax.Array:
    return jnp.full((num_agents,), -1, jnp.int32)


def make_bands(num_agents: int, num_bands: int, free_per_band: int = 3,
               key=None):
    """Assign agents to area bands; a few 'free' vehicles roam anywhere.

    Mirrors the paper's 3-area setup (30 restricted + 3-4 free per area).
    Returns band assignment [N] (-1 = free) and data-group [N] (free
    vehicles still have a home data group). Shared by every
    community-structured mobility model, not just the Manhattan grid.
    """
    per = num_agents // num_bands
    group = jnp.repeat(jnp.arange(num_bands, dtype=jnp.int32), per)
    if group.shape[0] < num_agents:
        extra = jnp.arange(num_agents - group.shape[0], dtype=jnp.int32) % num_bands
        group = jnp.concatenate([group, extra])
    band = group.copy()
    # first `free_per_band` agents of each band are free-roaming
    idx = jnp.arange(num_agents)
    start = (group * per)
    band = jnp.where(idx - start < free_per_band, -1, band)
    return band, group


def contact_activity(cfg: MobilityConfig, tau) -> jax.Array:
    """Diurnal activity g(τ) ∈ [0, 1] at in-epoch time ``tau`` seconds.

    A raised cosine over ``diurnal_period``: 1 at the peak of the cycle,
    0 at the trough. The envelope's phase restarts each epoch (τ is time
    *within* the epoch), so every compiled epoch step stays identical —
    one cycle per epoch when ``diurnal_period == epoch_seconds``.
    """
    period = max(float(cfg.diurnal_period), 1e-9)
    ang = 2.0 * jnp.pi * (jnp.asarray(tau, jnp.float32)
                          + cfg.diurnal_phase) / period
    return 0.5 * (1.0 + jnp.cos(ang))


def contact_envelope_active(cfg: MobilityConfig, tau) -> jax.Array:
    """Bool: does a simulation step at in-epoch time ``tau`` register
    contacts? Active while :func:`contact_activity` is at least the
    configured amplitude — amplitude 0 is always active, 1 only at the
    exact cycle peaks."""
    return contact_activity(cfg, tau) >= cfg.diurnal_amplitude


def epoch_step_times(cfg: MobilityConfig, n_steps: int) -> jax.Array:
    """[n_steps] f32 — in-epoch time after each simulation step, the τ
    the diurnal envelope is evaluated at (contacts are read *after* the
    step advances, so step s covers time (s+1)·step_seconds)."""
    return (jnp.arange(1, n_steps + 1, dtype=jnp.float32)
            * cfg.step_seconds)


def advance_toward(pos: jax.Array, dest: jax.Array, travel: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Move straight toward ``dest`` by ``travel`` meters, snapping on
    arrival. Returns (new_pos [N, 2], arrived [N] bool)."""
    delta = dest - pos
    dist = jnp.linalg.norm(delta, axis=1)
    arrive = dist <= travel
    unit = delta / jnp.maximum(dist, 1e-9)[:, None]
    new = jnp.where(arrive[:, None], dest, pos + unit * travel[:, None])
    return new, arrive


def generic_simulate_epoch(step_fn: Callable, contacts_fn: Callable
                           ) -> Callable:
    """Build a simulate_epoch from step + contacts_now (one lax.scan).

    Returns ``(state, union, durations)`` — the union contact matrix plus
    the per-pair steps-in-contact count the transfer budget is derived
    from. Both accumulate inside the same scan, so measuring durations
    costs no extra simulation pass.
    """

    def simulate_epoch(state, key, cfg: MobilityConfig, seconds: float):
        n_steps = max(1, int(seconds / cfg.step_seconds))
        keys = jax.random.split(key, n_steps)
        diurnal = cfg.diurnal_enabled   # static: off emits the exact
        # pre-envelope program (same scan body, same xs — bit-exact)

        def body(carry, xs):
            st, met, dur = carry
            if diurnal:
                k, active = xs
            else:
                k = xs
            st = step_fn(st, k, cfg)
            now = contacts_fn(st, cfg)
            if diurnal:
                now = now & active
            met = met | now
            dur = dur + now.astype(jnp.int32)
            return (st, met, dur), None

        shape = jax.eval_shape(lambda s: contacts_fn(s, cfg), state).shape
        met0 = jnp.zeros(shape, bool)
        dur0 = jnp.zeros(shape, jnp.int32)
        xs = keys
        if diurnal:
            xs = (keys, contact_envelope_active(
                cfg, epoch_step_times(cfg, n_steps)))
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0), xs)
        return state, met, dur

    return simulate_epoch


def generic_simulate_epoch_rows(step_fn: Callable, positions_fn: Callable
                                ) -> Callable:
    """Block-local counterpart of :func:`generic_simulate_epoch`.

    Advances the full (replicated) mobility state exactly like the dense
    scan — same key split, same step order — but only accumulates the
    ``[num_rows, W]`` contact/duration block of the shard's agent rows
    against the ``col_ids`` candidate window, so per-shard contact cost is
    O(num_rows * W) instead of O(N^2). With ``col_ids = arange(N)`` the
    block is the exact row slice of the dense matrices.
    """

    def simulate_epoch_rows(state, key, cfg: MobilityConfig, seconds: float,
                            *, row_start, num_rows: int, col_ids):
        n_steps = max(1, int(seconds / cfg.step_seconds))
        keys = jax.random.split(key, n_steps)
        col_ids = jnp.asarray(col_ids, jnp.int32)
        W = col_ids.shape[0]
        diurnal = cfg.diurnal_enabled   # static; mirrors the dense scan

        def body(carry, xs):
            st, met, dur = carry
            if diurnal:
                k, active = xs
            else:
                k = xs
            st = step_fn(st, k, cfg)
            now = contacts_block_from_positions(
                positions_fn(st, cfg), cfg.comm_range, row_start, num_rows,
                col_ids)
            if diurnal:
                now = now & active
            met = met | now
            dur = dur + now.astype(jnp.int32)
            return (st, met, dur), None

        met0 = jnp.zeros((num_rows, W), bool)
        dur0 = jnp.zeros((num_rows, W), jnp.int32)
        xs = keys
        if diurnal:
            xs = (keys, contact_envelope_active(
                cfg, epoch_step_times(cfg, n_steps)))
        (state, met, dur), _ = jax.lax.scan(body, (state, met0, dur0), xs)
        return state, met, dur

    return simulate_epoch_rows


# ---------------------------------------------------------------------------
# partner selection under a radio budget
# ---------------------------------------------------------------------------

def partners_from_contacts(met: jax.Array, max_partners: int, *,
                           sample: str = "lowest-id",
                           key: Optional[jax.Array] = None) -> jax.Array:
    """[N, D] partner ids from a contact matrix, -1 padded.

    ``sample="lowest-id"`` keeps the historical deterministic order (lowest
    agent ids first — a fixed D2D pairing order). ``sample="random"``
    permutes each row's contacts with ``key`` before capping at D, so no
    agent is systematically starved under a radio budget — the fairer
    default for non-grid models.

    ``met`` may be the square [N, N] matrix or a row block [n, W] (sharded
    engine); partner ids index the *columns* of ``met`` either way.
    """
    W = met.shape[1]
    if sample == "lowest-id":
        rank = jnp.where(met, jnp.arange(W, dtype=jnp.float32)[None, :],
                         jnp.inf)
    elif sample == "random":
        if key is None:
            raise ValueError("sample='random' requires a PRNG key")
        rank = jnp.where(met, jax.random.uniform(key, met.shape), jnp.inf)
    else:
        raise ValueError(f"unknown partner sample mode {sample!r}")
    idx = jnp.argsort(rank, axis=1)[:, :max_partners]
    chosen = jnp.take_along_axis(met, idx, axis=1)
    return jnp.where(chosen, idx, -1).astype(jnp.int32)
