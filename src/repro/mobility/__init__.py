from repro.mobility.manhattan import (  # noqa: F401
    MobilityState, init_mobility, positions, simulate_epoch,
    partners_from_contacts, make_bands,
)
