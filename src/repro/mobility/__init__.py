"""Pluggable mobility subsystem.

Models are selected by name via :mod:`repro.mobility.registry`
(``MobilityConfig.model``); all satisfy the :class:`~repro.mobility.base.
MobilityModel` protocol and feed the same ``simulate_epoch → (union
contact matrix, per-pair contact durations) → partners_from_contacts``
contract the fleet loop uses; the durations drive the transfer budget.
"""
from repro.mobility.base import (  # noqa: F401
    MobilityModel, contacts_from_positions, make_bands,
    partners_from_contacts,
)
from repro.mobility.registry import available, get_model, register  # noqa: F401
# Manhattan back-compat exports (historically `from repro.mobility import *`)
from repro.mobility.manhattan import (  # noqa: F401
    MobilityState, init_mobility, positions, simulate_epoch,
)
