"""Registry of mobility models, keyed by name.

``get_model("levy_walk")`` etc. — the experiment harness, benchmarks and
tools select mobility by ``MobilityConfig.model`` instead of importing a
specific module. Third-party models register themselves by calling
:func:`register` at import time.
"""
from __future__ import annotations

from typing import Dict, List

from repro.mobility.base import MobilityModel

_REGISTRY: Dict[str, MobilityModel] = {}


def register(model: MobilityModel) -> MobilityModel:
    _REGISTRY[model.name] = model
    return model


def _ensure_builtins() -> None:
    # import for registration side effects; cheap after the first call
    from repro.mobility import community, levy, manhattan, trace, waypoint  # noqa: F401


def get_model(name: str) -> MobilityModel:
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(f"unknown mobility model {name!r}; "
                       f"registered: {available()}")
    return _REGISTRY[name]


def available() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
