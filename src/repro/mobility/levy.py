"""Lévy walk mobility — truncated power-law flight lengths.

The standard model for human/vehicle mobility (Rhee et al., "On the
Levy-walk nature of human mobility"): each flight has a uniformly random
heading and a length drawn from a truncated Pareto distribution
P(l) ∝ l^-(1+α) on [levy_min_flight, levy_max_flight]. Small α → heavy
tail → occasional very long flights that mix the fleet; large α →
near-Brownian local motion. Agents reflect off area (and band) borders.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MobilityConfig
from repro.mobility.base import (
    MobilityModel, band_limits_y, contacts_from_positions, default_band,
    generic_simulate_epoch, generic_simulate_epoch_rows)
from repro.mobility.registry import register
from repro.mobility.waypoint import _sample_point


@dataclasses.dataclass
class LevyState:
    pos: jax.Array      # [N, 2] float32 meters
    heading: jax.Array  # [N, 2] float32 unit direction
    remain: jax.Array   # [N] float32 meters left in the current flight
    band: jax.Array     # [N] int32 (-1 = free)

jax.tree_util.register_dataclass(
    LevyState, data_fields=["pos", "heading", "remain", "band"],
    meta_fields=[])


def _sample_flight(key, n: int, cfg: MobilityConfig):
    """Headings + truncated-Pareto lengths via inverse-CDF sampling."""
    ka, kl = jax.random.split(key)
    theta = jax.random.uniform(ka, (n,), maxval=2.0 * jnp.pi)
    heading = jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=1)
    u = jax.random.uniform(kl, (n,))
    a = cfg.levy_alpha
    lmin, lmax = cfg.levy_min_flight, max(cfg.levy_max_flight,
                                          cfg.levy_min_flight + 1e-6)
    ratio = (lmin / lmax) ** a
    length = lmin * (1.0 - u * (1.0 - ratio)) ** (-1.0 / a)
    return heading, length


def init_levy(key, num_agents: int, cfg: MobilityConfig,
              band: Optional[jax.Array] = None) -> LevyState:
    if band is None:
        band = default_band(num_agents)
    band = band.astype(jnp.int32)
    k1, k2 = jax.random.split(key)
    pos = _sample_point(k1, band, cfg)
    heading, length = _sample_flight(k2, num_agents, cfg)
    return LevyState(pos=pos, heading=heading, remain=length, band=band)


def _reflect(pos, heading, band, cfg: MobilityConfig):
    """Bounce off the area borders (and the agent's band slice in y)."""
    lo, hi = band_limits_y(cfg, band)
    x, y = pos[:, 0], pos[:, 1]
    hx, hy = heading[:, 0], heading[:, 1]
    over_x = (x < 0.0) | (x > cfg.area_w)
    x = jnp.clip(jnp.where(x < 0.0, -x, jnp.where(x > cfg.area_w,
                                                  2 * cfg.area_w - x, x)),
                 0.0, cfg.area_w)
    over_y = (y < lo) | (y > hi)
    y = jnp.clip(jnp.where(y < lo, 2 * lo - y,
                           jnp.where(y > hi, 2 * hi - y, y)), lo, hi)
    hx = jnp.where(over_x, -hx, hx)
    hy = jnp.where(over_y, -hy, hy)
    return jnp.stack([x, y], 1), jnp.stack([hx, hy], 1)


def step(state: LevyState, key, cfg: MobilityConfig) -> LevyState:
    travel = jnp.minimum(cfg.speed * cfg.step_seconds, state.remain)
    pos = state.pos + state.heading * travel[:, None]
    pos, heading = _reflect(pos, state.heading, state.band, cfg)
    remain = state.remain - travel
    done = remain <= 1e-6
    new_heading, new_len = _sample_flight(key, state.band.shape[0], cfg)
    return LevyState(
        pos=pos,
        heading=jnp.where(done[:, None], new_heading, heading),
        remain=jnp.where(done, new_len, remain),
        band=state.band)


def positions(state: LevyState, cfg: MobilityConfig) -> jax.Array:
    return state.pos


def contacts_now(state: LevyState, cfg: MobilityConfig) -> jax.Array:
    return contacts_from_positions(state.pos, cfg.comm_range)


simulate_epoch = generic_simulate_epoch(step, contacts_now)
simulate_epoch_rows = generic_simulate_epoch_rows(step, positions)

MODEL = register(MobilityModel(
    name="levy_walk", init=init_levy, step=step, positions=positions,
    contacts_now=contacts_now, simulate_epoch=simulate_epoch,
    simulate_epoch_rows=simulate_epoch_rows))
