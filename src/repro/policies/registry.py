"""Registry of cache policies, keyed by name.

``get_policy("lru")`` etc. — the gossip exchange, the fleet engine,
benchmarks and tools select the retention policy by ``DFLConfig.policy``
instead of hardcoding a dispatch. Third-party policies register
themselves by calling :func:`register` at import time (mirrors
``repro.mobility.registry``).
"""
from __future__ import annotations

from typing import Dict, List, Union

from repro.policies.base import CachePolicy

_REGISTRY: Dict[str, CachePolicy] = {}


def register(policy: CachePolicy) -> CachePolicy:
    _REGISTRY[policy.name] = policy
    return policy


def _ensure_builtins() -> None:
    # import for registration side effects; cheap after the first call
    from repro.policies import builtin  # noqa: F401


def get_policy(name: str) -> CachePolicy:
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(f"unknown cache policy {name!r}; "
                       f"registered: {available()}")
    return _REGISTRY[name]


def resolve(policy: Union[str, CachePolicy]) -> CachePolicy:
    """Accept either a policy name or an already-built CachePolicy."""
    if isinstance(policy, CachePolicy):
        return policy
    return get_policy(policy)


def available() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
