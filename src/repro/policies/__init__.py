"""Pluggable cache-retention policies (paper §2.2–§3, Algorithms 2 & 3).

Registry-driven, mirroring ``repro.mobility``: select by name via
``DFLConfig.policy``; add a policy by registering a ~10-line priority
function (see ``repro.policies.base``).
"""
from repro.policies.base import (  # noqa: F401
    CachePolicy, PolicyContext, dedup_mask, effective_staleness_decay,
    retain,
)
from repro.policies.registry import (  # noqa: F401
    available, get_policy, register, resolve,
)
