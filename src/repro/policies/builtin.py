"""Built-in cache policies.

``lru`` and ``group`` are the paper's Algorithms 2 & 3; ``fifo`` and
``random`` are the policy-study baselines. The rest are beyond-paper
extensions after distribution/mobility-aware caching (arXiv:2505.18866,
arXiv:2512.24694):

``mobility_aware``     LRU biased by per-pair encounter rates — models from
                       frequently-met origins are evicted first (they are
                       cheap to re-obtain at the next contact), models from
                       rarely-met origins are protected. Knob:
                       ``mobility_bias`` (epochs of freshness one
                       encounter/epoch is worth; default 8).
``staleness_weighted`` LRU retention + aggregation weights decayed by the
                       entry's age, α_j ∝ n_j·γ^(t-τ). Knob: ``gamma``
                       (default 0.9); see ``aggregate.aggregation_weights``.
``priority``           generic configurable score mix over the metadata
                       struct. Knobs: ``w_ts`` (default 1), ``w_arrival``,
                       ``w_samples``, ``w_encounter`` (all default 0).

Every priority function is ~10 lines over one ``CacheMeta`` struct; the
shared engine in ``repro.policies.base`` does dedup/sort/truncate.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.policies.base import CachePolicy, PolicyContext
from repro.policies.registry import register

if TYPE_CHECKING:  # avoid a repro.core import cycle (core.gossip imports us)
    from repro.core.cache import CacheMeta


def _lru(meta: CacheMeta, ctx: PolicyContext, valid):
    """Alg. 2: retain the freshest-trained copy of each origin."""
    return meta.ts, valid


def _fifo(meta: CacheMeta, ctx: PolicyContext, valid):
    """Retain the most recently *received* entries (vs freshest-trained)."""
    return meta.arrival, valid


def _random(meta: CacheMeta, ctx: PolicyContext, valid):
    """Uniform-random retention after origin-dedup."""
    return jax.random.randint(ctx.rng, meta.origin.shape, 0, 2 ** 30), valid


def _group(meta: CacheMeta, ctx: PolicyContext, valid):
    """Alg. 3: per-group LRU with r_g slots (``ctx.group_slots``)."""
    group_slots = ctx.group_slots
    num_groups = group_slots.shape[0]
    M = meta.origin.shape[0]
    # rank of each entry within its group by ts desc (valid entries only)
    same_g = meta.group[None, :] == meta.group[:, None]
    better = same_g & valid[None, :] & (
        (meta.ts[None, :] > meta.ts[:, None])
        | ((meta.ts[None, :] == meta.ts[:, None])
           & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None])))
    rank = jnp.sum(better, axis=1)
    slots = jnp.where((meta.group >= 0) & (meta.group < num_groups),
                      group_slots[jnp.clip(meta.group, 0, num_groups - 1)], 0)
    return meta.ts, rank < slots


def _mobility_aware(meta: CacheMeta, ctx: PolicyContext, valid):
    """Freshness minus an encounter-rate penalty: evict what you will meet
    again soon, protect models from rarely-encountered origins."""
    bias = ctx.param("mobility_bias", 8.0)
    rate = ctx.encounter_rate(meta.origin)
    return meta.ts.astype(jnp.float32) - bias * rate, valid


def _staleness_weighted(meta: CacheMeta, ctx: PolicyContext, valid):
    """LRU retention; the policy's effect is the γ^age aggregation decay
    (``CachePolicy.staleness_decay``, resolved by the epoch step)."""
    return meta.ts, valid


def _priority(meta: CacheMeta, ctx: PolicyContext, valid):
    """Configurable linear score over the metadata struct."""
    score = (ctx.param("w_ts", 1.0) * meta.ts.astype(jnp.float32)
             + ctx.param("w_arrival", 0.0) * meta.arrival.astype(jnp.float32)
             + ctx.param("w_samples", 0.0) * meta.samples
             - ctx.param("w_encounter", 0.0)
             * ctx.encounter_rate(meta.origin))
    return score, valid


LRU = register(CachePolicy("lru", _lru))
FIFO = register(CachePolicy("fifo", _fifo, paper=False))
RANDOM = register(CachePolicy("random", _random, deterministic=False,
                              needs_rng=True, paper=False))
GROUP = register(CachePolicy("group", _group, needs_group_slots=True))
MOBILITY_AWARE = register(CachePolicy(
    "mobility_aware", _mobility_aware, needs_encounters=True, paper=False,
    knobs=("mobility_bias",)))
STALENESS_WEIGHTED = register(CachePolicy(
    "staleness_weighted", _staleness_weighted, paper=False,
    staleness_decay=0.9))
PRIORITY = register(CachePolicy(
    "priority", _priority, paper=False,
    knobs=("w_ts", "w_arrival", "w_samples", "w_encounter")))
