"""Cache-policy subsystem protocol + the shared dedup/retain engine.

A cache-retention policy (paper §2.2–§3, Algorithms 2 & 3) decides which
candidate models survive into an agent's fixed-capacity cache. Every
policy is a :class:`CachePolicy` whose core is one jit-able **priority
function** over a :class:`repro.core.cache.CacheMeta` struct:

    priority(meta, ctx, valid) -> (key, keep)

``key`` is a per-candidate sort score (higher = retained first; int32 or
float32), ``keep`` an extra boolean mask (all-True for most policies).
The shared :func:`retain` engine does everything else — origin dedup
keeping the freshest copy, masking, stable descending sort, truncation to
capacity, and blanking of empty slots — so a new policy is ~10 lines and
is automatically covered by the conformance suite
(``tests/test_cache_policies.py``).

Policies register themselves by name (``repro.policies.registry``); the
choice is static per trace — the fleet engine compiles one executable per
(algorithm, policy, shape) — while policy randomness stays a traced PRNG
key in :class:`PolicyContext`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # avoid a repro.core import cycle (core.gossip imports us)
    from repro.core.cache import CacheMeta

INT_MIN = jnp.int32(-2 ** 30)


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """Per-agent, per-epoch context handed to a policy's priority function.

    The engine vmaps over agents, so array fields are the *current agent's*
    view: ``rng`` a per-agent PRNG key, ``encounters`` the agent's
    cumulative per-origin encounter counts ``[N]`` (realized cache-exchange
    contacts, optionally warm-started from
    ``mobility.stats.encounter_stats``). ``params`` is the static
    name → float knob mapping from ``DFLConfig.policy_params``.
    """
    t: Any                                     # [] int32 current epoch
    capacity: int
    rng: Optional[jax.Array] = None            # per-agent PRNG key
    group_slots: Optional[jax.Array] = None    # [num_groups] int32
    encounters: Optional[jax.Array] = None     # [N] float32 counts
    params: Dict[str, float] = dataclasses.field(default_factory=dict)
    live: Optional[jax.Array] = None           # [N] bool fleet liveness by
                                               # global agent id (shared
                                               # across agents, like
                                               # group_slots; None = closed
                                               # world / churn off)

    def param(self, name: str, default: float) -> float:
        return float(self.params.get(name, default))

    def origin_live(self, origin: jax.Array) -> jax.Array:
        """Per-candidate bool: is each candidate's origin agent currently
        in coverage? All-True when no liveness mask is threaded (closed
        world) so liveness-aware scores degrade gracefully."""
        if self.live is None:
            return jnp.ones(origin.shape, bool)
        n = self.live.shape[0]
        return jnp.where(origin >= 0,
                         self.live[jnp.clip(origin, 0, n - 1)], True)

    def encounter_rate(self, origin: jax.Array) -> jax.Array:
        """Per-candidate encounter rate of this agent with each origin
        (encounters per elapsed epoch; 0 for empty candidates or when no
        encounter state is threaded)."""
        if self.encounters is None:
            return jnp.zeros(origin.shape, jnp.float32)
        n = self.encounters.shape[0]
        rate = self.encounters / jnp.maximum(
            jnp.asarray(self.t, jnp.float32), 1.0)
        return jnp.where(origin >= 0, rate[jnp.clip(origin, 0, n - 1)], 0.0)


PriorityFn = Callable[["CacheMeta", PolicyContext, jax.Array],
                      Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """A named cache-retention policy (see module docstring).

    ``deterministic`` policies must be candidate-permutation invariant (the
    retained *origin set* does not depend on candidate order) — the
    conformance suite enforces this. ``staleness_decay`` is the
    aggregation-weight decay γ the policy imposes by default (γ=1 = paper
    weighting; see ``repro.core.aggregate``); resolved via
    :func:`effective_staleness_decay`.
    """
    name: str
    priority: PriorityFn
    deterministic: bool = True
    needs_rng: bool = False
    needs_group_slots: bool = False
    needs_encounters: bool = False
    paper: bool = True              # appears in the source paper
    staleness_decay: float = 1.0    # default aggregation decay γ
    knobs: Tuple[str, ...] = ()     # accepted policy_params names ("gamma"
                                    # is accepted by every policy)


def beats_matrix(origin, ts, pref=None):
    """[i, j] = candidate j holds the same origin as i and wins the
    freshest-copy ordering: newer ts, ties broken by higher ``pref`` then
    lower index. The single source of the dedup tie-break — retention
    (:func:`dedup_mask`) and the transfer-budget admission share it, so
    the two stages can never disagree about which copy is "the" copy.
    """
    M = origin.shape[0]
    if pref is None:
        pref = jnp.zeros_like(ts)
    same = origin[None, :] == origin[:, None]          # [i, j]
    newer = ts[None, :] > ts[:, None]
    tie = ts[None, :] == ts[:, None]
    pref_j = (pref[None, :] > pref[:, None]) | (
        (pref[None, :] == pref[:, None])
        & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None]))
    return same & (newer | (tie & pref_j))


def dedup_mask(origin, ts, pref=None):
    """valid[i] = entry i is the best copy of its origin.

    Best = max ts; ties broken by higher ``pref`` then lower index.
    origin < 0 entries are invalid.
    """
    return (origin >= 0) & ~jnp.any(beats_matrix(origin, ts, pref), axis=1)


def validate_context(policy: CachePolicy, ctx: PolicyContext) -> None:
    if policy.needs_rng and ctx.rng is None:
        raise ValueError(f"cache policy {policy.name!r} requires a PRNG key")
    if policy.needs_group_slots and ctx.group_slots is None:
        raise ValueError(
            f"cache policy {policy.name!r} requires group_slots")
    if policy.needs_encounters and ctx.encounters is None:
        raise ValueError(
            f"cache policy {policy.name!r} requires encounter counts "
            "(thread FleetState.encounters through the exchange)")


def retain(meta: "CacheMeta", policy: CachePolicy, ctx: PolicyContext,
           pref=None) -> Tuple[jax.Array, "CacheMeta"]:
    """Run one agent's retention: dedup by origin, score, keep top-capacity.

    Returns ``(sel, meta_sel)`` where ``sel`` [capacity] indexes the
    candidate arrays (stable ordering: score ties break by candidate index,
    earlier = own cache) and ``meta_sel`` is the retained metadata with
    empty slots fully blanked (origin == -1 across every field).
    """
    validate_context(policy, ctx)
    valid = dedup_mask(meta.origin, meta.ts, pref=pref)
    key, keep = policy.priority(meta, ctx, valid)
    valid = valid & keep
    floor = (INT_MIN if jnp.issubdtype(key.dtype, jnp.integer)
             else -jnp.inf)
    key = jnp.where(valid, key, floor)
    order = jnp.argsort(-key, stable=True)
    sel = order[:ctx.capacity]
    return sel, meta.take(sel, valid[sel])


def effective_staleness_decay(policy: CachePolicy, configured: float = 1.0,
                              params: Optional[Dict[str, float]] = None
                              ) -> float:
    """Resolve the aggregation-weight decay γ for a run.

    An explicit ``DFLConfig.staleness_decay`` ≠ 1 wins; otherwise the
    policy-params key ``"gamma"``; otherwise the policy's own default.
    """
    if configured != 1.0:
        return float(configured)
    if params and "gamma" in params:
        return float(params["gamma"])
    return float(policy.staleness_decay)
