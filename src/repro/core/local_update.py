"""LocalUpdate (paper Algorithm 1, lines 1-9): K steps of SGD on the
proximal-regularized local loss

    g_{x(t)}(x; z) = f(x; z) + ρ/2 ‖x − x_i(t)‖²,

vectorized over the fleet with vmap. The loss function is model-specific
and injected, keeping the DFL layer model-agnostic.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def proximal_penalty(params, anchor):
    sq = jax.tree_util.tree_map(
        lambda p, a: jnp.sum(jnp.square(p.astype(jnp.float32)
                                        - a.astype(jnp.float32))),
        params, anchor)
    return sum(jax.tree_util.tree_leaves(sq))


def local_update(params, data, count, key, *, loss_fn: Callable,
                 steps: int, batch_size: int, lr, rho: float = 0.0):
    """Run K proximal-SGD steps for ONE agent.

    data: pytree of arrays [n_max, ...]; count: [] int32 valid rows;
    loss_fn(params, batch) -> scalar. Returns x̃_i(t).
    """
    anchor = params

    def objective(p, batch):
        loss = loss_fn(p, batch)
        if rho:
            loss = loss + 0.5 * rho * proximal_penalty(p, anchor)
        return loss

    def step(carry, k):
        p, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch_size,), 0,
                                 jnp.maximum(count, 1))
        batch = jax.tree_util.tree_map(lambda x: x[idx], data)
        loss, grads = jax.value_and_grad(objective)(p, batch)
        p = jax.tree_util.tree_map(
            lambda w, g: (w.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(w.dtype),
            p, grads)
        return (p, key), loss

    (params, _), losses = jax.lax.scan(step, (params, key),
                                       jnp.arange(steps))
    return params, losses


def fleet_local_update(params, data, counts, keys, *, loss_fn: Callable,
                       steps: int, batch_size: int, lr, rho: float = 0.0):
    """vmapped local update: params leaves [N, ...], data leaves [N, n, ...]."""
    fn = functools.partial(local_update, loss_fn=loss_fn, steps=steps,
                           batch_size=batch_size, lr=lr, rho=rho)
    return jax.vmap(fn)(params, data, counts, keys)
