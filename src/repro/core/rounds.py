"""Cached-DFL round orchestration (paper Algorithm 1 main process) plus the
paper's comparison baselines: DeFedAvg-style DFL (pairwise averaging, no
cache) and Centralized FL (server-side FedAvg).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.aggregate import aggregate
from repro.core.cache import ModelCache, evict_stale, init_cache
from repro.core.local_update import fleet_local_update
from repro.utils.tree import tree_take


@dataclasses.dataclass
class FleetState:
    params: Any            # pytree, leaves [N, ...]
    cache: ModelCache      # leaves [N, C, ...]
    samples: jax.Array     # [N] float32 — n_i
    group: jax.Array       # [N] int32 — distribution group of each agent
    t: jax.Array           # [] int32 — global epoch

jax.tree_util.register_dataclass(
    FleetState, data_fields=["params", "cache", "samples", "group", "t"],
    meta_fields=[])


def init_fleet(template_params, num_agents: int, cache_size: int,
               samples, group=None) -> FleetState:
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(),
        template_params)
    cache = init_cache(
        jax.tree_util.tree_map(lambda x: x[0], params), cache_size)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(), cache)
    if group is None:
        group = jnp.zeros((num_agents,), jnp.int32)
    return FleetState(params=params, cache=cache,
                      samples=jnp.asarray(samples, jnp.float32),
                      group=jnp.asarray(group, jnp.int32),
                      t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Cached-DFL epoch
# ---------------------------------------------------------------------------

def cached_dfl_epoch(state: FleetState, partners, data, counts, key, *,
                     loss_fn: Callable, local_steps: int, batch_size: int,
                     lr, rho: float = 0.0, tau_max: int = 10,
                     policy: str = "lru",
                     group_slots: Optional[jax.Array] = None,
                     staleness_decay: float = 1.0) -> FleetState:
    """One global epoch of Algorithm 1 for the whole fleet.

    partners: [N, D] contact lists for this epoch (-1 padded).
    """
    N = state.samples.shape[0]
    key, k_local, k_policy = jax.random.split(key, 3)
    local_keys = jax.random.split(k_local, N)

    # 1) LocalUpdate: x_i(t) -> x̃_i(t)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)

    # 2) CacheUpdate: DTN-like exchange with encountered agents
    cache = gossip.exchange(
        tilde, state.cache, partners, state.t, state.samples, state.group,
        tau_max=tau_max, policy=policy, group_slots=group_slots,
        rng=k_policy)

    # 3) ModelAggregation over all cached models (+ own)
    new_params = aggregate(tilde, state.samples, cache, t=state.t,
                           staleness_decay=staleness_decay)

    return dataclasses.replace(state, params=new_params, cache=cache,
                               t=state.t + 1), losses


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def dfl_epoch(state: FleetState, partners, data, counts, key, *,
              loss_fn: Callable, local_steps: int, batch_size: int, lr,
              rho: float = 0.0) -> FleetState:
    """DeFedAvg (paper's "DFL" baseline): local update, then pairwise
    sample-weighted averaging with the first contacted partner only."""
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)

    first = partners[:, 0]
    has = first >= 0
    pidx = jnp.clip(first, 0, N - 1)
    n_i = state.samples
    n_j = jnp.where(has, n_i[pidx], 0.0)
    w_i = n_i / (n_i + n_j)

    def leaf(p):
        pj = p[pidx]
        w = w_i.reshape((N,) + (1,) * (p.ndim - 1))
        mixed = w * p.astype(jnp.float32) + (1 - w) * pj.astype(jnp.float32)
        keep = has.reshape((N,) + (1,) * (p.ndim - 1))
        return jnp.where(keep, mixed, p.astype(jnp.float32)).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


def cfl_epoch(state: FleetState, data, counts, key, *, loss_fn: Callable,
              local_steps: int, batch_size: int, lr,
              rho: float = 0.0) -> FleetState:
    """Centralized FL (FedAvg): all agents aggregate on a server each epoch."""
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
    w = state.samples / jnp.sum(state.samples)

    def leaf(p):
        wexp = w.reshape((N,) + (1,) * (p.ndim - 1))
        avg = jnp.sum(wexp * p.astype(jnp.float32), axis=0)
        return jnp.broadcast_to(avg, p.shape).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


# ---------------------------------------------------------------------------
# fleet evaluation
# ---------------------------------------------------------------------------

def fleet_accuracy(state: FleetState, acc_fn: Callable, test_batch) -> jax.Array:
    """Average test metric over all agents' local models (paper's metric)."""
    accs = jax.vmap(lambda p: acc_fn(p, test_batch))(state.params)
    return jnp.mean(accs), accs
