"""Cached-DFL round orchestration (paper Algorithm 1 main process) plus the
paper's comparison baselines: DeFedAvg-style DFL (pairwise averaging, no
cache) and Centralized FL (server-side FedAvg).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.aggregate import aggregate
from repro.core.cache import ModelCache, init_cache
from repro.core.local_update import fleet_local_update
from repro.telemetry import metrics as metrics_lib


@dataclasses.dataclass
class FleetState:
    params: Any            # pytree, leaves [N, ...]
    cache: ModelCache      # leaves [N, C, ...]
    samples: jax.Array     # [N] float32 — n_i
    group: jax.Array       # [N] int32 — distribution group of each agent
    t: jax.Array           # [] int32 — global epoch
    encounters: Any = None # [N, N] float32 — cumulative per-pair exchange
                           # counts (mobility-aware cache policies)
    live: Any = None       # [N] bool — open-world liveness mask (this
                           # epoch's in-coverage agents; all-True when the
                           # churn schedule is off)

jax.tree_util.register_dataclass(
    FleetState,
    data_fields=["params", "cache", "samples", "group", "t", "encounters",
                 "live"],
    meta_fields=[])


def init_fleet(template_params, num_agents: int, cache_size: int,
               samples, group=None) -> FleetState:
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(),
        template_params)
    cache = init_cache(
        jax.tree_util.tree_map(lambda x: x[0], params), cache_size)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(), cache)
    if group is None:
        group = jnp.zeros((num_agents,), jnp.int32)
    return FleetState(params=params, cache=cache,
                      samples=jnp.asarray(samples, jnp.float32),
                      group=jnp.asarray(group, jnp.int32),
                      t=jnp.zeros((), jnp.int32),
                      encounters=jnp.zeros((num_agents, num_agents),
                                           jnp.float32),
                      live=jnp.ones((num_agents,), bool))


def liveness_mask(t, num_agents: int, period: int, fraction: float
                  ) -> jax.Array:
    """[N] bool — which agents are in coverage at epoch ``t``.

    Deterministic staggered round-robin outages: every ``period`` epochs
    agent i spends ``round(fraction * period)`` consecutive epochs away,
    phase-shifted by ``(i * period) // N`` so departures spread uniformly
    over the cycle (≈ a ``fraction`` share of the fleet is away at any
    epoch). Pure int32 arithmetic on the traced ``t`` — no PRNG splits,
    no retrace, and closed-form per agent so every shard of the sharded
    engine can reconstruct the whole fleet's mask locally.
    """
    down = int(round(fraction * period))  # repro: allow=RPR004 static Python args (config floats), never a device value
    phase = (jnp.arange(num_agents, dtype=jnp.int32) * period) // num_agents
    return ((jnp.asarray(t, jnp.int32) + phase) % period) >= down


def _freeze_dead(new_tree, old_tree, live: jax.Array):
    """where(live, new, old) leaf-wise over agent-leading [N, ...] trees."""
    def leaf(new, old):
        keep = live.reshape((live.shape[0],) + (1,) * (new.ndim - 1))
        return jnp.where(keep, new, old)
    return jax.tree_util.tree_map(leaf, new_tree, old_tree)


def count_encounters(encounters, partners):
    """Accumulate this epoch's realized exchange partners into the [N, N]
    per-pair encounter counts (no-op when encounters is None).

    Duplicate partner ids are masked with the same rule the exchange uses
    (``gossip.valid_partner_mask``), so the counts match the realized
    contacts one-for-one."""
    if encounters is None:
        return None
    # columns are global agent ids even when rows are one shard's block
    N = encounters.shape[-1]
    pvalid = gossip.valid_partner_mask(partners)
    hit = (partners[..., None] == jnp.arange(N)) & pvalid[..., None]
    return encounters + jnp.sum(hit, axis=1).astype(encounters.dtype)


# ---------------------------------------------------------------------------
# Cached-DFL epoch
# ---------------------------------------------------------------------------

def cached_dfl_epoch(state: FleetState, partners, data, counts, key, *,
                     loss_fn: Callable, local_steps: int, batch_size: int,
                     lr, rho: float = 0.0, tau_max: int = 10,
                     policy="lru",
                     group_slots: Optional[jax.Array] = None,
                     staleness_decay: float = 1.0,
                     policy_params: Optional[dict] = None,
                     gather_mode: str = "select",
                     durations: Optional[jax.Array] = None,
                     transfer_budget=None,
                     link_entries_per_step: float = 0.0,
                     with_stats: bool = False,
                     churn: bool = False):
    """One global epoch of Algorithm 1 for the whole fleet.

    partners: [N, D] contact lists for this epoch (-1 padded). ``policy``
    is a registered cache-policy name or CachePolicy (static per trace).
    ``durations`` [N, N] (steps in contact, from ``simulate_epoch``) plus
    ``transfer_budget`` / ``link_entries_per_step`` bound how many entries
    each contact can move (see ``gossip.exchange``).

    With ``with_stats`` (static) the exchange also reduces its traffic
    counters and the return becomes ``(state, losses, ExchangeStats)``.

    With ``churn`` (static) ``state.live`` is honored: dead agents skip
    the local update (their models freeze), their caches freeze whole —
    no staleness eviction while out of coverage, so entries age and are
    evicted on rejoin — and they are excluded from aggregation. The
    caller must already have masked ``partners`` so no dead agent appears
    as a realized partner; entries a dead agent previously gossiped keep
    spreading through live carriers untouched (the DTN effect).
    """
    N = state.samples.shape[0]
    key, k_local, k_policy = jax.random.split(key, 3)
    local_keys = jax.random.split(k_local, N)

    # 1) LocalUpdate: x_i(t) -> x̃_i(t)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
    if churn:
        tilde = _freeze_dead(tilde, state.params, state.live)

    # 2) CacheUpdate: DTN-like exchange with encountered agents; the
    # realized partner contacts feed the per-pair encounter counts that
    # mobility-aware policies score against
    encounters = count_encounters(state.encounters, partners)
    out = gossip.exchange(
        tilde, state.cache, partners, state.t, state.samples, state.group,
        tau_max=tau_max, policy=policy, group_slots=group_slots,
        rng=k_policy, encounters=encounters, policy_params=policy_params,
        gather_mode=gather_mode, durations=durations,
        transfer_budget=transfer_budget,
        link_entries_per_step=link_entries_per_step,
        with_stats=with_stats,
        live=state.live if churn else None)
    cache, xstats = out if with_stats else (out, None)
    if churn:
        cache = _freeze_dead(cache, state.cache, state.live)

    # 3) ModelAggregation over all cached models (+ own)
    new_params = aggregate(tilde, state.samples, cache, t=state.t,
                           staleness_decay=staleness_decay)
    if churn:
        new_params = _freeze_dead(new_params, state.params, state.live)

    new_state = dataclasses.replace(state, params=new_params, cache=cache,
                                    t=state.t + 1, encounters=encounters)
    if with_stats:
        return new_state, losses, xstats
    return new_state, losses


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def dfl_epoch(state: FleetState, partners, data, counts, key, *,
              loss_fn: Callable, local_steps: int, batch_size: int, lr,
              rho: float = 0.0, churn: bool = False
              ) -> Tuple[FleetState, jax.Array]:
    """DeFedAvg (paper's "DFL" baseline): local update, then pairwise
    sample-weighted averaging with the first contacted partner only.

    With ``churn`` (static) dead agents (``~state.live``) skip the local
    update; the caller masks ``partners`` so they neither pick nor serve
    as averaging partners — their models freeze until they rejoin.
    """
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
    if churn:
        tilde = _freeze_dead(tilde, state.params, state.live)

    first = partners[:, 0]
    has = first >= 0
    pidx = jnp.clip(first, 0, N - 1)
    n_i = state.samples
    n_j = jnp.where(has, n_i[pidx], 0.0)
    w_i = n_i / (n_i + n_j)

    def leaf(p):
        pj = p[pidx]
        w = w_i.reshape((N,) + (1,) * (p.ndim - 1))
        mixed = w * p.astype(jnp.float32) + (1 - w) * pj.astype(jnp.float32)
        keep = has.reshape((N,) + (1,) * (p.ndim - 1))
        return jnp.where(keep, mixed, p.astype(jnp.float32)).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


def cfl_epoch(state: FleetState, data, counts, key, *, loss_fn: Callable,
              local_steps: int, batch_size: int, lr,
              rho: float = 0.0, churn: bool = False
              ) -> Tuple[FleetState, jax.Array]:
    """Centralized FL (FedAvg): all agents aggregate on a server each epoch.

    With ``churn`` (static) only live agents contribute to (and receive)
    the server average — out-of-coverage agents neither upload nor
    download, so their models freeze until they rejoin.
    """
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
    if churn:
        tilde = _freeze_dead(tilde, state.params, state.live)
        live_w = state.samples * state.live.astype(jnp.float32)
        w = live_w / jnp.maximum(jnp.sum(live_w), 1e-9)
    else:
        w = state.samples / jnp.sum(state.samples)

    def leaf(p):
        wexp = w.reshape((N,) + (1,) * (p.ndim - 1))
        avg = jnp.sum(wexp * p.astype(jnp.float32), axis=0)
        return jnp.broadcast_to(avg, p.shape).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    if churn:
        new_params = _freeze_dead(new_params, state.params, state.live)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


# ---------------------------------------------------------------------------
# uniform epoch step
# ---------------------------------------------------------------------------

def make_epoch_step(algorithm: str, *, loss_fn: Callable, local_steps: int,
                    batch_size: int, rho: float = 0.0, tau_max: int = 10,
                    policy="lru",
                    group_slots: Optional[jax.Array] = None,
                    staleness_decay: float = 1.0,
                    policy_params: Optional[dict] = None,
                    gather_mode: str = "select",
                    transfer_budget=None,
                    link_entries_per_step: float = 0.0,
                    telemetry: bool = False,
                    churn: bool = False) -> Callable:
    """Bind an algorithm's hyperparameters into a uniform per-epoch step

        step(state, partners, durations, data, counts, key, lr,
             transfer_budget=None) -> (state, losses)

    (cfl ignores ``partners``/``durations``; dfl uses partners only). The
    single source of the algorithm dispatch for the legacy jitted loop,
    the fused engine, and the benchmarks — so a new hyperparameter is
    threaded in exactly one place. The cache policy is resolved through
    the registry once here, so the choice is static per trace; policies
    that impose an aggregation staleness decay (e.g.
    ``staleness_weighted``) have their γ resolved here too.

    Transfer budget: ``link_entries_per_step`` and the *default*
    ``transfer_budget`` are bound statically; a per-call
    ``transfer_budget`` (e.g. a traced scalar, so budget sweeps don't
    retrace) overrides the default.

    With ``telemetry`` (static) the step returns ``(state, losses,
    ExchangeStats)`` — real gossip traffic counters for ``cached``,
    zeros for the exchange-free baselines — so the fused engine can fold
    them into its :class:`~repro.telemetry.metrics.FleetMetrics` carry.

    With ``churn`` (static) the epoch honors ``state.live`` (see the
    per-algorithm epoch functions); the caller owns computing the mask
    and masking the contact matrix before partner selection. Off (the
    default) emits the exact pre-churn program — bit-exact.
    """
    common = dict(loss_fn=loss_fn, local_steps=local_steps,
                  batch_size=batch_size, rho=rho, churn=churn)
    if algorithm == "cached":
        from repro.policies import base as policy_base
        from repro.policies import registry as policy_registry
        pol = policy_registry.resolve(policy)
        staleness_decay = policy_base.effective_staleness_decay(
            pol, staleness_decay, policy_params)
        default_budget = transfer_budget

        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            tb = (default_budget if transfer_budget is None
                  else transfer_budget)
            return cached_dfl_epoch(
                state, partners, data, counts, key, lr=lr, tau_max=tau_max,
                policy=pol, group_slots=group_slots,
                staleness_decay=staleness_decay,
                policy_params=policy_params, gather_mode=gather_mode,
                durations=durations, transfer_budget=tb,
                link_entries_per_step=link_entries_per_step,
                with_stats=telemetry,
                **common)
    elif algorithm == "dfl":
        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            out = dfl_epoch(state, partners, data, counts, key, lr=lr,
                            **common)
            return out + (metrics_lib.zero_exchange_stats(),) if telemetry \
                else out
    elif algorithm == "cfl":
        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            out = cfl_epoch(state, data, counts, key, lr=lr, **common)
            return out + (metrics_lib.zero_exchange_stats(),) if telemetry \
                else out
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return step


# ---------------------------------------------------------------------------
# fused fleet-epoch engine
# ---------------------------------------------------------------------------

class FleetEngine:
    """A fused multi-epoch driver: one jit-compiled on-device loop that
    chains mobility → partner selection → a full FL epoch for up to
    ``chunk`` epochs per call.

    ``run(state, mstate, key, lr, data, counts, num_epochs[,
    transfer_budget])`` returns ``(state, mstate, key, losses)`` where
    ``losses`` is the per-epoch mean training loss ``[chunk]`` (NaN past
    ``num_epochs``). ``lr``, ``num_epochs`` and ``transfer_budget`` are
    *traced* scalars: changing any of them between calls never retraces —
    the epoch loop is a ``lax.fori_loop`` with a traced bound, so any
    total epoch budget runs through one compiled executable, partial
    chunks pay for exactly the epochs they run, and a bandwidth-budget
    sweep reuses one executable. ``traces`` counts actual retraces (one
    per (algorithm, shape) by construction).

    With ``donate=True`` the fleet and mobility state buffers are donated to
    XLA, so the ``[N, C, ...]`` cache is updated in place between calls
    instead of doubling peak memory (donation is a no-op on backends that
    don't support aliasing, e.g. CPU).
    """

    def __init__(self, run_fn: Callable, *, chunk: int, donate: bool):
        self.chunk = chunk
        self.donate = donate
        self._traces = 0

        def counted(*args):
            self._traces += 1          # runs at trace time only
            return run_fn(*args)

        self.run = jax.jit(counted,
                           donate_argnums=(0, 1) if donate else ())

    @property
    def traces(self) -> int:
        return self._traces


def make_fleet_engine(*, algorithm: str, mob_model, mob_cfg,
                      epoch_seconds: float, max_partners: int,
                      partner_sample: str = "lowest-id",
                      partners_fn: Optional[Callable] = None,
                      loss_fn: Callable, local_steps: int, batch_size: int,
                      lr_default: float = 0.1, rho: float = 0.0,
                      tau_max: int = 10, policy="lru",
                      group_slots: Optional[jax.Array] = None,
                      staleness_decay: float = 1.0,
                      policy_params: Optional[dict] = None,
                      gather_mode: str = "select",
                      transfer_budget=None,
                      link_entries_per_step: float = 0.0,
                      chunk: int = 1,
                      donate: Optional[bool] = None,
                      telemetry: bool = False,
                      churn_period: int = 0,
                      churn_fraction: float = 0.0) -> FleetEngine:
    """Build the fused epoch engine for one (algorithm, scenario) pair.

    The per-epoch key discipline matches the legacy host loop exactly
    (``split(key, 3)`` for deterministic partner sampling, ``split(key, 4)``
    for random sampling), so a fused run reproduces the legacy trajectory
    from the same seed.

    The per-pair contact durations ride the same scanned mobility state the
    union contacts do — no extra host round-trip — and feed the per-link
    transfer budget (``transfer_budget`` entries/link/epoch, optionally
    passed per ``run`` call as a traced scalar so budget sweeps never
    retrace; ``link_entries_per_step`` converts measured duration to link
    capacity and is static).

    With ``telemetry`` (static per engine) a :class:`FleetMetrics`
    accumulator rides the fori_loop carry: ``run(..., metrics=m)``
    returns ``(state, mstate, key, losses, metrics)``. The accumulation
    only reads state — the key discipline and model trajectory are
    bit-exact with a telemetry-off engine — and a telemetry engine still
    traces once per (algorithm, shape).

    Open-world churn (``churn_period > 0``): each epoch the engine
    computes the :func:`liveness_mask` from the traced ``state.t`` (no
    PRNG, no retrace), masks the contact matrix so dead agents neither
    meet nor are met, and stores the mask on ``state.live`` for the epoch
    step. 0 (default) compiles the exact churn-free program.
    """
    from repro.mobility.base import partners_from_contacts

    if partners_fn is None:
        partners_fn = partners_from_contacts
    if donate is None:
        # CPU XLA can't alias buffers; skip donation to avoid warning spam.
        donate = jax.default_backend() != "cpu"
    churn = churn_period > 0 and round(churn_fraction * churn_period) > 0

    step = make_epoch_step(
        algorithm, loss_fn=loss_fn, local_steps=local_steps,
        batch_size=batch_size, rho=rho, tau_max=tau_max, policy=policy,
        group_slots=group_slots, staleness_decay=staleness_decay,
        policy_params=policy_params, gather_mode=gather_mode,
        transfer_budget=transfer_budget,
        link_entries_per_step=link_entries_per_step,
        telemetry=telemetry, churn=churn)

    def epoch_step(state, mstate, key, lr, data, counts, tb, metrics):
        if partner_sample == "lowest-id":
            key, k1, k2 = jax.random.split(key, 3)
            k3 = None
        else:
            key, k1, k2, k3 = jax.random.split(key, 4)
        mstate, met, dur = mob_model.simulate_epoch(mstate, k1, cfg=mob_cfg,
                                                    seconds=epoch_seconds)
        if churn:
            live = liveness_mask(state.t, state.samples.shape[0],
                                 churn_period, churn_fraction)
            met = met & live[:, None] & live[None, :]
            state = dataclasses.replace(state, live=live)
        partners = partners_fn(met, max_partners, sample=partner_sample,
                               key=k3)
        if telemetry:
            state, losses, xstats = step(state, partners, dur, data, counts,
                                         k2, lr, transfer_budget=tb)
            metrics = metrics_lib.accumulate(metrics, state, partners,
                                             xstats)
        else:
            state, losses = step(state, partners, dur, data, counts, k2, lr,
                                 transfer_budget=tb)
        return state, mstate, key, losses, metrics

    def run_epochs(state, mstate, key, lr, data, counts, num_epochs,
                   transfer_budget=None, metrics=None):
        losses0 = jnp.full((chunk,), jnp.nan, jnp.float32)

        def body(i, carry):
            state, mstate, key, losses, metrics = carry
            state, mstate, key, ep_losses, metrics = epoch_step(
                state, mstate, key, lr, data, counts, transfer_budget,
                metrics)
            losses = jax.lax.dynamic_update_index_in_dim(
                losses, jnp.mean(ep_losses), i, 0)
            return state, mstate, key, losses, metrics

        # clamp to the losses-buffer capacity: epochs past `chunk` would
        # run but pile their losses into the last slot
        out = jax.lax.fori_loop(
            0, jnp.minimum(num_epochs, chunk), body,
            (state, mstate, key, losses0, metrics))
        # telemetry-off: `metrics` is None (an empty pytree) both in and
        # out; drop it so existing 4-tuple callers are untouched
        return out if telemetry else out[:4]

    return FleetEngine(run_epochs, chunk=chunk, donate=donate)


# ---------------------------------------------------------------------------
# sharded fleet-epoch engine (shard_map over the agent axis)
# ---------------------------------------------------------------------------

def _shard_map_fn():
    """shard_map with the version-portable replication-check kwarg."""
    import inspect
    try:
        from jax import shard_map as fn  # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map as fn
    sig = inspect.signature(fn).parameters
    check_kw = ({"check_vma": False} if "check_vma" in sig
                else {"check_rep": False})
    return fn, check_kw


def fleet_state_specs(state, num_agents: int, axis: str):
    """PartitionSpec tree for a FleetState (or any fleet pytree): leaves
    with a leading agent dimension are sharded along ``axis``, scalars
    (``t``) replicated. Delegates to ``sharding.rules.fleet_specs``."""
    from repro.sharding.rules import fleet_specs
    return fleet_specs(state, num_agents, axis)


def make_sharded_fleet_engine(*, mesh, algorithm: str, mob_model, mob_cfg,
                              epoch_seconds: float, max_partners: int,
                              partner_sample: str = "lowest-id",
                              loss_fn: Callable, local_steps: int,
                              batch_size: int, rho: float = 0.0,
                              tau_max: int = 10, policy="lru",
                              group_slots: Optional[jax.Array] = None,
                              staleness_decay: float = 1.0,
                              policy_params: Optional[dict] = None,
                              gather_mode: str = "select",
                              transfer_budget=None,
                              link_entries_per_step: float = 0.0,
                              halo: int = 0,
                              chunk: int = 1,
                              donate: Optional[bool] = None,
                              telemetry: bool = False,
                              churn_period: int = 0,
                              churn_fraction: float = 0.0) -> FleetEngine:
    """Fused engine sharded over the agent axis with ``shard_map``.

    Each of the mesh's devices owns ``n_local = N / ndev`` index-contiguous
    agents: their models, cache rows, data shards, and encounter rows.
    Mobility state is O(N) and *replicated* — every shard steps the full
    fleet's trajectory from the same keys (identical ops ⇒ identical
    states), but only materializes its own ``[n_local, W]`` contact /
    duration block. The dense ``[N, N]`` contact matrix never exists.

    ``halo`` picks the candidate window ``W`` each shard gossips over:

    * ``halo == 0`` — exact mode: ``W = N`` via an ``all_gather`` of every
      shard's fresh models + cache (the window is the whole fleet), so
      partner selection and the exchange see exactly the dense inputs and
      the run is bit-exact with :func:`make_fleet_engine` (same per-agent
      key streams: all fleet-sized key splits happen at global N and are
      row-sliced per shard).
    * ``halo = H > 0`` — block-sparse mode: the window is the shard's own
      rows plus ``H`` boundary rows from each ring neighbour
      (``lax.ppermute``), ``W = n_local + 2H``, and contacts are computed
      against the window's columns only — per-shard contact + gossip work
      drops from O(n_local·N) to O(n_local·W). Contacts outside the
      window are *dropped* (documented approximation): with index-banded
      mobility (grouped runs assign contiguous index blocks to area
      bands) the dropped fraction is near zero, and partner order inside
      the window is deterministic (lowest window row first). Requires
      ``n_local + 2H < N``; otherwise the engine falls back to exact mode.

    ``cfl`` averages via a ``psum`` of per-shard weighted partial sums and
    losses via ``pmean`` — same math as the dense engine up to float
    summation order (documented tolerance). ``partner_sample`` must be
    ``"lowest-id"``: random sampling draws an [N, N] uniform matrix, which
    is exactly the dense-shaped buffer this engine exists to avoid.

    Telemetry accumulates per shard and psum-reduces each epoch's deltas,
    so the replicated counters stay identical across shards while
    ``origins_seen`` rows stay shard-local. Same
    1-trace-per-(algorithm, shape) and donation discipline as the fused
    engine — ``lr``, ``num_epochs`` and ``transfer_budget`` are traced.

    Open-world churn: the :func:`liveness_mask` schedule is a closed form
    over (epoch, global agent id), so each shard reconstructs the whole
    fleet's mask locally — no cross-shard communication. Contact blocks
    are masked by live rows × live window columns, and ``state.live``
    carries the shard's own rows.
    """
    from jax.sharding import PartitionSpec as P

    from repro.mobility.base import partners_from_contacts

    if partner_sample != "lowest-id":
        raise ValueError(
            "engine='sharded' supports partner_sample='lowest-id' only: "
            "'random' ranks contacts with a dense [N, N] uniform draw, "
            "which defeats the block-sparse contact path")
    if mob_model.simulate_epoch_rows is None:
        raise ValueError(
            f"mobility model {mob_model.name!r} has no simulate_epoch_rows; "
            "the sharded engine needs the block-local contact variant")
    if halo < 0:
        raise ValueError(f"shard_halo must be >= 0, got {halo}")
    if donate is None:
        donate = jax.default_backend() != "cpu"

    shard_map_fn, check_kw = _shard_map_fn()
    ndev = int(mesh.devices.size)  # repro: allow=RPR004 static mesh size read once at build time, not a device value
    axis = mesh.axis_names[0]

    if algorithm == "cached":
        from repro.policies import base as policy_base
        from repro.policies import registry as policy_registry
        pol = policy_registry.resolve(policy)
        staleness_decay = policy_base.effective_staleness_decay(
            pol, staleness_decay, policy_params)
    elif algorithm not in ("dfl", "cfl"):
        raise ValueError(f"unknown algorithm {algorithm!r}")
    default_budget = transfer_budget
    churn = churn_period > 0 and round(churn_fraction * churn_period) > 0

    def run_epochs(state, mstate, key, lr, data, counts, num_epochs,
                   transfer_budget=None, metrics=None):
        N = state.samples.shape[0]
        if N % ndev:
            raise ValueError(
                f"dfl.num_agents={N} must divide evenly over the "
                f"{ndev}-device mesh (use --mesh to pick a divisor)")
        n_local = N // ndev
        full_window = halo == 0 or n_local + 2 * halo >= N
        W = N if full_window else n_local + 2 * halo
        tb = default_budget if transfer_budget is None else transfer_budget

        rep = lambda tree: jax.tree_util.tree_map(lambda _: P(), tree)
        st_specs = fleet_state_specs(state, N, axis)
        data_specs = fleet_state_specs(data, N, axis)
        counts_specs = fleet_state_specs(counts, N, axis)
        m_specs = metrics_lib.shard_specs(axis) if metrics is not None \
            else None

        def window_tree(tree):
            """Gather each shard's W-row candidate window (leaf-wise)."""
            if full_window:
                if ndev == 1:
                    return tree
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.all_gather(x, axis, axis=0,
                                                 tiled=True), tree)
            fwd = [(i, (i + 1) % ndev) for i in range(ndev)]
            bwd = [(i, (i - 1) % ndev) for i in range(ndev)]

            def leaf(x):
                left = jax.lax.ppermute(x[-halo:], axis, fwd)
                right = jax.lax.ppermute(x[:halo], axis, bwd)
                return jnp.concatenate([left, x, right], axis=0)

            return jax.tree_util.tree_map(leaf, tree)

        def split_rows(k, row0):
            """split at *global* fleet size, then slice this shard's rows —
            threefry streams depend on the split count, so a local-size
            split would diverge from the dense engine."""
            keys = jax.random.split(k, N)
            return jax.lax.dynamic_slice_in_dim(keys, row0, n_local, axis=0)

        def epoch_body(state, mstate, key, lr, data, counts, tb, metrics):
            dev = jax.lax.axis_index(axis)
            row0 = dev * n_local
            gids = row0 + jnp.arange(n_local, dtype=jnp.int32)
            start = jnp.zeros((), jnp.int32) if full_window \
                else (row0 - halo) % N
            col_ids = (start + jnp.arange(W, dtype=jnp.int32)) % N
            self_rows = (gids - start) % N

            if partner_sample == "lowest-id":
                key, k1, k2 = jax.random.split(key, 3)
            mstate, met, dur = mob_model.simulate_epoch_rows(
                mstate, k1, mob_cfg, epoch_seconds, row_start=row0,
                num_rows=n_local, col_ids=col_ids)
            live_full = None
            if churn:
                # closed-form schedule: every shard rebuilds the global
                # [N] mask locally, then masks its contact block
                live_full = liveness_mask(state.t, N, churn_period,
                                          churn_fraction)
                live_rows = jax.lax.dynamic_slice_in_dim(
                    live_full, row0, n_local)
                live_cols = jnp.take(live_full, col_ids)
                met = met & live_rows[:, None] & live_cols[None, :]
                state = dataclasses.replace(state, live=live_rows)
            partners_w = partners_from_contacts(met, max_partners,
                                                sample=partner_sample)
            partners_g = jnp.where(partners_w >= 0,
                                   (start + partners_w) % N, -1)

            tilde = None
            if algorithm == "cached":
                _, k_local, k_policy = jax.random.split(k2, 3)
                local_keys = split_rows(k_local, row0)
                tilde, losses = fleet_local_update(
                    state.params, data, counts, local_keys, loss_fn=loss_fn,
                    steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
                if churn:
                    tilde = _freeze_dead(tilde, state.params, state.live)
                encounters = count_encounters(state.encounters, partners_g)
                pool = gossip.ExchangePool(
                    params=window_tree(tilde),
                    cache=window_tree(state.cache),
                    samples=window_tree(state.samples),
                    group=window_tree(state.group),
                    ids=col_ids, self_rows=self_rows)
                rng_keys = split_rows(k_policy, row0) if pol.needs_rng \
                    else None
                out = gossip.exchange(
                    tilde, state.cache, partners_w, state.t, state.samples,
                    state.group, tau_max=tau_max, policy=pol,
                    group_slots=group_slots, rng_keys=rng_keys,
                    encounters=encounters, policy_params=policy_params,
                    gather_mode=gather_mode, durations=dur,
                    transfer_budget=tb,
                    link_entries_per_step=link_entries_per_step,
                    with_stats=telemetry, pool=pool, live=live_full)
                cache, xstats = out if telemetry else (out, None)
                if churn:
                    cache = _freeze_dead(cache, state.cache, state.live)
                new_params = aggregate(tilde, state.samples, cache,
                                       t=state.t, staleness_decay=
                                       staleness_decay)
                if churn:
                    new_params = _freeze_dead(new_params, state.params,
                                              state.live)
                state = dataclasses.replace(
                    state, params=new_params, cache=cache, t=state.t + 1,
                    encounters=encounters)
            elif algorithm == "dfl":
                local_keys = split_rows(k2, row0)
                tilde, losses = fleet_local_update(
                    state.params, data, counts, local_keys, loss_fn=loss_fn,
                    steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
                if churn:
                    tilde = _freeze_dead(tilde, state.params, state.live)
                pool_params = window_tree(tilde)
                pool_samples = window_tree(state.samples)
                first = partners_w[:, 0]
                has = first >= 0
                pidx = jnp.clip(first, 0, W - 1)
                n_i = state.samples
                n_j = jnp.where(has, pool_samples[pidx], 0.0)
                w_i = n_i / (n_i + n_j)

                def leaf(p, pool_p):
                    pj = pool_p[pidx]
                    w = w_i.reshape((n_local,) + (1,) * (p.ndim - 1))
                    mixed = (w * p.astype(jnp.float32)
                             + (1 - w) * pj.astype(jnp.float32))
                    keep = has.reshape((n_local,) + (1,) * (p.ndim - 1))
                    return jnp.where(keep, mixed,
                                     p.astype(jnp.float32)).astype(p.dtype)

                new_params = jax.tree_util.tree_map(leaf, tilde, pool_params)
                state = dataclasses.replace(state, params=new_params,
                                            t=state.t + 1)
                xstats = None
            else:  # cfl
                local_keys = split_rows(k2, row0)
                tilde, losses = fleet_local_update(
                    state.params, data, counts, local_keys, loss_fn=loss_fn,
                    steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
                if churn:
                    tilde = _freeze_dead(tilde, state.params, state.live)
                    live_w = state.samples * state.live.astype(jnp.float32)
                    total = jax.lax.psum(jnp.sum(live_w), axis)
                    w = live_w / jnp.maximum(total, 1e-9)
                else:
                    total = jax.lax.psum(jnp.sum(state.samples), axis)
                    w = state.samples / total

                def leaf(p):
                    wexp = w.reshape((n_local,) + (1,) * (p.ndim - 1))
                    part = jnp.sum(wexp * p.astype(jnp.float32), axis=0)
                    avg = jax.lax.psum(part, axis)
                    return jnp.broadcast_to(avg, p.shape).astype(p.dtype)

                new_params = jax.tree_util.tree_map(leaf, tilde)
                if churn:
                    new_params = _freeze_dead(new_params, state.params,
                                              state.live)
                state = dataclasses.replace(state, params=new_params,
                                            t=state.t + 1)
                xstats = None

            loss = jax.lax.pmean(jnp.mean(losses), axis)
            if telemetry:
                new_m = metrics_lib.accumulate(metrics, state, partners_g,
                                               xstats)

                def fold(old, new):
                    # replicated counters: add the psum of per-shard deltas
                    return old + jax.lax.psum(new - old, axis)

                metrics = metrics_lib.FleetMetrics(
                    epochs=new_m.epochs,              # +1, already global
                    staleness_hist=fold(metrics.staleness_hist,
                                        new_m.staleness_hist),
                    origins_seen=new_m.origins_seen,  # row-local latch
                    offered=fold(metrics.offered, new_m.offered),
                    admitted=fold(metrics.admitted, new_m.admitted),
                    admitted_capped=fold(metrics.admitted_capped,
                                         new_m.admitted_capped),
                    link_capacity=fold(metrics.link_capacity,
                                       new_m.link_capacity),
                    capped_links=fold(metrics.capped_links,
                                      new_m.capped_links),
                    contacts=fold(metrics.contacts, new_m.contacts))
            return state, mstate, key, loss, metrics

        def sharded_body(state, mstate, key, lr, data, counts, num_epochs,
                         tb, metrics):
            losses0 = jnp.full((chunk,), jnp.nan, jnp.float32)

            def body(i, carry):
                state, mstate, key, losses, metrics = carry
                state, mstate, key, loss, metrics = epoch_body(
                    state, mstate, key, lr, data, counts, tb, metrics)
                losses = jax.lax.dynamic_update_index_in_dim(
                    losses, loss, i, 0)
                return state, mstate, key, losses, metrics

            out = jax.lax.fori_loop(
                0, jnp.minimum(num_epochs, chunk), body,
                (state, mstate, key, losses0, metrics))
            return out if telemetry else out[:4]

        in_specs = (st_specs, rep(mstate), P(), P(), data_specs,
                    counts_specs, P(), rep(tb), m_specs)
        out_specs = (st_specs, rep(mstate), P(), P())
        if telemetry:
            out_specs = out_specs + (m_specs,)
        fn = shard_map_fn(sharded_body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **check_kw)
        return fn(state, mstate, key, lr, data, counts, num_epochs, tb,
                  metrics)

    return FleetEngine(run_epochs, chunk=chunk, donate=donate)


# ---------------------------------------------------------------------------
# fleet evaluation
# ---------------------------------------------------------------------------

def fleet_accuracy(state: FleetState, acc_fn: Callable, test_batch) -> jax.Array:
    """Average test metric over all agents' local models (paper's metric)."""
    accs = jax.vmap(lambda p: acc_fn(p, test_batch))(state.params)
    return jnp.mean(accs), accs


def fleet_eval(state: FleetState, acc_fn: Callable, test_batch,
               live_only: bool = False):
    """On-device fleet evaluation: (mean_acc, cache_num, cache_age) scalars.

    Cache occupancy / staleness stats are reduced inside the jitted eval so
    only three scalars cross the host boundary — the legacy path pulled the
    full [N, C] metadata to host every eval.

    With ``live_only`` (static — churn runs only, so churn-free evals stay
    bit-exact) the mean accuracy and cache stats average over the agents
    in coverage this epoch (``state.live``): out-of-coverage agents'
    frozen models shouldn't drag the fleet metric.
    """
    if live_only:
        lf = state.live.astype(jnp.float32)
        _, accs = fleet_accuracy(state, acc_fn, test_batch)
        n_live = jnp.maximum(jnp.sum(lf), 1.0)
        acc = jnp.sum(accs * lf) / n_live
        vf = state.cache.valid.astype(jnp.float32) * lf[:, None]
        ages = (state.t - state.cache.ts).astype(jnp.float32)
        cache_num = jnp.sum(vf) / n_live
        cache_age = jnp.sum(ages * vf) / jnp.maximum(jnp.sum(vf), 1.0)
        return acc, cache_num, cache_age
    acc, _ = fleet_accuracy(state, acc_fn, test_batch)
    vf = state.cache.valid.astype(jnp.float32)
    ages = (state.t - state.cache.ts).astype(jnp.float32)
    cache_num = jnp.mean(jnp.sum(vf, axis=1))
    cache_age = jnp.sum(ages * vf) / jnp.maximum(jnp.sum(vf), 1.0)
    return acc, cache_num, cache_age


def fleet_dispersion(state: FleetState, acc_fn: Callable, test_batch):
    """Per-agent accuracy dispersion: ``(acc_std, acc_min, acc_max)``.

    Deliberately a separate jit unit from :func:`fleet_eval`: folding the
    dispersion reductions into the eval trace changes XLA's fusion choices
    and can shift the reported mean accuracy by an ULP, which would break
    the telemetry-on == telemetry-off bit-exactness guarantee.
    """
    _, accs = fleet_accuracy(state, acc_fn, test_batch)
    return jnp.std(accs), jnp.min(accs), jnp.max(accs)
