"""Cached-DFL round orchestration (paper Algorithm 1 main process) plus the
paper's comparison baselines: DeFedAvg-style DFL (pairwise averaging, no
cache) and Centralized FL (server-side FedAvg).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.aggregate import aggregate
from repro.core.cache import ModelCache, evict_stale, init_cache
from repro.core.local_update import fleet_local_update
from repro.telemetry import metrics as metrics_lib
from repro.utils.tree import tree_take


@dataclasses.dataclass
class FleetState:
    params: Any            # pytree, leaves [N, ...]
    cache: ModelCache      # leaves [N, C, ...]
    samples: jax.Array     # [N] float32 — n_i
    group: jax.Array       # [N] int32 — distribution group of each agent
    t: jax.Array           # [] int32 — global epoch
    encounters: Any = None # [N, N] float32 — cumulative per-pair exchange
                           # counts (mobility-aware cache policies)

jax.tree_util.register_dataclass(
    FleetState,
    data_fields=["params", "cache", "samples", "group", "t", "encounters"],
    meta_fields=[])


def init_fleet(template_params, num_agents: int, cache_size: int,
               samples, group=None) -> FleetState:
    params = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(),
        template_params)
    cache = init_cache(
        jax.tree_util.tree_map(lambda x: x[0], params), cache_size)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (num_agents,) + x.shape).copy(), cache)
    if group is None:
        group = jnp.zeros((num_agents,), jnp.int32)
    return FleetState(params=params, cache=cache,
                      samples=jnp.asarray(samples, jnp.float32),
                      group=jnp.asarray(group, jnp.int32),
                      t=jnp.zeros((), jnp.int32),
                      encounters=jnp.zeros((num_agents, num_agents),
                                           jnp.float32))


def count_encounters(encounters, partners):
    """Accumulate this epoch's realized exchange partners into the [N, N]
    per-pair encounter counts (no-op when encounters is None).

    Duplicate partner ids are masked with the same rule the exchange uses
    (``gossip.valid_partner_mask``), so the counts match the realized
    contacts one-for-one."""
    if encounters is None:
        return None
    N = encounters.shape[0]
    pvalid = gossip.valid_partner_mask(partners)
    hit = (partners[..., None] == jnp.arange(N)) & pvalid[..., None]
    return encounters + jnp.sum(hit, axis=1).astype(encounters.dtype)


# ---------------------------------------------------------------------------
# Cached-DFL epoch
# ---------------------------------------------------------------------------

def cached_dfl_epoch(state: FleetState, partners, data, counts, key, *,
                     loss_fn: Callable, local_steps: int, batch_size: int,
                     lr, rho: float = 0.0, tau_max: int = 10,
                     policy="lru",
                     group_slots: Optional[jax.Array] = None,
                     staleness_decay: float = 1.0,
                     policy_params: Optional[dict] = None,
                     gather_mode: str = "select",
                     durations: Optional[jax.Array] = None,
                     transfer_budget=None,
                     link_entries_per_step: float = 0.0,
                     with_stats: bool = False):
    """One global epoch of Algorithm 1 for the whole fleet.

    partners: [N, D] contact lists for this epoch (-1 padded). ``policy``
    is a registered cache-policy name or CachePolicy (static per trace).
    ``durations`` [N, N] (steps in contact, from ``simulate_epoch``) plus
    ``transfer_budget`` / ``link_entries_per_step`` bound how many entries
    each contact can move (see ``gossip.exchange``).

    With ``with_stats`` (static) the exchange also reduces its traffic
    counters and the return becomes ``(state, losses, ExchangeStats)``.
    """
    N = state.samples.shape[0]
    key, k_local, k_policy = jax.random.split(key, 3)
    local_keys = jax.random.split(k_local, N)

    # 1) LocalUpdate: x_i(t) -> x̃_i(t)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)

    # 2) CacheUpdate: DTN-like exchange with encountered agents; the
    # realized partner contacts feed the per-pair encounter counts that
    # mobility-aware policies score against
    encounters = count_encounters(state.encounters, partners)
    out = gossip.exchange(
        tilde, state.cache, partners, state.t, state.samples, state.group,
        tau_max=tau_max, policy=policy, group_slots=group_slots,
        rng=k_policy, encounters=encounters, policy_params=policy_params,
        gather_mode=gather_mode, durations=durations,
        transfer_budget=transfer_budget,
        link_entries_per_step=link_entries_per_step,
        with_stats=with_stats)
    cache, xstats = out if with_stats else (out, None)

    # 3) ModelAggregation over all cached models (+ own)
    new_params = aggregate(tilde, state.samples, cache, t=state.t,
                           staleness_decay=staleness_decay)

    new_state = dataclasses.replace(state, params=new_params, cache=cache,
                                    t=state.t + 1, encounters=encounters)
    if with_stats:
        return new_state, losses, xstats
    return new_state, losses


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def dfl_epoch(state: FleetState, partners, data, counts, key, *,
              loss_fn: Callable, local_steps: int, batch_size: int, lr,
              rho: float = 0.0) -> Tuple[FleetState, jax.Array]:
    """DeFedAvg (paper's "DFL" baseline): local update, then pairwise
    sample-weighted averaging with the first contacted partner only."""
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)

    first = partners[:, 0]
    has = first >= 0
    pidx = jnp.clip(first, 0, N - 1)
    n_i = state.samples
    n_j = jnp.where(has, n_i[pidx], 0.0)
    w_i = n_i / (n_i + n_j)

    def leaf(p):
        pj = p[pidx]
        w = w_i.reshape((N,) + (1,) * (p.ndim - 1))
        mixed = w * p.astype(jnp.float32) + (1 - w) * pj.astype(jnp.float32)
        keep = has.reshape((N,) + (1,) * (p.ndim - 1))
        return jnp.where(keep, mixed, p.astype(jnp.float32)).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


def cfl_epoch(state: FleetState, data, counts, key, *, loss_fn: Callable,
              local_steps: int, batch_size: int, lr,
              rho: float = 0.0) -> Tuple[FleetState, jax.Array]:
    """Centralized FL (FedAvg): all agents aggregate on a server each epoch."""
    N = state.samples.shape[0]
    local_keys = jax.random.split(key, N)
    tilde, losses = fleet_local_update(
        state.params, data, counts, local_keys, loss_fn=loss_fn,
        steps=local_steps, batch_size=batch_size, lr=lr, rho=rho)
    w = state.samples / jnp.sum(state.samples)

    def leaf(p):
        wexp = w.reshape((N,) + (1,) * (p.ndim - 1))
        avg = jnp.sum(wexp * p.astype(jnp.float32), axis=0)
        return jnp.broadcast_to(avg, p.shape).astype(p.dtype)

    new_params = jax.tree_util.tree_map(leaf, tilde)
    return dataclasses.replace(state, params=new_params, t=state.t + 1), losses


# ---------------------------------------------------------------------------
# uniform epoch step
# ---------------------------------------------------------------------------

def make_epoch_step(algorithm: str, *, loss_fn: Callable, local_steps: int,
                    batch_size: int, rho: float = 0.0, tau_max: int = 10,
                    policy="lru",
                    group_slots: Optional[jax.Array] = None,
                    staleness_decay: float = 1.0,
                    policy_params: Optional[dict] = None,
                    gather_mode: str = "select",
                    transfer_budget=None,
                    link_entries_per_step: float = 0.0,
                    telemetry: bool = False) -> Callable:
    """Bind an algorithm's hyperparameters into a uniform per-epoch step

        step(state, partners, durations, data, counts, key, lr,
             transfer_budget=None) -> (state, losses)

    (cfl ignores ``partners``/``durations``; dfl uses partners only). The
    single source of the algorithm dispatch for the legacy jitted loop,
    the fused engine, and the benchmarks — so a new hyperparameter is
    threaded in exactly one place. The cache policy is resolved through
    the registry once here, so the choice is static per trace; policies
    that impose an aggregation staleness decay (e.g.
    ``staleness_weighted``) have their γ resolved here too.

    Transfer budget: ``link_entries_per_step`` and the *default*
    ``transfer_budget`` are bound statically; a per-call
    ``transfer_budget`` (e.g. a traced scalar, so budget sweeps don't
    retrace) overrides the default.

    With ``telemetry`` (static) the step returns ``(state, losses,
    ExchangeStats)`` — real gossip traffic counters for ``cached``,
    zeros for the exchange-free baselines — so the fused engine can fold
    them into its :class:`~repro.telemetry.metrics.FleetMetrics` carry.
    """
    common = dict(loss_fn=loss_fn, local_steps=local_steps,
                  batch_size=batch_size, rho=rho)
    if algorithm == "cached":
        from repro.policies import base as policy_base
        from repro.policies import registry as policy_registry
        pol = policy_registry.resolve(policy)
        staleness_decay = policy_base.effective_staleness_decay(
            pol, staleness_decay, policy_params)
        default_budget = transfer_budget

        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            tb = (default_budget if transfer_budget is None
                  else transfer_budget)
            return cached_dfl_epoch(
                state, partners, data, counts, key, lr=lr, tau_max=tau_max,
                policy=pol, group_slots=group_slots,
                staleness_decay=staleness_decay,
                policy_params=policy_params, gather_mode=gather_mode,
                durations=durations, transfer_budget=tb,
                link_entries_per_step=link_entries_per_step,
                with_stats=telemetry,
                **common)
    elif algorithm == "dfl":
        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            out = dfl_epoch(state, partners, data, counts, key, lr=lr,
                            **common)
            return out + (metrics_lib.zero_exchange_stats(),) if telemetry \
                else out
    elif algorithm == "cfl":
        def step(state, partners, durations, data, counts, key, lr,
                 transfer_budget=None):
            out = cfl_epoch(state, data, counts, key, lr=lr, **common)
            return out + (metrics_lib.zero_exchange_stats(),) if telemetry \
                else out
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return step


# ---------------------------------------------------------------------------
# fused fleet-epoch engine
# ---------------------------------------------------------------------------

class FleetEngine:
    """A fused multi-epoch driver: one jit-compiled on-device loop that
    chains mobility → partner selection → a full FL epoch for up to
    ``chunk`` epochs per call.

    ``run(state, mstate, key, lr, data, counts, num_epochs[,
    transfer_budget])`` returns ``(state, mstate, key, losses)`` where
    ``losses`` is the per-epoch mean training loss ``[chunk]`` (NaN past
    ``num_epochs``). ``lr``, ``num_epochs`` and ``transfer_budget`` are
    *traced* scalars: changing any of them between calls never retraces —
    the epoch loop is a ``lax.fori_loop`` with a traced bound, so any
    total epoch budget runs through one compiled executable, partial
    chunks pay for exactly the epochs they run, and a bandwidth-budget
    sweep reuses one executable. ``traces`` counts actual retraces (one
    per (algorithm, shape) by construction).

    With ``donate=True`` the fleet and mobility state buffers are donated to
    XLA, so the ``[N, C, ...]`` cache is updated in place between calls
    instead of doubling peak memory (donation is a no-op on backends that
    don't support aliasing, e.g. CPU).
    """

    def __init__(self, run_fn: Callable, *, chunk: int, donate: bool):
        self.chunk = chunk
        self.donate = donate
        self._traces = 0

        def counted(*args):
            self._traces += 1          # runs at trace time only
            return run_fn(*args)

        self.run = jax.jit(counted,
                           donate_argnums=(0, 1) if donate else ())

    @property
    def traces(self) -> int:
        return self._traces


def make_fleet_engine(*, algorithm: str, mob_model, mob_cfg,
                      epoch_seconds: float, max_partners: int,
                      partner_sample: str = "lowest-id",
                      partners_fn: Optional[Callable] = None,
                      loss_fn: Callable, local_steps: int, batch_size: int,
                      lr_default: float = 0.1, rho: float = 0.0,
                      tau_max: int = 10, policy="lru",
                      group_slots: Optional[jax.Array] = None,
                      staleness_decay: float = 1.0,
                      policy_params: Optional[dict] = None,
                      gather_mode: str = "select",
                      transfer_budget=None,
                      link_entries_per_step: float = 0.0,
                      chunk: int = 1,
                      donate: Optional[bool] = None,
                      telemetry: bool = False) -> FleetEngine:
    """Build the fused epoch engine for one (algorithm, scenario) pair.

    The per-epoch key discipline matches the legacy host loop exactly
    (``split(key, 3)`` for deterministic partner sampling, ``split(key, 4)``
    for random sampling), so a fused run reproduces the legacy trajectory
    from the same seed.

    The per-pair contact durations ride the same scanned mobility state the
    union contacts do — no extra host round-trip — and feed the per-link
    transfer budget (``transfer_budget`` entries/link/epoch, optionally
    passed per ``run`` call as a traced scalar so budget sweeps never
    retrace; ``link_entries_per_step`` converts measured duration to link
    capacity and is static).

    With ``telemetry`` (static per engine) a :class:`FleetMetrics`
    accumulator rides the fori_loop carry: ``run(..., metrics=m)``
    returns ``(state, mstate, key, losses, metrics)``. The accumulation
    only reads state — the key discipline and model trajectory are
    bit-exact with a telemetry-off engine — and a telemetry engine still
    traces once per (algorithm, shape).
    """
    from repro.mobility.base import partners_from_contacts

    if partners_fn is None:
        partners_fn = partners_from_contacts
    if donate is None:
        # CPU XLA can't alias buffers; skip donation to avoid warning spam.
        donate = jax.default_backend() != "cpu"

    step = make_epoch_step(
        algorithm, loss_fn=loss_fn, local_steps=local_steps,
        batch_size=batch_size, rho=rho, tau_max=tau_max, policy=policy,
        group_slots=group_slots, staleness_decay=staleness_decay,
        policy_params=policy_params, gather_mode=gather_mode,
        transfer_budget=transfer_budget,
        link_entries_per_step=link_entries_per_step,
        telemetry=telemetry)

    def epoch_step(state, mstate, key, lr, data, counts, tb, metrics):
        if partner_sample == "lowest-id":
            key, k1, k2 = jax.random.split(key, 3)
            k3 = None
        else:
            key, k1, k2, k3 = jax.random.split(key, 4)
        mstate, met, dur = mob_model.simulate_epoch(mstate, k1, cfg=mob_cfg,
                                                    seconds=epoch_seconds)
        partners = partners_fn(met, max_partners, sample=partner_sample,
                               key=k3)
        if telemetry:
            state, losses, xstats = step(state, partners, dur, data, counts,
                                         k2, lr, transfer_budget=tb)
            metrics = metrics_lib.accumulate(metrics, state, partners,
                                             xstats)
        else:
            state, losses = step(state, partners, dur, data, counts, k2, lr,
                                 transfer_budget=tb)
        return state, mstate, key, losses, metrics

    def run_epochs(state, mstate, key, lr, data, counts, num_epochs,
                   transfer_budget=None, metrics=None):
        losses0 = jnp.full((chunk,), jnp.nan, jnp.float32)

        def body(i, carry):
            state, mstate, key, losses, metrics = carry
            state, mstate, key, ep_losses, metrics = epoch_step(
                state, mstate, key, lr, data, counts, transfer_budget,
                metrics)
            losses = jax.lax.dynamic_update_index_in_dim(
                losses, jnp.mean(ep_losses), i, 0)
            return state, mstate, key, losses, metrics

        # clamp to the losses-buffer capacity: epochs past `chunk` would
        # run but pile their losses into the last slot
        out = jax.lax.fori_loop(
            0, jnp.minimum(num_epochs, chunk), body,
            (state, mstate, key, losses0, metrics))
        # telemetry-off: `metrics` is None (an empty pytree) both in and
        # out; drop it so existing 4-tuple callers are untouched
        return out if telemetry else out[:4]

    return FleetEngine(run_epochs, chunk=chunk, donate=donate)


# ---------------------------------------------------------------------------
# fleet evaluation
# ---------------------------------------------------------------------------

def fleet_accuracy(state: FleetState, acc_fn: Callable, test_batch) -> jax.Array:
    """Average test metric over all agents' local models (paper's metric)."""
    accs = jax.vmap(lambda p: acc_fn(p, test_batch))(state.params)
    return jnp.mean(accs), accs


def fleet_eval(state: FleetState, acc_fn: Callable, test_batch):
    """On-device fleet evaluation: (mean_acc, cache_num, cache_age) scalars.

    Cache occupancy / staleness stats are reduced inside the jitted eval so
    only three scalars cross the host boundary — the legacy path pulled the
    full [N, C] metadata to host every eval.
    """
    acc, _ = fleet_accuracy(state, acc_fn, test_batch)
    vf = state.cache.valid.astype(jnp.float32)
    ages = (state.t - state.cache.ts).astype(jnp.float32)
    cache_num = jnp.mean(jnp.sum(vf, axis=1))
    cache_age = jnp.sum(ages * vf) / jnp.maximum(jnp.sum(vf), 1.0)
    return acc, cache_num, cache_age


def fleet_dispersion(state: FleetState, acc_fn: Callable, test_batch):
    """Per-agent accuracy dispersion: ``(acc_std, acc_min, acc_max)``.

    Deliberately a separate jit unit from :func:`fleet_eval`: folding the
    dispersion reductions into the eval trace changes XLA's fusion choices
    and can shift the reported mean accuracy by an ULP, which would break
    the telemetry-on == telemetry-off bit-exactness guarantee.
    """
    _, accs = fleet_accuracy(state, acc_fn, test_batch)
    return jnp.std(accs), jnp.min(accs), jnp.max(accs)
