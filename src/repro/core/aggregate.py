"""Model aggregation over cached models (paper Algorithm 1, lines 10-13).

x_i(t+1) = Σ_{j ∈ C_i(t) ∪ {i}} α_j x̃_j(τ),  α_j = n_j / Σ n_j.

Two execution paths:
  * pytree path — leafwise einsum over the stacked cache axis (fleet sim);
  * flat/Pallas path — the model flattened to one vector, reduced by the
    ``cache_aggregate`` TPU kernel (pod-scale deployment hot spot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cache import ModelCache


def aggregation_weights(own_samples, cache_samples, valid,
                        include_self: bool = True, ages=None,
                        staleness_decay: float = 1.0):
    """α weights: [w_self, w_cache...] normalized over valid entries.

    staleness_decay < 1 is a beyond-paper extension (after asynchronous-FL
    mixing, Xie et al. 2019): a cached model aged `a` epochs contributes
    n_j · γ^a, damping the staleness error term in Theorem 4 at the cost
    of less information from far-away agents. γ=1 recovers the paper.
    """
    w_cache = cache_samples * valid
    if ages is not None and staleness_decay != 1.0:
        w_cache = w_cache * jnp.power(
            jnp.float32(staleness_decay),
            jnp.maximum(ages, 0).astype(jnp.float32))
    w_self = jnp.asarray(own_samples, jnp.float32) * (1.0 if include_self else 0.0)
    total = w_self + jnp.sum(w_cache, axis=-1)
    total = jnp.maximum(total, 1e-12)
    return w_self / total, w_cache / total[..., None]


def aggregate(params, own_samples, cache: ModelCache, *,
              include_self: bool = True, t=None,
              staleness_decay: float = 1.0):
    """Weighted average of own model + cached models.

    Fleet-vectorized: params leaves [N, ...], cache leaves [N, C, ...] —
    or single-agent: params [...], cache [C, ...].
    """
    ages = None if t is None else (t - cache.ts)
    w_self, w_cache = aggregation_weights(
        own_samples, cache.samples, cache.valid.astype(jnp.float32),
        include_self, ages=ages, staleness_decay=staleness_decay)

    def leaf(p, m):
        nb = w_cache.ndim - 1  # 0 for single agent, 1 for fleet
        wexp = w_cache.reshape(w_cache.shape + (1,) * (m.ndim - nb - 1))
        contrib = jnp.sum(wexp * m.astype(jnp.float32), axis=nb)
        ws = w_self.reshape(w_self.shape + (1,) * (p.ndim - nb))
        return (ws * p.astype(jnp.float32) + contrib).astype(p.dtype)

    return jax.tree_util.tree_map(leaf, params, cache.models)


def aggregate_flat(flat_params, flat_cache, own_samples, cache_samples,
                   valid, *, use_kernel: bool = True,
                   include_self: bool = True, ages=None,
                   staleness_decay: float = 1.0):
    """Flat-vector aggregation: flat_params [D], flat_cache [C, D].

    The pod-scale path; `use_kernel` routes through the Pallas kernel.
    ``ages``/``staleness_decay`` apply the γ^age weight decay (e.g. the
    ``staleness_weighted`` policy) inside the kernel path's weights.
    """
    w_self, w_cache = aggregation_weights(own_samples, cache_samples,
                                          valid.astype(jnp.float32),
                                          include_self, ages=ages,
                                          staleness_decay=staleness_decay)
    if use_kernel:
        from repro.kernels import ops as kops
        acc = kops.cache_aggregate(flat_cache, w_cache,
                                   valid.astype(jnp.float32))
    else:
        from repro.kernels import ref as kref
        acc = kref.cache_aggregate_ref(flat_cache, w_cache,
                                       valid.astype(jnp.float32))
    return (w_self * flat_params.astype(jnp.float32) + acc).astype(
        flat_params.dtype)


def aggregate_flat_gathered(flat_params, src, sel, own_samples,
                            cand_samples, valid, *, use_kernel: bool = True,
                            include_self: bool = True, ages=None,
                            staleness_decay: float = 1.0):
    """Single-pass gather + aggregate over a candidate pool.

    flat_params: [D] own model; src: [M, D] candidate pool (cache rows +
    fresh models as produced by the gossip metadata phase); sel: [C] int32
    winning rows; cand_samples/valid: [C] per-winner weights/mask;
    ages: optional [C] per-winner staleness for the γ^age weight decay.

    Fuses gossip phase 2 with ModelAggregation: the winners are streamed
    from ``src`` directly into the weighted reduction (Pallas kernel when
    ``use_kernel``), so the gathered [C, D] cache copy never round-trips
    through HBM between CacheUpdate and ModelAggregation.
    """
    w_self, w_cache = aggregation_weights(own_samples, cand_samples,
                                          valid.astype(jnp.float32),
                                          include_self, ages=ages,
                                          staleness_decay=staleness_decay)
    w = w_cache * valid.astype(jnp.float32)
    if use_kernel:
        from repro.kernels import ops as kops
        acc = kops.gather_cache_aggregate(src, sel, w)
    else:
        from repro.kernels import ref as kref
        acc = kref.gather_cache_aggregate_ref(src, sel, w)
    return (w_self * flat_params.astype(jnp.float32) + acc).astype(
        flat_params.dtype)
