"""The Cached-DFL model cache (paper §2.2, Algorithms 2 & 3).

TPU adaptation: instead of PyTorch dicts of ``state_dict``s, the cache is a
fixed-capacity *stacked pytree* — every leaf of the model gets a leading
``[C]`` axis — plus a :class:`CacheMeta` bundle of flat metadata arrays.
All updates (staleness eviction, dedup/retention, policy scoring) are
``jax.lax`` ops over the metadata, so an entire fleet's cache maintenance
jits into one program and never leaves the device.

Retention policies live in ``repro.policies`` (registry-driven; see
``repro.policies.registry.available()``). This module keeps the cache
containers, staleness eviction, and the single-insert path; the legacy
``select_*`` helpers are kept as thin shims over the policy engine.

Metadata per slot (see :class:`CacheMeta`):
    ts      int32  epoch at which the cached model finished local training
                   (the paper's τ);  -1 = empty slot
    origin  int32  agent the model was trained on; -1 = empty
    samples float32 n_j (local dataset size) for aggregation weights
    group   int32  origin agent's distribution group (Algorithm 3)
    arrival int32  epoch the entry was received (fifo policy)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_take

NEG = jnp.int32(-1)


@dataclasses.dataclass
class CacheMeta:
    """The per-entry metadata bundle, as one struct.

    Replaces the ``(origin, ts, samples, group, arrival)`` positional
    plumbing between the gossip candidate phase, the policy engine and the
    cache container. Leaves share a common leading shape — ``[M]`` for a
    candidate set, ``[C]`` for one agent's cache, ``[N, C]`` for a fleet.
    """
    ts: jax.Array        # int32
    origin: jax.Array    # int32
    samples: jax.Array   # float32
    group: jax.Array     # int32
    arrival: jax.Array   # int32

    @property
    def valid(self) -> jax.Array:
        return self.origin >= 0

    def take(self, sel, sel_valid) -> "CacheMeta":
        """Gather entries ``sel``, blanking every field where ``sel_valid``
        is False (empty slots carry origin == -1 across *all* metadata)."""
        return CacheMeta(
            ts=jnp.where(sel_valid, self.ts[sel], NEG),
            origin=jnp.where(sel_valid, self.origin[sel], NEG),
            samples=jnp.where(sel_valid, self.samples[sel], 0.0),
            group=jnp.where(sel_valid, self.group[sel], NEG),
            arrival=jnp.where(sel_valid, self.arrival[sel], NEG))

    def as_dict(self) -> Dict[str, jax.Array]:
        return {"ts": self.ts, "origin": self.origin,
                "samples": self.samples, "group": self.group,
                "arrival": self.arrival}


jax.tree_util.register_dataclass(
    CacheMeta,
    data_fields=["ts", "origin", "samples", "group", "arrival"],
    meta_fields=[])


@dataclasses.dataclass
class ModelCache:
    models: Any          # pytree, leaves [C, ...]
    ts: jax.Array        # [C] int32
    origin: jax.Array    # [C] int32
    samples: jax.Array   # [C] float32
    group: jax.Array     # [C] int32
    arrival: jax.Array   # [C] int32

    @property
    def capacity(self) -> int:
        return self.ts.shape[0]

    @property
    def valid(self) -> jax.Array:
        return self.origin >= 0

    @property
    def meta(self) -> CacheMeta:
        return CacheMeta(ts=self.ts, origin=self.origin,
                         samples=self.samples, group=self.group,
                         arrival=self.arrival)

jax.tree_util.register_dataclass(
    ModelCache,
    data_fields=["models", "ts", "origin", "samples", "group", "arrival"],
    meta_fields=[])


def init_cache(template_params, capacity: int) -> ModelCache:
    models = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + x.shape, x.dtype), template_params)
    z = jnp.full((capacity,), NEG)
    return ModelCache(models=models, ts=z, origin=z,
                      samples=jnp.zeros((capacity,), jnp.float32),
                      group=z, arrival=z)


def evict_stale(cache: ModelCache, t, tau_max) -> ModelCache:
    """Remove entries with staleness t - τ >= τ_max (Alg. 2 lines 1-5)."""
    keep = cache.valid & ((t - cache.ts) < tau_max)
    return dataclasses.replace(
        cache,
        ts=jnp.where(keep, cache.ts, NEG),
        origin=jnp.where(keep, cache.origin, NEG),
        samples=jnp.where(keep, cache.samples, 0.0),
        group=jnp.where(keep, cache.group, NEG),
        arrival=jnp.where(keep, cache.arrival, NEG))


# ---------------------------------------------------------------------------
# legacy candidate-selection API — thin shims over repro.policies
# ---------------------------------------------------------------------------

def _run_policy(policy_name: str, origin, ts, samples, group, arrival,
                capacity: int, *, t=None, rng=None, group_slots=None,
                pref=None):
    from repro.policies import base as policy_base
    from repro.policies import registry as policy_registry
    meta = CacheMeta(ts=ts, origin=origin, samples=samples, group=group,
                     arrival=arrival)
    if t is None:
        # fallback when the caller has no epoch clock: the freshest
        # candidate timestamp, floored at 0 so an all-empty candidate set
        # (max ts == -1) never yields a negative epoch. Age-based scoring
        # (mobility_aware rates, staleness decay) needs the real epoch —
        # pass ``t`` explicitly.
        t = jnp.maximum(jnp.max(ts), 0)
    ctx = policy_base.PolicyContext(t=jnp.asarray(t, jnp.int32),
                                    capacity=capacity,
                                    rng=rng, group_slots=group_slots)
    sel, sel_meta = policy_base.retain(
        meta, policy_registry.get_policy(policy_name), ctx, pref=pref)
    return sel, sel_meta.as_dict()


def select_lru(origin, ts, samples, group, arrival, capacity: int,
               rank_key: Optional[jax.Array] = None, *, t=None):
    """LRU retention (Alg. 2 lines 6-18): dedup by origin keeping freshest,
    sort by ts descending, retain first `capacity`.

    Returns (sel_idx [capacity], meta dict) — sel_idx indexes the candidate
    arrays; invalid selections have origin == -1. ``t`` is the current
    epoch for the policy context (defaults to the freshest candidate ts).
    """
    return _run_policy("lru", origin, ts, samples, group, arrival, capacity,
                       pref=rank_key, t=t)


def select_group(origin, ts, samples, group, arrival, capacity: int,
                 group_slots: jax.Array, *, t=None):
    """Group-Based retention (Alg. 3): per-group LRU with r_g slots.

    group_slots: [num_groups] int32 with sum == capacity.
    """
    return _run_policy("group", origin, ts, samples, group, arrival,
                       capacity, group_slots=group_slots, t=t)


def select_fifo(origin, ts, samples, group, arrival, capacity: int, *,
                t=None):
    """FIFO variant: dedup by origin (freshest copy), retain the most
    recently *received* entries. Non-paper baseline for the policy study."""
    return _run_policy("fifo", origin, ts, samples, group, arrival, capacity,
                       t=t)


def select_random(origin, ts, samples, group, arrival, capacity: int, key, *,
                  t=None):
    """Random retention after origin-dedup. Non-paper baseline."""
    return _run_policy("random", origin, ts, samples, group, arrival,
                       capacity, rng=key, t=t)


def apply_selection(cache: ModelCache, cand_models, sel, meta) -> ModelCache:
    """Gather selected candidate models into a fresh cache.

    ``meta`` is a :class:`CacheMeta` (or the legacy field dict)."""
    models = tree_take(cand_models, sel, axis=0)
    if isinstance(meta, CacheMeta):
        meta = meta.as_dict()
    return dataclasses.replace(cache, models=models, **meta)


def insert(cache: ModelCache, params, t, origin, samples, group,
           tau_max, policy="lru", rng: Optional[jax.Array] = None,
           group_slots: Optional[jax.Array] = None,
           policy_params: Optional[Dict[str, float]] = None,
           encounters: Optional[jax.Array] = None,
           transfer_budget: Optional[float] = None) -> ModelCache:
    """Insert/refresh a single model (Alg. 2 line 6) then retain under the
    configured ``policy`` (name or :class:`repro.policies.CachePolicy`).

    Used by the pod-scale deployment where exchanges arrive one at a time;
    honors the same registry as the fleet path so both agree.
    ``transfer_budget`` mirrors the fleet exchange's per-link entry cap: a
    single insert moves one model, so a (static) budget below one whole
    entry masks the arriving candidate — the cache still ages and evicts.
    """
    from repro.policies import base as policy_base
    from repro.policies import registry as policy_registry
    pol = policy_registry.resolve(policy)
    cache = evict_stale(cache, t, tau_max)
    C = cache.capacity
    admitted = transfer_budget is None or transfer_budget >= 1.0
    cand_models = jax.tree_util.tree_map(
        lambda c, x: jnp.concatenate([c, x[None].astype(c.dtype)], axis=0),
        cache.models, params)
    meta = CacheMeta(
        ts=jnp.concatenate([cache.ts, jnp.asarray(
            [t if admitted else -1], jnp.int32)]),
        origin=jnp.concatenate([cache.origin, jnp.asarray(
            [origin if admitted else -1], jnp.int32)]),
        samples=jnp.concatenate([cache.samples, jnp.asarray(
            [samples if admitted else 0.0], jnp.float32)]),
        group=jnp.concatenate([cache.group, jnp.asarray(
            [group if admitted else -1], jnp.int32)]),
        arrival=jnp.concatenate([cache.arrival, jnp.asarray(
            [t if admitted else -1], jnp.int32)]))
    ctx = policy_base.PolicyContext(
        t=jnp.asarray(t, jnp.int32), capacity=C, rng=rng,
        group_slots=group_slots, encounters=encounters,
        params=dict(policy_params or {}))
    sel, sel_meta = policy_base.retain(meta, pol, ctx)
    return apply_selection(cache, cand_models, sel, sel_meta)
