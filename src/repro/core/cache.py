"""The Cached-DFL model cache (paper §2.2, Algorithms 2 & 3).

TPU adaptation: instead of PyTorch dicts of ``state_dict``s, the cache is a
fixed-capacity *stacked pytree* — every leaf of the model gets a leading
``[C]`` axis — plus flat metadata arrays. All updates (staleness eviction,
LRU dedup/retention, group-based pruning) are ``jax.lax`` ops over the
metadata, so an entire fleet's cache maintenance jits into one program and
never leaves the device.

Metadata per slot:
    ts      int32  epoch at which the cached model finished local training
                   (the paper's τ);  -1 = empty slot
    origin  int32  agent the model was trained on; -1 = empty
    samples float32 n_j (local dataset size) for aggregation weights
    group   int32  origin agent's distribution group (Algorithm 3)
    arrival int32  epoch the entry was received (fifo policy)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_take

NEG = jnp.int32(-1)


@dataclasses.dataclass
class ModelCache:
    models: Any          # pytree, leaves [C, ...]
    ts: jax.Array        # [C] int32
    origin: jax.Array    # [C] int32
    samples: jax.Array   # [C] float32
    group: jax.Array     # [C] int32
    arrival: jax.Array   # [C] int32

    @property
    def capacity(self) -> int:
        return self.ts.shape[0]

    @property
    def valid(self) -> jax.Array:
        return self.origin >= 0

jax.tree_util.register_dataclass(
    ModelCache,
    data_fields=["models", "ts", "origin", "samples", "group", "arrival"],
    meta_fields=[])


def init_cache(template_params, capacity: int) -> ModelCache:
    models = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + x.shape, x.dtype), template_params)
    z = jnp.full((capacity,), NEG)
    return ModelCache(models=models, ts=z, origin=z,
                      samples=jnp.zeros((capacity,), jnp.float32),
                      group=z, arrival=z)


def evict_stale(cache: ModelCache, t, tau_max) -> ModelCache:
    """Remove entries with staleness t - τ >= τ_max (Alg. 2 lines 1-5)."""
    keep = cache.valid & ((t - cache.ts) < tau_max)
    return dataclasses.replace(
        cache,
        ts=jnp.where(keep, cache.ts, NEG),
        origin=jnp.where(keep, cache.origin, NEG),
        samples=jnp.where(keep, cache.samples, 0.0),
        group=jnp.where(keep, cache.group, NEG),
        arrival=jnp.where(keep, cache.arrival, NEG))


# ---------------------------------------------------------------------------
# candidate-set selection (metadata phase)
# ---------------------------------------------------------------------------

def _dedup_mask(origin, ts, pref):
    """valid[i] = entry i is the best copy of its origin.

    Best = max ts; ties broken by higher ``pref`` then lower index.
    origin < 0 entries are invalid.
    """
    M = origin.shape[0]
    same = origin[None, :] == origin[:, None]          # [i, j]
    newer = ts[None, :] > ts[:, None]
    tie = ts[None, :] == ts[:, None]
    pref_j = (pref[None, :] > pref[:, None]) | (
        (pref[None, :] == pref[:, None])
        & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None]))
    beaten = same & (newer | (tie & pref_j))
    return (origin >= 0) & ~jnp.any(beaten, axis=1)


def select_lru(origin, ts, samples, group, arrival, capacity: int,
               rank_key: Optional[jax.Array] = None):
    """LRU retention (Alg. 2 lines 6-18): dedup by origin keeping freshest,
    sort by ts descending, retain first `capacity`.

    Returns (sel_idx [capacity], meta dict) — sel_idx indexes the candidate
    arrays; invalid selections have origin == -1.
    """
    pref = jnp.zeros_like(ts) if rank_key is None else rank_key
    valid = _dedup_mask(origin, ts, pref)
    key = jnp.where(valid, ts, jnp.int32(-2**30))
    # stable ordering: break ts ties by candidate index (earlier = own cache)
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = valid[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def select_group(origin, ts, samples, group, arrival, capacity: int,
                 group_slots: jax.Array):
    """Group-Based retention (Alg. 3): per-group LRU with r_g slots.

    group_slots: [num_groups] int32 with sum == capacity.
    """
    num_groups = group_slots.shape[0]
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    M = origin.shape[0]
    # rank of each entry within its group by ts desc (valid entries only)
    same_g = (group[None, :] == group[:, None])
    better = same_g & valid[None, :] & (
        (ts[None, :] > ts[:, None])
        | ((ts[None, :] == ts[:, None])
           & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None])))
    rank = jnp.sum(better, axis=1)
    slots = jnp.where((group >= 0) & (group < num_groups),
                      group_slots[jnp.clip(group, 0, num_groups - 1)], 0)
    keep = valid & (rank < slots)
    key = jnp.where(keep, ts, jnp.int32(-2**30))
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = keep[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def _retain(retain_key, valid, origin, ts, samples, group, arrival,
            capacity: int):
    key = jnp.where(valid, retain_key, jnp.int32(-2**30))
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = valid[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def select_fifo(origin, ts, samples, group, arrival, capacity: int):
    """FIFO variant: dedup by origin (freshest copy), retain the most
    recently *received* entries. Non-paper baseline for the policy study."""
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    return _retain(arrival, valid, origin, ts, samples, group, arrival,
                   capacity)


def select_random(origin, ts, samples, group, arrival, capacity: int, key):
    """Random retention after origin-dedup. Non-paper baseline."""
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    rnd = jax.random.randint(key, origin.shape, 0, 2**30)
    return _retain(rnd, valid, origin, ts, samples, group, arrival, capacity)


def apply_selection(cache: ModelCache, cand_models, sel, meta) -> ModelCache:
    """Gather selected candidate models into a fresh cache."""
    models = tree_take(cand_models, sel, axis=0)
    return dataclasses.replace(cache, models=models, **meta)


def insert(cache: ModelCache, params, t, origin, samples, group,
           tau_max) -> ModelCache:
    """Insert/refresh a single model (Alg. 2 line 6) then LRU-retain.

    Used by the pod-scale deployment where exchanges arrive one at a time.
    """
    cache = evict_stale(cache, t, tau_max)
    C = cache.capacity
    cand_models = jax.tree_util.tree_map(
        lambda c, x: jnp.concatenate([c, x[None].astype(c.dtype)], axis=0),
        cache.models, params)
    origin_c = jnp.concatenate([cache.origin, jnp.asarray([origin], jnp.int32)])
    ts_c = jnp.concatenate([cache.ts, jnp.asarray([t], jnp.int32)])
    samples_c = jnp.concatenate([cache.samples,
                                 jnp.asarray([samples], jnp.float32)])
    group_c = jnp.concatenate([cache.group, jnp.asarray([group], jnp.int32)])
    arrival_c = jnp.concatenate([cache.arrival, jnp.asarray([t], jnp.int32)])
    sel, meta = select_lru(origin_c, ts_c, samples_c, group_c, arrival_c, C)
    return apply_selection(cache, cand_models, sel, meta)
