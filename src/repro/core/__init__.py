"""Cached-DFL: the paper's primary contribution as a composable JAX module."""
from repro.core.cache import ModelCache, init_cache, evict_stale, insert  # noqa: F401
from repro.core.aggregate import (  # noqa: F401
    aggregate, aggregate_flat, aggregate_flat_gathered,
)
from repro.core.gossip import exchange, gather_winners  # noqa: F401
from repro.core.local_update import local_update, fleet_local_update  # noqa: F401
from repro.core.rounds import (  # noqa: F401
    FleetState, FleetEngine, init_fleet, liveness_mask, make_epoch_step,
    make_fleet_engine, cached_dfl_epoch, dfl_epoch, cfl_epoch,
    fleet_accuracy, fleet_eval,
)
