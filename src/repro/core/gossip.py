"""DTN-like model spreading: contact-driven cache exchange (paper §4.5).

The whole fleet's exchange for one epoch is a single vectorized program:

  phase 1 (metadata): per agent, build the candidate set
      own cache ∪ {partner j's fresh model} ∪ partner j's cache  (∀ j met)
      and run the cache-update policy purely on (origin, ts, …) arrays;
  phase 2 (gather): fetch only the winning models' weights with a clamped
      advanced-indexing gather from the cache plus a ``jnp.where`` select
      of the own-model rows (no stacked ``[N, C+1, ...]`` copy).

This two-phase split is the TPU adaptation of Algorithm 2: selecting by
metadata first avoids materializing N·D·(C+1) candidate model copies, and
the select-based gather keeps phase 2 free of full-cache temporaries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.cache import CacheMeta, ModelCache, NEG
from repro.policies import base as policy_base
from repro.policies import registry as policy_registry
from repro.policies.base import CachePolicy


def _candidates(cache: ModelCache, t, partners, own_ts, own_samples,
                own_group, tau_max):
    """Build candidate metadata [N, M] and source coordinates.

    M = C + D*(1 + C): own cache, then per partner (own model, cache).
    Source coordinate (agent, slot): slot C refers to the agent's own model
    in the stacked gather array; slots 0..C-1 are its cache entries.
    """
    N, C = cache.ts.shape
    D = partners.shape[1]
    pvalid = partners >= 0
    pidx = jnp.clip(partners, 0, N - 1)

    # --- own cache entries ---
    o_ts, o_origin = cache.ts, cache.origin
    o_samples, o_group, o_arrival = cache.samples, cache.group, cache.arrival
    o_src_a = jnp.broadcast_to(jnp.arange(N)[:, None], (N, C))
    o_src_s = jnp.broadcast_to(jnp.arange(C)[None, :], (N, C))

    # --- partners' fresh models ---
    p_ts = jnp.where(pvalid, own_ts[pidx], NEG)
    p_origin = jnp.where(pvalid, partners, NEG)
    p_samples = jnp.where(pvalid, own_samples[pidx], 0.0)
    p_group = jnp.where(pvalid, own_group[pidx], NEG)
    p_arrival = jnp.where(pvalid, t, NEG)
    p_src_a = pidx
    p_src_s = jnp.full((N, D), C, jnp.int32)

    # --- partners' caches ---
    c_ts = jnp.where(pvalid[..., None], cache.ts[pidx], NEG).reshape(N, D * C)
    c_origin = jnp.where(pvalid[..., None], cache.origin[pidx],
                         NEG).reshape(N, D * C)
    c_samples = jnp.where(pvalid[..., None], cache.samples[pidx],
                          0.0).reshape(N, D * C)
    c_group = jnp.where(pvalid[..., None], cache.group[pidx],
                        NEG).reshape(N, D * C)
    c_arrival = jnp.where(jnp.broadcast_to(pvalid[..., None], (N, D, C)),
                          t, NEG).reshape(N, D * C)
    c_src_a = jnp.broadcast_to(pidx[..., None], (N, D, C)).reshape(N, D * C)
    c_src_s = jnp.broadcast_to(jnp.arange(C)[None, None, :],
                               (N, D, C)).reshape(N, D * C)

    cat = lambda *xs: jnp.concatenate(xs, axis=1)
    ts = cat(o_ts, p_ts, c_ts)
    origin = cat(o_origin, p_origin, c_origin)
    samples = cat(o_samples, p_samples, c_samples)
    group = cat(o_group, p_group, c_group)
    arrival = cat(o_arrival, p_arrival, c_arrival)
    src_a = cat(o_src_a, p_src_a, c_src_a)
    src_s = cat(o_src_s, p_src_s, c_src_s)

    # staleness kick-out (Alg. 2 lines 1-5) on ALL candidates
    fresh = (origin >= 0) & ((t - ts) < tau_max)
    origin = jnp.where(fresh, origin, NEG)
    ts = jnp.where(fresh, ts, NEG)
    return ts, origin, samples, group, arrival, src_a, src_s


def gather_winners(cache_models, params, gather_a, gather_s, *,
                   mode: str = "select"):
    """Phase-2 weight fetch: winners[i, c] = model at (gather_a, gather_s).

    Slot index ``C`` refers to agent ``gather_a``'s own (fresh) model; slots
    ``0..C-1`` are its cache entries.

    ``mode="select"`` (default) is the allocation-light path: one clamped
    gather from the cache plus a gather from ``params``, combined with a
    ``jnp.where`` on the own-model mask. XLA fuses the select into the
    gathers, so no ``[N, C+1, ...]`` stacked copy of the whole cache is ever
    materialized. ``mode="concat"`` keeps the original stack-then-gather
    formulation as a bit-exact reference for tests and benchmarks.
    """
    def select_leaf(cache_leaf, params_leaf):
        C = cache_leaf.shape[1]
        slot = jnp.minimum(gather_s, C - 1)          # clamp own-model slot C
        from_cache = cache_leaf[gather_a, slot]
        own = params_leaf[gather_a].astype(cache_leaf.dtype)
        is_own = (gather_s == C).reshape(
            gather_s.shape + (1,) * (cache_leaf.ndim - 2))
        return jnp.where(is_own, own, from_cache)

    def concat_leaf(cache_leaf, params_leaf):
        # stacked [N, C+1, ...]: cache slots then own model
        stacked = jnp.concatenate(
            [cache_leaf, params_leaf[:, None].astype(cache_leaf.dtype)],
            axis=1)
        return stacked[gather_a, gather_s]

    if mode == "select":
        leaf = select_leaf
    elif mode == "concat":
        leaf = concat_leaf
    else:
        raise ValueError(f"unknown gather mode {mode!r}")
    return jax.tree_util.tree_map(leaf, cache_models, params)


def exchange(params, cache: ModelCache, partners, t, own_samples, own_group,
             *, tau_max: int, policy: Union[str, CachePolicy] = "lru",
             group_slots: Optional[jax.Array] = None,
             rng: Optional[jax.Array] = None,
             encounters: Optional[jax.Array] = None,
             policy_params: Optional[Dict[str, float]] = None,
             gather_mode: str = "select") -> ModelCache:
    """One epoch of DTN-like cache exchange for the whole fleet.

    params: pytree [N, ...] (post-local-update models x̃_i(t));
    cache: leaves [N, C, ...]; partners: [N, D] int32 (-1 padded);
    encounters: optional [N, N] cumulative per-pair encounter counts for
    mobility-aware policies. ``policy`` is a registered policy name (or a
    CachePolicy); the choice is static per trace, policy randomness stays
    the traced ``rng`` key. Agents with no partners still run staleness
    eviction + retention.
    """
    pol = policy_registry.resolve(policy)
    N, C = cache.ts.shape
    own_ts = jnp.full((N,), t, jnp.int32)
    ts, origin, samples, group, arrival, src_a, src_s = _candidates(
        cache, t, partners, own_ts, own_samples, own_group, tau_max)

    if pol.needs_rng and rng is None:
        raise ValueError(f"{pol.name} policy requires rng")
    keys = jax.random.split(rng, N) if pol.needs_rng else None
    pparams = dict(policy_params or {})
    t_arr = jnp.asarray(t, jnp.int32)

    def one_agent(origin_i, ts_i, samples_i, group_i, arrival_i, key_i,
                  enc_i):
        meta = CacheMeta(ts=ts_i, origin=origin_i, samples=samples_i,
                         group=group_i, arrival=arrival_i)
        ctx = policy_base.PolicyContext(
            t=t_arr, capacity=C, rng=key_i, group_slots=group_slots,
            encounters=enc_i, params=pparams)
        return policy_base.retain(meta, pol, ctx)

    sel, meta = jax.vmap(
        one_agent,
        in_axes=(0, 0, 0, 0, 0,
                 0 if keys is not None else None,
                 0 if encounters is not None else None))(
        origin, ts, samples, group, arrival, keys, encounters)

    # phase 2: gather winning model weights only
    gather_a = jnp.take_along_axis(src_a, sel, axis=1)  # [N, C]
    gather_s = jnp.take_along_axis(src_s, sel, axis=1)
    models = gather_winners(cache.models, params, gather_a, gather_s,
                            mode=gather_mode)
    return dataclasses.replace(cache, models=models, **meta.as_dict())
