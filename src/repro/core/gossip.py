"""DTN-like model spreading: contact-driven cache exchange (paper §4.5).

The whole fleet's exchange for one epoch is a single vectorized program:

  phase 1 (metadata): per agent, build the candidate set
      own cache ∪ {partner j's fresh model} ∪ partner j's cache  (∀ j met)
      and run the cache-update policy purely on (origin, ts, …) arrays;
  phase 2 (gather): fetch only the winning models' weights with a clamped
      advanced-indexing gather from the cache plus a ``jnp.where`` select
      of the own-model rows (no stacked ``[N, C+1, ...]`` copy).

This two-phase split is the TPU adaptation of Algorithm 2: selecting by
metadata first avoids materializing N·D·(C+1) candidate model copies, and
the select-based gather keeps phase 2 free of full-cache temporaries.

Transfer budget (contact-duration-limited transfers): real vehicular
contacts are short, so one contact can only move a bounded number of
models. ``exchange`` accepts a per-epoch budget — a flat per-link entry
cap (``transfer_budget``) and/or a duration-derived cap
(``durations[i, j] steps × link_entries_per_step``, using the per-pair
contact durations ``simulate_epoch`` measures). Non-own candidates beyond
a link's cap are masked *before* policy retention, ordered by the
configured policy's own priority function — so every registered policy
composes with the budget without extra code. An unlimited budget is
bit-exact with the unbudgeted exchange.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.cache import CacheMeta, ModelCache, NEG
from repro.policies import base as policy_base
from repro.policies import registry as policy_registry
from repro.policies.base import CachePolicy
from repro.telemetry.metrics import ExchangeStats


class ExchangePool(NamedTuple):
    """The source side of an exchange: who the receivers can copy *from*.

    The fused (dense) engine uses the identity pool — every agent sources
    from the whole fleet, partner ids are global agent ids. The sharded
    engine passes each shard's gathered halo window instead: ``params`` /
    ``cache`` / ``samples`` / ``group`` hold the W window rows, ``ids``
    maps window row -> global agent id, and partner ids in ``partners``
    are *pool-relative* row indices. ``self_rows`` gives each receiver's
    own row inside the pool (needed for the own-cache source coordinates
    consumed by the phase-2 gather).
    """
    params: Any          # pytree, leaves [W, ...] — fresh models
    cache: ModelCache    # leaves [W, C, ...]
    samples: jax.Array   # [W] float32
    group: jax.Array     # [W] int32
    ids: jax.Array       # [W] int32 global agent id per pool row
    self_rows: jax.Array # [n_receivers] int32 pool row of each receiver


def identity_pool(params, cache: ModelCache, own_samples, own_group
                  ) -> ExchangePool:
    """Pool for the dense path: pool row index == global agent id."""
    n = cache.ts.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    return ExchangePool(params=params, cache=cache, samples=own_samples,
                        group=own_group, ids=ids, self_rows=ids)


def valid_partner_mask(partners: jax.Array) -> jax.Array:
    """[N, D] bool — real partner slots, first occurrence per id only.

    A partner id repeated within one row (possible with hand-built partner
    lists or degenerate samplers) is masked after its first occurrence —
    duplicates would inject the same candidates twice, charge a transfer
    budget twice for one physical link, and inflate encounter counts.
    """
    D = partners.shape[1]
    dup = jnp.any((partners[:, :, None] == partners[:, None, :])
                  & jnp.tril(jnp.ones((D, D), bool), -1)[None], axis=2)
    return (partners >= 0) & ~dup


def _candidates(cache: ModelCache, t, partners, tau_max,
                pool: ExchangePool):
    """Build candidate metadata [N, M] and source coordinates.

    M = C + D*(1 + C): own cache, then per partner (own model, cache).
    Source coordinate (agent, slot): slot C refers to pool row
    ``gather_a``'s own model in the stacked gather array; slots 0..C-1 are
    its cache entries. ``partners`` holds pool-relative row indices (equal
    to global agent ids for the identity pool). Duplicate partner ids are
    masked (:func:`valid_partner_mask`).
    """
    N, C = cache.ts.shape
    D = partners.shape[1]
    W = pool.ids.shape[0]
    pvalid = valid_partner_mask(partners)
    pidx = jnp.clip(partners, 0, W - 1)

    # --- own cache entries ---
    o_ts, o_origin = cache.ts, cache.origin
    o_samples, o_group, o_arrival = cache.samples, cache.group, cache.arrival
    o_src_a = jnp.broadcast_to(pool.self_rows[:, None], (N, C))
    o_src_s = jnp.broadcast_to(jnp.arange(C)[None, :], (N, C))

    # --- partners' fresh models ---
    t32 = jnp.asarray(t, jnp.int32)
    p_ts = jnp.where(pvalid, jnp.broadcast_to(t32, (N, D)), NEG)
    p_origin = jnp.where(pvalid, pool.ids[pidx], NEG)
    p_samples = jnp.where(pvalid, pool.samples[pidx], 0.0)
    p_group = jnp.where(pvalid, pool.group[pidx], NEG)
    p_arrival = jnp.where(pvalid, t, NEG)
    p_src_a = pidx
    p_src_s = jnp.full((N, D), C, jnp.int32)

    # --- partners' caches ---
    c_ts = jnp.where(pvalid[..., None], pool.cache.ts[pidx],
                     NEG).reshape(N, D * C)
    c_origin = jnp.where(pvalid[..., None], pool.cache.origin[pidx],
                         NEG).reshape(N, D * C)
    c_samples = jnp.where(pvalid[..., None], pool.cache.samples[pidx],
                          0.0).reshape(N, D * C)
    c_group = jnp.where(pvalid[..., None], pool.cache.group[pidx],
                        NEG).reshape(N, D * C)
    c_arrival = jnp.where(jnp.broadcast_to(pvalid[..., None], (N, D, C)),
                          t, NEG).reshape(N, D * C)
    c_src_a = jnp.broadcast_to(pidx[..., None], (N, D, C)).reshape(N, D * C)
    c_src_s = jnp.broadcast_to(jnp.arange(C)[None, None, :],
                               (N, D, C)).reshape(N, D * C)

    cat = lambda *xs: jnp.concatenate(xs, axis=1)
    ts = cat(o_ts, p_ts, c_ts)
    origin = cat(o_origin, p_origin, c_origin)
    samples = cat(o_samples, p_samples, c_samples)
    group = cat(o_group, p_group, c_group)
    arrival = cat(o_arrival, p_arrival, c_arrival)
    src_a = cat(o_src_a, p_src_a, c_src_a)
    src_s = cat(o_src_s, p_src_s, c_src_s)

    # staleness kick-out (Alg. 2 lines 1-5) on ALL candidates
    fresh = (origin >= 0) & ((t - ts) < tau_max)
    origin = jnp.where(fresh, origin, NEG)
    ts = jnp.where(fresh, ts, NEG)
    return ts, origin, samples, group, arrival, src_a, src_s


def _candidate_links(num_cache: int, num_partners: int) -> jax.Array:
    """[M] link id per candidate: -1 = own cache (free), else the partner
    slot d whose radio link carries the entry. Layout mirrors
    :func:`_candidates`: own cache, partner models, partner caches."""
    return jnp.concatenate([
        jnp.full((num_cache,), -1, jnp.int32),
        jnp.arange(num_partners, dtype=jnp.int32),
        jnp.repeat(jnp.arange(num_partners, dtype=jnp.int32), num_cache)])


def link_caps(partners, durations, transfer_budget,
              link_entries_per_step: float) -> jax.Array:
    """[N, D] float32 — whole-entry admission cap per (agent, partner slot).

    The cap is the measured contact time converted to entries
    (``durations × link_entries_per_step``), clamped by the flat
    ``transfer_budget`` when one is set; either limit alone also works.
    Fractional capacity is floored — a contact either moves a whole model
    or it doesn't.
    """
    N, D = partners.shape
    cap = jnp.full((N, D), jnp.inf, jnp.float32)
    if link_entries_per_step > 0:
        if durations is None:
            raise ValueError(
                "link_entries_per_step > 0 needs the per-pair contact "
                "durations returned by simulate_epoch")
        # durations columns may be a window [n, W] (sharded engine), so
        # clamp against the duration matrix, not the receiver count
        pidx = jnp.clip(partners, 0, durations.shape[1] - 1)
        dur = jnp.take_along_axis(durations, pidx, axis=1)
        cap = dur.astype(jnp.float32) * link_entries_per_step
    if transfer_budget is not None:
        tb = jnp.asarray(transfer_budget, jnp.float32)
        # negative = the 'unlimited' sentinel (DFLConfig docs); honor it
        # here too so per-call traced budgets that bypass the config
        # normalization can't silently turn into a cap of -1 (no exchange)
        cap = jnp.minimum(cap, jnp.where(tb < 0, jnp.inf, tb))
    return jnp.floor(cap)


def _admit_within_budget(meta: CacheMeta, pol: CachePolicy,
                         ctx: "policy_base.PolicyContext", link: jax.Array,
                         cap: jax.Array):
    """Mask one agent's candidates down to each link's entry cap.

    Returns ``(meta, admitted)`` — the masked candidate metadata plus the
    [M] admission mask (True for entries that survive; own-cache entries
    are always True, charged entries only when they made their link's
    cut), so telemetry can count realized link traffic.

    The configured policy's own priority function orders which entries
    make the cut on a saturated link (higher key first, earlier candidate
    on ties — the same stable order the retention engine uses), so every
    registered policy composes with the budget for free. Own-cache
    candidates (link == -1) ride free: they are already local.

    Budget is only spent on entries retention could actually keep: a copy
    that fails the policy's keep mask (e.g. a group with zero slots), is
    not the freshest copy *on its own link*, or loses to a copy already
    in the receiver's own cache is never transmitted — it neither charges
    the link nor survives. Copies of one origin offered on *different*
    links each charge their own link (no cross-link coordination for
    dedup: a saturated link cutting the freshest copy must not also
    forfeit a staler copy riding an idle link); retention keeps the
    freshest of whatever arrived. All other link traffic beyond the cap
    is masked, so budget 0 moves nothing even for rank-relative keep
    masks.

    Known one-shot approximation: the keep gate is evaluated against the
    pre-admission candidate view, so a *rank-relative* keep (the group
    policy's slot rank) may still reject an entry whose outranking
    same-group competitor is itself cut by another link's cap. Resolving
    that exactly needs an admission/keep fixpoint; the greedy pass trades
    that corner (the entry arrives at a later contact) for a single
    vectorized step.
    """
    # keep mask against the same global-dedup view retention uses
    valid = policy_base.dedup_mask(meta.origin, meta.ts)
    key, keep = pol.priority(meta, ctx, valid)
    key = key.astype(jnp.float32)
    M = link.shape[0]
    idx = jnp.arange(M)
    charged = link >= 0
    # origin dedup at transmission time, restricted to copies on the same
    # link or in the receiver's own cache (own copies can't be
    # budget-masked, so deduping against them never forfeits the origin);
    # shares retention's tie-break via beats_matrix
    beats = policy_base.beats_matrix(meta.origin, meta.ts)
    link_best = meta.valid & ~jnp.any(
        beats & (link[None, :] == link[:, None]), axis=1)
    unbeaten_by_own = ~jnp.any(beats & (link[None, :] < 0), axis=1)
    # the keep gate only applies where it matches retention's dedup view
    # (globally-valid entries); a globally-beaten but link-best copy rides
    # ungated — whether it is kept is retention's call
    contender = charged & link_best & unbeaten_by_own & (keep | ~valid)
    ahead = ((link[None, :] == link[:, None]) & contender[None, :]
             & ((key[None, :] > key[:, None])
                | ((key[None, :] == key[:, None])
                   & (idx[None, :] < idx[:, None]))))
    rank = jnp.sum(ahead, axis=1)
    cap_c = cap[jnp.clip(link, 0, cap.shape[0] - 1)]
    admitted = ~charged | (contender & (rank < cap_c))
    return CacheMeta(
        ts=jnp.where(admitted, meta.ts, NEG),
        origin=jnp.where(admitted, meta.origin, NEG),
        samples=jnp.where(admitted, meta.samples, 0.0),
        group=jnp.where(admitted, meta.group, NEG),
        arrival=jnp.where(admitted, meta.arrival, NEG)), admitted


def gather_winners(cache_models, params, gather_a, gather_s, *,
                   mode: str = "select"):
    """Phase-2 weight fetch: winners[i, c] = model at (gather_a, gather_s).

    Slot index ``C`` refers to agent ``gather_a``'s own (fresh) model; slots
    ``0..C-1`` are its cache entries.

    ``mode="select"`` (default) is the allocation-light path: one clamped
    gather from the cache plus a gather from ``params``, combined with a
    ``jnp.where`` on the own-model mask. XLA fuses the select into the
    gathers, so no ``[N, C+1, ...]`` stacked copy of the whole cache is ever
    materialized. ``mode="concat"`` keeps the original stack-then-gather
    formulation as a bit-exact reference for tests and benchmarks.
    """
    def select_leaf(cache_leaf, params_leaf):
        C = cache_leaf.shape[1]
        slot = jnp.minimum(gather_s, C - 1)          # clamp own-model slot C
        from_cache = cache_leaf[gather_a, slot]
        own = params_leaf[gather_a].astype(cache_leaf.dtype)
        is_own = (gather_s == C).reshape(
            gather_s.shape + (1,) * (cache_leaf.ndim - 2))
        return jnp.where(is_own, own, from_cache)

    def concat_leaf(cache_leaf, params_leaf):
        # stacked [N, C+1, ...]: cache slots then own model
        stacked = jnp.concatenate(
            [cache_leaf, params_leaf[:, None].astype(cache_leaf.dtype)],
            axis=1)
        return stacked[gather_a, gather_s]

    if mode == "select":
        leaf = select_leaf
    elif mode == "concat":
        leaf = concat_leaf
    else:
        raise ValueError(f"unknown gather mode {mode!r}")
    return jax.tree_util.tree_map(leaf, cache_models, params)


def exchange(params, cache: ModelCache, partners, t, own_samples, own_group,
             *, tau_max: int, policy: Union[str, CachePolicy] = "lru",
             group_slots: Optional[jax.Array] = None,
             rng: Optional[jax.Array] = None,
             encounters: Optional[jax.Array] = None,
             policy_params: Optional[Dict[str, float]] = None,
             gather_mode: str = "select",
             durations: Optional[jax.Array] = None,
             transfer_budget=None,
             link_entries_per_step: float = 0.0,
             with_stats: bool = False,
             pool: Optional[ExchangePool] = None,
             rng_keys: Optional[jax.Array] = None,
             live: Optional[jax.Array] = None):
    """One epoch of DTN-like cache exchange for the whole fleet.

    params: pytree [N, ...] (post-local-update models x̃_i(t));
    cache: leaves [N, C, ...]; partners: [N, D] int32 (-1 padded);
    encounters: optional [N, N] cumulative per-pair encounter counts for
    mobility-aware policies. ``policy`` is a registered policy name (or a
    CachePolicy); the choice is static per trace, policy randomness stays
    the traced ``rng`` key. Agents with no partners still run staleness
    eviction + retention.

    Transfer budget: when ``transfer_budget`` is set (entries per link per
    epoch; may be a traced scalar — sweeping it never retraces) and/or
    ``link_entries_per_step > 0`` (converts the measured per-pair contact
    ``durations`` [N, N] from ``simulate_epoch`` into link capacity), each
    partner link admits at most its cap of non-own candidates, ordered by
    the policy's priority (see :func:`_admit_within_budget`). Budget 0
    degenerates to no exchange (caches only age/evict); an unlimited
    budget is bit-exact with the unbudgeted path.

    With ``with_stats`` (static flag — telemetry-enabled traces only) the
    return becomes ``(cache, ExchangeStats)``: fleet-total offered /
    admitted entry counts plus the finite link capacity, for gossip
    traffic and budget-utilization telemetry. The cache result is
    untouched by the flag.

    Sharded engine hooks: ``pool`` replaces the implicit whole-fleet
    source side with an :class:`ExchangePool` (partner ids then index pool
    rows, and ``durations`` columns align with pool rows); ``rng_keys``
    supplies pre-split per-receiver policy keys so the caller can split at
    global fleet size and slice its rows (threefry streams depend on the
    split count, so splitting at local size would diverge from the dense
    path). Both default to the dense behaviour.

    Open-world fleets: ``live`` ([N] bool by *global* agent id, same in
    both engines) rides :class:`~repro.policies.base.PolicyContext` so
    liveness-aware cache policies can score candidates by whether their
    origin is currently in coverage. The exchange itself never consults
    it — dead agents are excluded upstream by masking the contact matrix,
    while entries they previously gossiped keep spreading through live
    carriers (the DTN effect).
    """
    pol = policy_registry.resolve(policy)
    N, C = cache.ts.shape
    D = partners.shape[1]
    if pool is None:
        pool = identity_pool(params, cache, own_samples, own_group)
    ts, origin, samples, group, arrival, src_a, src_s = _candidates(
        cache, t, partners, tau_max, pool)

    if pol.needs_rng:
        if rng_keys is not None:
            keys = rng_keys
        elif rng is not None:
            keys = jax.random.split(rng, N)
        else:
            raise ValueError(f"{pol.name} policy requires rng")
    else:
        keys = None
    pparams = dict(policy_params or {})
    t_arr = jnp.asarray(t, jnp.int32)

    budgeted = transfer_budget is not None or link_entries_per_step > 0
    if budgeted or with_stats:
        link = _candidate_links(C, D)
    else:
        link = None
    if budgeted:
        caps = link_caps(partners, durations, transfer_budget,
                         link_entries_per_step)
    else:
        caps = None

    def one_agent(origin_i, ts_i, samples_i, group_i, arrival_i, key_i,
                  enc_i, cap_i):
        meta = CacheMeta(ts=ts_i, origin=origin_i, samples=samples_i,
                         group=group_i, arrival=arrival_i)
        ctx = policy_base.PolicyContext(
            t=t_arr, capacity=C, rng=key_i, group_slots=group_slots,
            encounters=enc_i, params=pparams, live=live)
        if with_stats:
            offered = jnp.sum(((link >= 0) & meta.valid)
                              .astype(jnp.float32))
        if budgeted:
            meta, admitted = _admit_within_budget(meta, pol, ctx, link,
                                                  cap_i)
            if with_stats:
                sent = admitted & (link >= 0)
                cap_c = cap_i[jnp.clip(link, 0, cap_i.shape[0] - 1)]
                n_sent = jnp.sum(sent.astype(jnp.float32))
                n_capped = jnp.sum((sent & jnp.isfinite(cap_c))
                                   .astype(jnp.float32))
        elif with_stats:
            n_sent, n_capped = offered, jnp.float32(0.0)
        out = policy_base.retain(meta, pol, ctx)
        if with_stats:
            return out + ((offered, n_sent, n_capped),)
        return out

    outs = jax.vmap(
        one_agent,
        in_axes=(0, 0, 0, 0, 0,
                 0 if keys is not None else None,
                 0 if encounters is not None else None,
                 0 if caps is not None else None))(
        origin, ts, samples, group, arrival, keys, encounters, caps)
    if with_stats:
        sel, meta, (offered_pa, sent_pa, sent_capped_pa) = outs
    else:
        sel, meta = outs

    # phase 2: gather winning model weights only (from the pool side)
    gather_a = jnp.take_along_axis(src_a, sel, axis=1)  # [N, C]
    gather_s = jnp.take_along_axis(src_s, sel, axis=1)
    models = gather_winners(pool.cache.models, pool.params, gather_a,
                            gather_s, mode=gather_mode)
    new_cache = dataclasses.replace(cache, models=models, **meta.as_dict())
    if not with_stats:
        return new_cache

    if budgeted:
        pvalid = valid_partner_mask(partners)
        finite = pvalid & jnp.isfinite(caps)
        capacity = jnp.sum(jnp.where(finite, caps, 0.0))
        capped_links = jnp.sum(finite.astype(jnp.float32))
    else:
        capacity = capped_links = jnp.float32(0.0)
    stats = ExchangeStats(
        offered=jnp.sum(offered_pa), admitted=jnp.sum(sent_pa),
        admitted_capped=jnp.sum(sent_capped_pa),
        link_capacity=capacity, capped_links=capped_links)
    return new_cache, stats
