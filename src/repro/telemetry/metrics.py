"""On-device fleet telemetry: fixed-shape accumulators for the epoch loop.

The paper's experimental story is the interplay between mobility, cache
staleness and convergence — but the raw signals (entry ages at
aggregation time, how far each model has spread, how much a bandwidth
budget actually admits) live deep inside the jitted epoch. The
:class:`FleetMetrics` struct makes them observable without breaking the
engine's compile discipline: every field is a fixed-shape array, the
struct rides the fused engine's ``lax.fori_loop`` carry, and all
reductions happen on device — only the final small arrays cross to host
(``summarize``).

Accumulation never touches the PRNG key stream and only *reads* the
fleet state, so a telemetry-on run is bit-exact with telemetry-off on
model trajectories (pinned by ``tests/test_telemetry.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ExchangeStats:
    """One epoch's gossip traffic, reduced over the whole fleet.

    ``offered`` counts valid non-own candidate entries presented over
    radio links (partner fresh models + partner cache entries, after the
    staleness kick-out); ``admitted`` counts the entries that actually
    crossed a link into retention (for a budgeted exchange: survived
    dedup + the per-link admission cap; unbudgeted: all offered).
    ``admitted_capped`` restricts that to links with a *finite* cap, and
    ``link_capacity`` / ``capped_links`` total the finite per-link entry
    capacity and the number of such links — together they give the
    budget-utilization fraction ``admitted_capped / link_capacity``.
    """
    offered: jax.Array         # [] float32
    admitted: jax.Array        # [] float32
    admitted_capped: jax.Array # [] float32
    link_capacity: jax.Array   # [] float32
    capped_links: jax.Array    # [] float32


jax.tree_util.register_dataclass(
    ExchangeStats,
    data_fields=["offered", "admitted", "admitted_capped", "link_capacity",
                 "capped_links"],
    meta_fields=[])


def zero_exchange_stats() -> ExchangeStats:
    z = jnp.zeros((), jnp.float32)
    return ExchangeStats(offered=z, admitted=z, admitted_capped=z,
                         link_capacity=z, capped_links=z)


@dataclasses.dataclass
class FleetMetrics:
    """Cumulative fleet observables (fixed shapes; fori_loop-carry safe).

    ``staleness_hist[b]`` counts cached entries of age ``b`` (epochs since
    local training, clamped to the last bin) summed over agents, slots and
    epochs — one entry-epoch per count. ``origins_seen[i, o]`` latches
    once agent ``i`` has ever cached a model that originated at agent
    ``o`` — the delay-tolerant model spread the paper motivates; a row's
    popcount is that agent's reachability. Traffic fields accumulate
    :class:`ExchangeStats`; ``contacts`` counts realized (deduped)
    partner links per epoch.
    """
    epochs: jax.Array          # [] int32 — epochs accumulated
    staleness_hist: jax.Array  # [B] float32
    origins_seen: jax.Array    # [N, N] bool
    offered: jax.Array         # [] float32
    admitted: jax.Array        # [] float32
    admitted_capped: jax.Array # [] float32
    link_capacity: jax.Array   # [] float32
    capped_links: jax.Array    # [] float32
    contacts: jax.Array        # [] float32


jax.tree_util.register_dataclass(
    FleetMetrics,
    data_fields=["epochs", "staleness_hist", "origins_seen", "offered",
                 "admitted", "admitted_capped", "link_capacity",
                 "capped_links", "contacts"],
    meta_fields=[])


def init_metrics(num_agents: int, bins: int) -> FleetMetrics:
    """Zeroed accumulators; ``bins`` should cover ages ``0..tau_max``
    (ages beyond the last bin are clamped into it)."""
    z = jnp.zeros((), jnp.float32)
    return FleetMetrics(
        epochs=jnp.zeros((), jnp.int32),
        staleness_hist=jnp.zeros((bins,), jnp.float32),
        origins_seen=jnp.zeros((num_agents, num_agents), bool),
        offered=z, admitted=z, admitted_capped=z, link_capacity=z,
        capped_links=z, contacts=z)


def accumulate(metrics: FleetMetrics, state, partners,
               xstats: Optional[ExchangeStats] = None) -> FleetMetrics:
    """Fold one epoch into the accumulators (jit-able, device-resident).

    ``state`` is the *post-epoch* FleetState (its ``t`` has already been
    advanced, so entry ages are measured against ``t - 1`` — the epoch
    the aggregation actually used). ``partners`` is that epoch's [N, D]
    contact list; ``xstats`` the exchange's traffic counters (None for
    algorithms without a cache exchange).
    """
    from repro.core.gossip import valid_partner_mask  # late: avoid cycle

    cache = state.cache
    valid = cache.origin >= 0
    t_agg = state.t - 1
    B = metrics.staleness_hist.shape[0]
    ages = jnp.clip(t_agg - cache.ts, 0, B - 1)
    hist = metrics.staleness_hist + jnp.sum(
        (ages[..., None] == jnp.arange(B)) & valid[..., None],
        axis=(0, 1)).astype(jnp.float32)

    # columns span the whole fleet even when the rows are one shard's
    # agents (sharded engine), so size the origin id range off the last axis
    N = metrics.origins_seen.shape[-1]
    hit = (cache.origin[:, :, None] == jnp.arange(N)) & valid[:, :, None]
    seen = metrics.origins_seen | jnp.any(hit, axis=1)

    contacts = metrics.contacts + jnp.sum(
        valid_partner_mask(partners).astype(jnp.float32))

    if xstats is None:
        xstats = zero_exchange_stats()
    return FleetMetrics(
        epochs=metrics.epochs + 1,
        staleness_hist=hist,
        origins_seen=seen,
        offered=metrics.offered + xstats.offered,
        admitted=metrics.admitted + xstats.admitted,
        admitted_capped=metrics.admitted_capped + xstats.admitted_capped,
        link_capacity=metrics.link_capacity + xstats.link_capacity,
        capped_links=metrics.capped_links + xstats.capped_links,
        contacts=contacts)


def shard_specs(axis: str) -> FleetMetrics:
    """PartitionSpec tree for the sharded fleet engine: ``origins_seen``
    rows follow the agent axis, every other accumulator is replicated
    (the engine psum-reduces each epoch's per-shard deltas, so the
    replicated copies stay identical). Shape-based spec inference is not
    safe here — ``staleness_hist`` is [bins] and bins can collide with a
    shard-divisible fleet size."""
    from jax.sharding import PartitionSpec as P
    rep = P()
    return FleetMetrics(epochs=rep, staleness_hist=rep,
                        origins_seen=P(axis, None), offered=rep,
                        admitted=rep, admitted_capped=rep, link_capacity=rep,
                        capped_links=rep, contacts=rep)


# repro: allow=RPR004 summarize IS the host boundary: small accumulators ship once per run
def summarize(metrics: FleetMetrics) -> Dict[str, Any]:
    """Ship the accumulators to host and reduce to a JSON-able summary."""
    hist = np.asarray(metrics.staleness_hist, dtype=float)
    total = float(hist.sum())
    bins = np.arange(hist.shape[0], dtype=float)
    if total > 0:
        mean_stale = float((hist * bins).sum() / total)
        cdf = np.cumsum(hist) / total
        p95 = int(np.searchsorted(cdf, 0.95))
    else:
        mean_stale, p95 = 0.0, 0
    seen = np.asarray(metrics.origins_seen)
    N = seen.shape[0]
    spread = seen.sum(axis=1).astype(float)     # distinct origins per agent
    epochs = int(metrics.epochs)
    offered = float(metrics.offered)
    admitted = float(metrics.admitted)
    admitted_capped = float(metrics.admitted_capped)
    capacity = float(metrics.link_capacity)
    contacts = float(metrics.contacts)
    denom = max(epochs, 1)
    return {
        "epochs": epochs,
        "num_agents": int(N),
        "staleness_hist": [int(h) for h in hist],
        "staleness_mean": mean_stale,
        "staleness_p95": p95,
        "cache_entry_epochs": int(total),
        "spread_mean": float(spread.mean()) if N else 0.0,
        "spread_min": float(spread.min()) if N else 0.0,
        "spread_max": float(spread.max()) if N else 0.0,
        "reach_fraction": float(spread.mean() / N) if N else 0.0,
        "offered": offered,
        "admitted": admitted,
        "denied": offered - admitted,
        "admitted_per_epoch": admitted / denom,
        "link_capacity": capacity,
        "capped_links": float(metrics.capped_links),
        "budget_utilization": (admitted_capped / capacity
                               if capacity > 0 else None),
        "contacts": contacts,
        "contacts_per_epoch": contacts / denom,
    }
