"""Nestable phase-timing spans on the monotonic ``perf_counter`` clock.

A :class:`SpanTimer` is a plain host-side recorder: ``with
timer.span("dispatch"): ...`` appends a row ``{name, start, dur_s,
depth}`` when the block closes. ``totals()`` collapses the rows into a
``name -> seconds`` phase breakdown (what ``RunResult.phase_s``
carries); an ``on_close`` callback lets the runner mirror every span
into the structured event stream without coupling the two modules.

Spans nest (depth 1 = outermost); a nested span's time is counted in
both its own name and its ancestors', so totals are per-phase wall
times, not a partition.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, List, Optional


class SpanTimer:
    def __init__(self, on_close: Optional[Callable[[str, float, float, int],
                                                   None]] = None):
        self._rows: List[Dict] = []
        self._depth = 0
        self.on_close = on_close

    @contextlib.contextmanager
    def span(self, name: str):
        start = time.perf_counter()
        self._depth += 1
        depth = self._depth
        try:
            yield self
        finally:
            self._depth -= 1
            dur = time.perf_counter() - start
            self._rows.append({"name": name, "start": start,
                               "dur_s": dur, "depth": depth})
            if self.on_close is not None:
                self.on_close(name, start, dur, depth)

    def rows(self) -> List[Dict]:
        """Closed spans in completion order (inner spans close first)."""
        return list(self._rows)

    def totals(self) -> Dict[str, float]:
        """Total seconds per span name (repeated spans sum)."""
        out: Dict[str, float] = {}
        for row in self._rows:
            out[row["name"]] = out.get(row["name"], 0.0) + row["dur_s"]
        return out

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name ``{"total_s", "count", "max_s"}`` aggregates."""
        out: Dict[str, Dict[str, float]] = {}
        for row in self._rows:
            agg = out.setdefault(row["name"],
                                 {"total_s": 0.0, "count": 0, "max_s": 0.0})
            agg["total_s"] += row["dur_s"]
            agg["count"] += 1
            agg["max_s"] = max(agg["max_s"], row["dur_s"])
        return out
