"""Fleet observability: on-device metrics, host-side spans, run events.

Two halves, glued together by ``repro.fl.runner``:

- :mod:`repro.telemetry.metrics` — fixed-shape on-device accumulators
  (:class:`FleetMetrics`) that ride the fused engine's ``lax.fori_loop``
  carry: cache-staleness histogram, model-spread/reachability, gossip
  traffic + budget utilization, encounter counters. Reduced on device,
  shipped to host as a handful of scalars/small arrays at run end.
- :mod:`repro.telemetry.spans` / :mod:`repro.telemetry.events` —
  ``perf_counter``-based phase spans (build/engine/dispatch/eval) and a
  structured, schema-validated JSONL run-event stream
  (:class:`RunEvent`).

Telemetry is gated by ``Scenario.telemetry``; the zero-telemetry path is
bit-exact with the untelemetered engine (pinned by
``tests/test_telemetry.py``), and telemetry-on fused runs keep the
1-trace-per-(algorithm, shape) compile discipline.
"""
from repro.telemetry.events import (  # noqa: F401
    EVENT_KINDS, SCHEMA_VERSION, EventLog, RunEvent, validate_event,
    validate_events, validate_jsonl, write_jsonl)
from repro.telemetry.metrics import (  # noqa: F401
    ExchangeStats, FleetMetrics, accumulate, init_metrics, summarize,
    zero_exchange_stats)
from repro.telemetry.spans import SpanTimer  # noqa: F401

__all__ = [
    "ExchangeStats", "FleetMetrics", "accumulate", "init_metrics",
    "summarize", "zero_exchange_stats",
    "SpanTimer",
    "EventLog", "RunEvent", "EVENT_KINDS", "SCHEMA_VERSION",
    "validate_event", "validate_events", "validate_jsonl", "write_jsonl",
]
