"""Structured run events: an append-only, schema-validated JSONL stream.

Every telemetry-enabled run emits a sequence of :class:`RunEvent`
records — run lifecycle, phase spans, engine compiles, eval points —
timestamped on the monotonic ``perf_counter`` clock relative to run
start and tagged with the scenario's ``content_hash`` for provenance.
The stream rides ``RunResult.telemetry["events"]`` and can be written
as JSON Lines via the train CLI's ``--telemetry-out`` (one event per
line, strict RFC 8259, sorted by ``t``).

Schema (``SCHEMA_VERSION``) — each line is an object with exactly:

    kind   str   one of EVENT_KINDS
    t      float seconds since run start (monotonic, >= 0; lines sorted)
    run    str   Scenario.content_hash() of the run
    epoch  int | null  1-based epoch the event refers to (null = run-level)
    data   object      kind-specific payload (see KIND_REQUIRED_DATA)

``validate_event`` / ``validate_events`` / ``validate_jsonl`` check a
record, a stream, or a file against this schema and return a list of
human-readable problems (empty = valid); ``tools/check_scenarios.py
--telemetry`` runs that gate over a live run per algorithm.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional

SCHEMA_VERSION = "repro-telemetry-v1"

#: run lifecycle + the scenario service's per-run queue events
#: (``run_queued`` / ``run_batched`` / ``run_failed`` — emitted by
#: ``repro.serve.service`` against one service-session hash, with the
#: submitted spec's own hash riding in ``data``)
EVENT_KINDS = ("run_start", "phase", "compile", "eval", "run_end",
               "run_queued", "run_batched", "run_failed")

#: data keys each kind must carry (extra keys are allowed)
KIND_REQUIRED_DATA = {
    "run_start": ("algorithm", "engine", "num_agents", "epochs"),
    "phase": ("name", "dur_s"),
    "compile": ("traces",),
    "eval": ("acc",),
    "run_end": ("best_acc", "final_acc", "wall_s"),
    "run_queued": ("rid",),
    "run_batched": ("rid", "wave"),
    "run_failed": ("rid", "error"),
}


@dataclasses.dataclass
class RunEvent:
    kind: str
    t: float                      # seconds since run start (monotonic)
    run: str                      # scenario content hash
    epoch: Optional[int] = None   # 1-based; None = run-level event
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "t": self.t, "run": self.run,
                "epoch": self.epoch, "data": dict(self.data)}


class EventLog:
    """Collects RunEvents against one run's clock and hash."""

    def __init__(self, run_hash: str):
        self.run = run_hash
        self.t0 = time.perf_counter()
        self._events: List[RunEvent] = []

    def emit(self, kind: str, *, epoch: Optional[int] = None,
             at: Optional[float] = None, **data) -> RunEvent:
        """Append an event; ``at`` (an absolute ``perf_counter`` reading)
        backdates it — used for phase spans timestamped at span start."""
        t = (time.perf_counter() if at is None else at) - self.t0
        ev = RunEvent(kind=kind, t=max(t, 0.0), run=self.run, epoch=epoch,
                      data=data)
        self._events.append(ev)
        return ev

    def span_callback(self):
        """An ``on_close`` hook for :class:`~repro.telemetry.spans
        .SpanTimer` that mirrors every span as a ``phase`` event."""
        def on_close(name: str, start: float, dur: float, depth: int):
            self.emit("phase", at=start, name=name, dur_s=dur, depth=depth)
        return on_close

    def to_dicts(self) -> List[Dict[str, Any]]:
        """The event stream as JSON-able dicts, sorted by timestamp."""
        return [e.to_dict() for e in sorted(self._events,
                                            key=lambda e: e.t)]

    def write_jsonl(self, path: str) -> None:
        write_jsonl(path, self.to_dicts())


def write_jsonl(path: str, events: Iterable[Mapping[str, Any]]) -> None:
    """Write events (dicts or RunEvents) as sorted JSON Lines."""
    rows = [e.to_dict() if isinstance(e, RunEvent) else dict(e)
            for e in events]
    rows.sort(key=lambda r: r.get("t", 0.0))
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row, sort_keys=True, allow_nan=False) + "\n")


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def validate_event(d: Mapping[str, Any]) -> List[str]:
    """Problems with one event record (empty list = valid)."""
    problems: List[str] = []
    if not isinstance(d, Mapping):
        return [f"event is not an object: {d!r}"]
    missing = [k for k in ("kind", "t", "run", "epoch", "data") if k not in d]
    if missing:
        problems.append(f"missing key(s) {missing}")
    kind = d.get("kind")
    if kind not in EVENT_KINDS:
        problems.append(f"unknown kind {kind!r}; valid: {list(EVENT_KINDS)}")
    t = d.get("t")
    if not isinstance(t, (int, float)) or isinstance(t, bool) or t < 0:
        problems.append(f"t must be a non-negative number, got {t!r}")
    if not isinstance(d.get("run"), str) or not d.get("run"):
        problems.append(f"run must be a non-empty hash string, "
                        f"got {d.get('run')!r}")
    epoch = d.get("epoch")
    if epoch is not None and (not isinstance(epoch, int)
                              or isinstance(epoch, bool)):
        problems.append(f"epoch must be an int or null, got {epoch!r}")
    data = d.get("data")
    if not isinstance(data, Mapping):
        problems.append(f"data must be an object, got {data!r}")
    elif kind in KIND_REQUIRED_DATA:
        need = [k for k in KIND_REQUIRED_DATA[kind] if k not in data]
        if need:
            problems.append(f"{kind!r} data missing key(s) {need}")
    return problems


def validate_events(events: Iterable[Mapping[str, Any]]) -> List[str]:
    """Problems across a stream: per-event schema + monotone timestamps +
    one shared run hash."""
    problems: List[str] = []
    last_t = None
    runs = set()
    n = 0
    for i, ev in enumerate(events):
        n += 1
        for p in validate_event(ev):
            problems.append(f"event[{i}]: {p}")
        t = ev.get("t") if isinstance(ev, Mapping) else None
        if isinstance(t, (int, float)) and not isinstance(t, bool):
            if last_t is not None and t < last_t:
                problems.append(
                    f"event[{i}]: t={t} precedes previous t={last_t} "
                    f"(stream must be sorted by t)")
            last_t = t
        if isinstance(ev, Mapping):
            runs.add(ev.get("run"))
    if n == 0:
        problems.append("empty event stream")
    if len(runs) > 1:
        problems.append(f"events carry {len(runs)} distinct run hashes: "
                        f"{sorted(map(str, runs))}")
    return problems


def validate_jsonl(path: str) -> List[str]:
    """Validate a JSONL event file (parse errors reported per line)."""
    events: List[Mapping[str, Any]] = []
    problems: List[str] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                problems.append(f"line {lineno}: invalid JSON ({e})")
    return problems + validate_events(events)
