from repro.checkpoint.io import save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.model_store import ModelStore  # noqa: F401
