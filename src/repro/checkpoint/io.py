"""Pytree checkpointing: npz payload + json treedef manifest."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def save_pytree(path: str, tree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def to_np(x):
        arr = np.asarray(x)
        if arr.dtype.isbuiltin != 1:  # extension dtypes (e.g. bfloat16)
            arr = arr.astype(np.float32)
        return arr

    np.savez(path + ".npz", **{f"leaf_{i}": to_np(x)
                               for i, x in enumerate(leaves)})
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "num_leaves": len(leaves)}, f)


def load_pytree(path: str, tree_like):
    """Load into the structure of `tree_like` (shape/dtype template)."""
    data = np.load(path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(leaves) == len(data.files), (
        f"checkpoint has {len(data.files)} leaves, template {len(leaves)}")
    new = [data[f"leaf_{i}"].astype(leaves[i].dtype)
           for i in range(len(leaves))]
    for old, n in zip(leaves, new):
        assert old.shape == n.shape, f"shape mismatch {old.shape} vs {n.shape}"
    return jax.tree_util.tree_unflatten(treedef, new)
