"""Agent model store — the pod-scale cache backing.

At fleet scale the cache is device-resident; at pod scale (huge models,
agents time-multiplexed over the cluster) cached models of *other* agents
live in a host/disk store keyed by (agent, epoch), and the device cache is
streamed from it. This mirrors how a real deployment would checkpoint
exchanged models between DFL rounds.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List

from repro.checkpoint.io import load_pytree, save_pytree


@dataclasses.dataclass
class StoreEntry:
    agent: int
    epoch: int
    samples: float
    group: int
    path: str


class ModelStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")
        self.entries: List[StoreEntry] = []
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self.entries = [StoreEntry(**e) for e in json.load(f)]

    def _save_index(self):
        with open(self._index_path, "w") as f:
            json.dump([dataclasses.asdict(e) for e in self.entries], f)

    def put(self, params, *, agent: int, epoch: int, samples: float,
            group: int = 0) -> StoreEntry:
        path = os.path.join(self.root, f"agent{agent:04d}_ep{epoch:06d}")
        save_pytree(path, params)
        # one live model per agent: newest wins
        self.entries = [e for e in self.entries if e.agent != agent
                        or e.epoch > epoch]
        entry = StoreEntry(agent, epoch, samples, group, path)
        self.entries.append(entry)
        self._save_index()
        return entry

    def evict_stale(self, now_epoch: int, tau_max: int):
        dead = [e for e in self.entries if now_epoch - e.epoch >= tau_max]
        self.entries = [e for e in self.entries
                        if now_epoch - e.epoch < tau_max]
        for e in dead:
            for suffix in (".npz", ".tree.json"):
                try:
                    os.remove(e.path + suffix)
                except FileNotFoundError:
                    pass
        self._save_index()

    def freshest(self, limit: int) -> List[StoreEntry]:
        return sorted(self.entries, key=lambda e: -e.epoch)[:limit]

    def load(self, entry: StoreEntry, template):
        return load_pytree(entry.path, template)
