"""The paper's own model configs (Tables 4-6): CNNs + mini-ResNet.

These are the models Cached-DFL is evaluated with in the AAAI'25 paper:
- MNIST CNN      (Table 4): 2 conv (10, 20 ch, 5x5) + FC 320->50->10
- FashionMNIST CNN (Table 5): 2 conv+BN (16, 32 ch, 5x5) + FC 7*7*32->10
- ResNet-18      (Table 6): for CIFAR-10; we expose a width-scaled variant
  (mini_resnet) so CPU benchmarks stay tractable.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_hw: int
    in_channels: int
    conv_channels: tuple
    kernel: int
    fc_hidden: int          # 0 -> single FC head
    num_classes: int = 10
    batch_norm: bool = False
    source: str = "AAAI'25 Cached-DFL Tables 4-6"


MNIST_CNN = CNNConfig(
    name="paper-mnist-cnn", image_hw=28, in_channels=1,
    conv_channels=(10, 20), kernel=5, fc_hidden=50,
)

FASHION_CNN = CNNConfig(
    name="paper-fashion-cnn", image_hw=28, in_channels=1,
    conv_channels=(16, 32), kernel=5, fc_hidden=0, batch_norm=True,
)

# Width-scaled ResNet stand-in for CIFAR-10 benchmarks on CPU.
MINI_RESNET = CNNConfig(
    name="paper-mini-resnet", image_hw=32, in_channels=3,
    conv_channels=(16, 32, 64), kernel=3, fc_hidden=0,
)

PAPER_CONFIGS = {c.name: c for c in (MNIST_CNN, FASHION_CNN, MINI_RESNET)}
