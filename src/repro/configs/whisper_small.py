"""whisper-small — encoder-decoder, conv frontend stubbed. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a stub per the assignment:
``input_specs()`` provides precomputed frame embeddings [B, frames, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,       # decoder layers
    enc_layers=12,     # encoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    enc_context=1500,
    source="arXiv:2212.04356",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", n_layers=2, enc_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512, enc_context=32,
    )
