"""hymba-1.5b — hybrid: parallel attention + mamba heads. [arXiv:2411.13676]

Attention uses a sliding window (Hymba keeps most layers SWA), which also
makes the long_500k decode shape tractable.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    ssm_state=16,
    ssm_head_dim=64,
    sliding_window=1024,
    source="arXiv:2411.13676",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="hymba-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, head_dim=64, ssm_state=8,
        sliding_window=64,
    )
