"""Model / run configuration dataclasses.

Every assigned architecture gets one file in this package exporting CONFIG
(a ModelConfig with the exact published dimensions, source cited) plus a
``smoke()`` reduced variant for CPU tests (≤2 layers, d_model ≤ 512,
≤4 experts).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # attention details
    sliding_window: int = 0  # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # encoder-decoder (audio)
    enc_layers: int = 0
    enc_context: int = 1500  # fixed encoder frames for decode shapes
    # multimodal stub frontend
    image_tokens: int = 0  # VLM: # of patch-embedding positions per sample
    # numerics
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    moe_token_shard: bool = False   # shard expert token buffers over "model"
    moe_shard_map: bool = False     # shard_map-local MoE dispatch (§Perf):
                                    # keeps sort/scatter per data shard so
                                    # GSPMD never gathers the global batch
    kv_quant: bool = False          # int8 KV cache for decode (§Perf):
                                    # halves the memory-bound decode traffic
    # citation for the exact dims
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def enc_dec(self) -> bool:
        return self.enc_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        emb = self.vocab * d
        per_layer = 0
        if not self.attn_free:
            q = d * self.n_heads * h
            kv = 2 * d * self.n_kv_heads * h
            o = self.n_heads * h * d
            per_layer += q + kv + o
            if self.qkv_bias:
                per_layer += (self.n_heads + 2 * self.n_kv_heads) * h
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d if self.family == "ssm" else self.n_heads * h
            n_h = max(1, d_in // self.ssm_head_dim)
            # in_proj (x, z, B, C, dt) + out_proj + A/D/dt_bias
            per_layer += d * (2 * d_in + 2 * self.ssm_state + n_h)
            per_layer += d_in * d + 2 * n_h
        if self.moe_experts:
            per_layer += d * self.moe_experts  # router
            per_layer += self.moe_experts * 3 * d * self.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff  # gated MLP
        per_layer += 2 * d  # norms
        n_blocks = self.n_layers + self.enc_layers
        head = d * self.vocab
        return emb + n_blocks * per_layer + head + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        inactive = (self.moe_experts - self.moe_top_k) * 3 * d * self.d_ff
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in INPUT_SHAPES]}")


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    """Cached-DFL protocol hyperparameters (paper defaults, §B.4)."""
    num_agents: int = 100
    cache_size: int = 10
    tau_max: int = 10
    local_steps: int = 10           # K
    rho: float = 0.0                # proximal coefficient (paper's ρ)
    lr: float = 0.1
    batch_size: int = 64
    epoch_seconds: float = 120.0
    policy: str = "lru"             # any registered cache policy — see
                                    # repro.policies.registry.available()
                                    # (lru/group = paper Alg. 2/3; fifo,
                                    # random, mobility_aware,
                                    # staleness_weighted, priority, ...)
    policy_params: Tuple[Tuple[str, float], ...] = ()
                                    # static (name, value) knobs for score-
                                    # based policies, e.g.
                                    # (("mobility_bias", 8.0),) or
                                    # (("gamma", 0.9),)
    num_groups: int = 0             # >0 enables group-based policy metadata
    aggregate_self: bool = True     # own model always participates
    staleness_decay: float = 1.0    # beyond-paper: α_j ∝ n_j·γ^age (γ=1 = paper)
    # contact-duration-limited transfers: cap how many cache entries one
    # contact can move (a bandwidth budget on gossip.exchange)
    transfer_budget: float = float("inf")
                                    # entries per link per epoch; inf (or
                                    # any negative value) = unlimited,
                                    # 0 = metadata-only contacts
    link_entries_per_step: float = 0.0
                                    # entries per simulation step of
                                    # measured contact duration; 0 = the
                                    # link speed does not constrain
    # sharded engine: half-width of the gossip halo window, in agents.
    # 0 = exact mode (each shard gathers the full fleet as its candidate
    # pool — bit-exact with the fused engine); H > 0 restricts contacts
    # and candidates to the [row-H, row+n_local+H) index window around
    # each shard, so per-shard contact/gossip work is O(n_local * W)
    # instead of O(n_local * N). Spatially-banded mobility (grouped runs:
    # contiguous index blocks = area bands) keeps the dropped contacts
    # near zero; ignored by the fused/legacy engines.
    shard_halo: int = 0
    # open-world churn: a deterministic staggered join/leave schedule.
    # Every ``churn_period`` epochs each agent goes out of coverage for
    # ``round(churn_fraction * churn_period)`` consecutive epochs, with
    # per-agent phase offsets spread uniformly over the period so roughly
    # a ``churn_fraction`` share of the fleet is away at any epoch.
    # Dead agents don't train, never appear as realized partners, and
    # their caches freeze — but entries they already gossiped keep
    # spreading through live carriers (the DTN effect). 0 = closed world
    # (every agent always live; engines are bit-exact with no churn code).
    churn_period: int = 0           # epochs per join/leave cycle; 0 = off
    churn_fraction: float = 0.0     # fraction of each cycle spent away

    @property
    def churn_enabled(self) -> bool:
        """True when the join/leave schedule actually removes agents."""
        return (self.churn_period > 0
                and round(self.churn_fraction * self.churn_period) > 0)

    @property
    def resolved_transfer_budget(self) -> Optional[float]:
        """The flat per-link cap, or None when unlimited (inf/negative) —
        so an 'unlimited' sentinel never reaches the exchange as a cap."""
        tb = self.transfer_budget
        return tb if (math.isfinite(tb) and tb >= 0) else None

    @property
    def transfer_budget_enabled(self) -> bool:
        """True when either budget knob actually limits the exchange."""
        return (self.link_entries_per_step > 0
                or self.resolved_transfer_budget is not None)


@dataclasses.dataclass(frozen=True)
class MobilityConfig:
    """Mobility scenario config; ``model`` picks a registered mobility model.

    Registered models (see ``repro.mobility.registry``): ``manhattan``
    (paper §4.4 grid), ``random_waypoint``, ``levy_walk``, ``community``
    (RPGM group mobility), ``trace`` (contact-schedule replay). Shared
    fields come first; per-model fields are grouped below and ignored by
    models that don't use them.
    """
    model: str = "manhattan"
    speed: float = 13.89            # m/s (manhattan / levy cruise speed)
    comm_range: float = 100.0       # meters
    step_seconds: float = 1.0       # sim integration step
    num_bands: int = 3              # area bands for group-restricted runs
    # --- manhattan grid (paper §4.4) ---
    p_straight: float = 0.5
    grid_w: int = 10                # intersections east-west
    grid_h: int = 30                # intersections north-south
    block_w: float = 274.0          # meters between avenues
    block_h: float = 80.0           # meters between streets
    # --- continuous plane (random_waypoint / levy_walk / community) ---
    area_w: float = 2000.0          # meters
    area_h: float = 2000.0          # meters
    # --- random waypoint ---
    v_min: float = 5.0              # m/s, per-leg speed draw
    v_max: float = 15.0
    pause_max: float = 0.0          # seconds of pause at each waypoint
    # --- levy walk (truncated power-law flight lengths) ---
    levy_alpha: float = 1.5         # tail exponent, P(l) ∝ l^-(1+α)
    levy_min_flight: float = 20.0   # meters
    levy_max_flight: float = 2000.0
    # --- community / RPGM group mobility ---
    community_radius: float = 150.0 # members orbit within this of the center
    center_speed: float = 5.0       # m/s, group-center waypoint speed
    roam_prob: float = 0.05         # chance a member leg roams the full area
    # --- contact-trace replay ---
    trace_path: str = ""            # .npz with contacts [T,N,N] or edge list
    trace_frames_per_epoch: int = 0 # 0 -> int(epoch_seconds / step_seconds)
    trace_loop: bool = True         # wrap around vs hold last frame
    # --- diurnal contact-intensity envelope (all models) ---
    # Time-varying contact load: a simulation step at in-epoch time τ
    # registers contacts only while the activity
    #   g(τ) = (1 + cos(2π (τ + diurnal_phase) / diurnal_period)) / 2
    # is at least ``diurnal_amplitude`` — a cosine day/night cycle whose
    # duty ratio shrinks as the amplitude grows. Trajectories still
    # advance every step (vehicles keep moving off-peak; only the radio
    # contact process is modulated). 0 amplitude = the stationary contact
    # process, bit-exact with the envelope-free models.
    diurnal_period: float = 86400.0 # seconds per activity cycle
    diurnal_amplitude: float = 0.0  # 0 = always active … →1 = peaks only
    diurnal_phase: float = 0.0      # seconds of phase offset into the cycle

    @property
    def diurnal_enabled(self) -> bool:
        """True when the envelope actually gates any contacts."""
        return self.diurnal_amplitude > 0.0
