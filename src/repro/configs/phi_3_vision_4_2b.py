"""phi-3-vision-4.2b — phi3-mini LM backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    image_tokens=1024,  # projector output positions consumed by the LM
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="phi-3-vision-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512, image_tokens=8,
    )
