from repro.configs.base import (  # noqa: F401
    DFLConfig,
    INPUT_SHAPES,
    InputShape,
    MobilityConfig,
    ModelConfig,
    get_shape,
)
