"""mamba2-780m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    source="arXiv:2405.21060",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=128, vocab=512,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
    )
