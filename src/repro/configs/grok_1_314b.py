"""grok-1-314b — MoE, 8 experts top-2, GQA kv=8. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    moe_experts=8,
    moe_top_k=2,
    source="hf:xai-org/grok-1",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="grok-1-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, moe_experts=4, moe_top_k=2,
    )
