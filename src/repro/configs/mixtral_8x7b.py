"""mixtral-8x7b — MoE 8 experts top-2, GQA kv=8, SWA 4096. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    moe_experts=8,
    moe_top_k=2,
    sliding_window=4096,
    source="arXiv:2401.04088",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, moe_experts=4, moe_top_k=2,
        sliding_window=64,
    )
