"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

from repro.configs import (
    deepseek_67b,
    grok_1_314b,
    hymba_1_5b,
    internlm2_1_8b,
    internlm2_20b,
    mamba2_780m,
    mixtral_8x7b,
    phi_3_vision_4_2b,
    qwen2_7b,
    whisper_small,
)
from repro.configs.base import ModelConfig

_MODULES = {
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "grok-1-314b": grok_1_314b,
    "internlm2-1.8b": internlm2_1_8b,
    "qwen2-7b": qwen2_7b,
    "mamba2-780m": mamba2_780m,
    "mixtral-8x7b": mixtral_8x7b,
    "hymba-1.5b": hymba_1_5b,
    "deepseek-67b": deepseek_67b,
    "internlm2-20b": internlm2_20b,
    "whisper-small": whisper_small,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke()


def supports_shape(cfg: ModelConfig, shape_name: str) -> bool:
    """Assignment rules: long_500k requires sub-quadratic attention."""
    if shape_name != "long_500k":
        return True
    if cfg.family == "ssm":
        return True
    return cfg.sliding_window > 0


def skip_reason(cfg: ModelConfig, shape_name: str) -> str:
    if supports_shape(cfg, shape_name):
        return ""
    return (
        f"{cfg.name}: full quadratic attention; long_500k decode would need "
        "a 524288-token dense KV cache (skip sanctioned by assignment)"
    )
