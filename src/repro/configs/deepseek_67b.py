"""deepseek-67b — dense llama-arch GQA kv=8. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    source="arXiv:2401.02954",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="deepseek-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512,
    )
