"""internlm2-1.8b — dense GQA kv=8. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    source="arXiv:2403.17297",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="internlm2-1.8b-smoke", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
    )
