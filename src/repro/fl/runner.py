"""Execute Scenarios: ``run(scenario) -> RunResult`` and the
compile-aware grid runner ``sweep(base, axes) -> SweepResult``.

``run`` subsumes the old ``run_experiment`` ad-hoc kwargs — engine
choice, verbosity and cache-stat recording ride in the Scenario — and
returns a typed :class:`RunResult` (metric arrays, best/final accuracy,
engine/trace/wall-clock stats, config snapshot + content hash) instead
of an untyped dict. ``run_experiment`` in ``fl/experiment.py`` remains
as a thin compatibility shim over this module.

``sweep`` partitions axes into *traced* knobs (``dfl.lr``,
``dfl.transfer_budget``, ``epochs`` — changing them never retraces the
fused engine) and *trace-static* knobs (algorithm, policy, shapes, ...),
orders the grid so trace-static combinations are outer and traced
combinations inner, and shares one :class:`FleetEngine` per static
combination across all of its cells — asserting in accounting (and the
tests pin it) the fused engine's one-trace-per-(algorithm, shape)
guarantee through the new API.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import itertools
import json
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import rounds as rounds_lib
from repro.fl.scenario import (Fleet, ResolvedScenario, Scenario, _encode)
from repro.mobility.base import partners_from_contacts
from repro.optim.schedules import ReduceLROnPlateau
from repro.telemetry import events as events_lib
from repro.telemetry import metrics as metrics_lib
from repro.telemetry import spans as spans_lib

#: dotted override paths the fused engine treats as traced scalars —
#: sweeping them reuses the compiled executable (no retrace).
TRACED_AXES = frozenset({"dfl.lr", "dfl.transfer_budget", "epochs"})


# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    """Typed outcome of one Scenario run (JSON-able via ``to_dict``)."""
    scenario: Scenario
    config_hash: str
    engine: str
    epoch: List[int]
    acc: List[float]
    lr: List[float]
    cache_num: List[float]
    cache_age: List[float]
    best_acc: float
    best_epoch: int               # 1-based epoch of the best accuracy
    final_acc: float
    traces: int                   # engine retraces charged to this run
    wall_s: float
    phase_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    telemetry: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario.to_dict(),
            "config_hash": self.config_hash,
            "engine": self.engine,
            "metrics": {"epoch": self.epoch, "acc": self.acc,
                        "lr": self.lr, "cache_num": self.cache_num,
                        "cache_age": self.cache_age},
            "best_acc": self.best_acc, "best_epoch": self.best_epoch,
            "final_acc": self.final_acc, "traces": self.traces,
            "wall_s": self.wall_s,
            "phase_s": dict(self.phase_s),
            "telemetry": self.telemetry,
        }

    def to_json(self, **dumps_kwargs) -> str:
        dumps_kwargs.setdefault("indent", 1)
        dumps_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **dumps_kwargs)

    def history(self) -> Dict[str, Any]:
        """The legacy ``run_experiment`` dict (compatibility shim)."""
        return {"epoch": list(self.epoch), "acc": list(self.acc),
                "lr": list(self.lr), "cache_num": list(self.cache_num),
                "cache_age": list(self.cache_age),
                "epoch_traces": self.traces, "engine": self.engine,
                "best_acc": self.best_acc, "final_acc": self.final_acc,
                "wall_s": self.wall_s}


# ---------------------------------------------------------------------------
# run
# ---------------------------------------------------------------------------

def _engine_key(rs: ResolvedScenario, chunk: int, traced_budget: bool,
                telemetry: bool = False):
    """Everything that forces a distinct fused/sharded engine: static
    trace bindings + array shapes. Traced scalars (lr, epoch budget, and
    — in traced-budget mode — the transfer budget) are zeroed out so
    sweeps over them share one engine. ``telemetry`` is a static binding
    (the metrics carry changes the trace), so telemetry-on and -off cells
    never share an engine; so are the engine kind and mesh size (a
    ``mesh`` axis sweeps device counts as one engine per count)."""
    cfg = rs.experiment
    dfl_static = dataclasses.replace(
        cfg.dfl, lr=0.0,
        transfer_budget=0.0 if traced_budget else cfg.dfl.transfer_budget)
    return (rs.scenario.engine, rs.scenario.mesh,
            cfg.algorithm, cfg.distribution, cfg.num_groups,
            cfg.max_partners, cfg.partner_sample, cfg.n_train, cfg.n_test,
            rs.model_cfg, rs.mobility, dfl_static, chunk, traced_budget,
            telemetry)


def engine_cache_key(scenario: Scenario, *,
                     force_traced_budget: bool = False):
    """The (hashable) engine-cache key ``run(scenario, engines=...)`` will
    look up for this spec — the scenario service groups submitted specs by
    it so same-key waves share one compiled engine. Two scenarios with
    equal keys differ only in traced knobs (lr / epochs / seed-side state,
    and the transfer budget under ``force_traced_budget``), which the
    engine accepts per call without retracing."""
    rs = scenario.resolve()
    cfg = rs.experiment
    traced_budget = force_traced_budget and cfg.algorithm == "cached"
    return _engine_key(rs, cfg.eval_every, traced_budget,
                       scenario.telemetry)


def run(scenario: Scenario, *,
        engines: Optional[Dict[Any, rounds_lib.FleetEngine]] = None,
        force_traced_budget: bool = False) -> RunResult:
    """Run one Scenario end to end.

    ``engines`` is an optional cache mapping engine keys to live
    ``FleetEngine`` objects; ``sweep`` passes one so cells that differ
    only in traced knobs reuse a compiled executable. With
    ``force_traced_budget`` the per-link transfer budget is always passed
    as a traced scalar (unlimited = +inf, bit-exact with the unbudgeted
    path), so a budget axis never retraces.

    With ``scenario.telemetry`` the result additionally carries
    ``phase_s`` (build/compile/dispatch/eval wall breakdown) and a
    ``telemetry`` dict: on-device fleet metrics (staleness histogram,
    model spread, gossip traffic, budget utilization), per-eval accuracy
    dispersion + encounter-rate drift, span aggregates and the
    schema-validated structured event stream (see ``repro.telemetry``).
    The model trajectory is bit-exact with a telemetry-off run.
    """
    rs = scenario.resolve()
    spans = events = None
    if scenario.telemetry:
        events = events_lib.EventLog(scenario.content_hash())
        spans = spans_lib.SpanTimer(on_close=events.span_callback())
        cfg = scenario.experiment
        events.emit("run_start", algorithm=cfg.algorithm,
                    engine=scenario.engine,
                    num_agents=cfg.dfl.num_agents, epochs=cfg.epochs)
        with spans.span("build"):
            fleet = rs.build_fleet()
    else:
        fleet = rs.build_fleet()
    return _drive(rs, fleet, engines=engines,
                  force_traced_budget=force_traced_budget,
                  spans=spans, events=events)


def _drive(rs: ResolvedScenario, fleet: Fleet, *,
           engines: Optional[Dict[Any, rounds_lib.FleetEngine]] = None,
           force_traced_budget: bool = False,
           spans: Optional[spans_lib.SpanTimer] = None,
           events: Optional[events_lib.EventLog] = None) -> RunResult:
    from repro.fl import experiment as experiment_lib  # shim-free builders

    scenario = rs.scenario
    cfg = rs.experiment
    verbose = scenario.verbose
    record_cache_stats = scenario.record_cache_stats
    engine = scenario.engine
    telemetry = scenario.telemetry
    if telemetry and events is None:
        events = events_lib.EventLog(scenario.content_hash())
    if telemetry and spans is None:
        spans = spans_lib.SpanTimer(on_close=events.span_callback())

    state, mstate = fleet.state, fleet.mobility_state
    data, counts, test_batch = fleet.data, fleet.counts, fleet.test_batch
    loss_fn = fleet.loss_fn()
    # churn runs report the live-agent average (out-of-coverage agents'
    # frozen models shouldn't drag the fleet metric); static flag, so
    # churn-free evals compile the exact pre-churn program
    eval_fn = jax.jit(functools.partial(rounds_lib.fleet_eval,
                                        acc_fn=fleet.acc_fn(),
                                        live_only=cfg.dfl.churn_enabled))
    # dispersion stays its own jit unit so telemetry can't perturb eval
    disp_fn = (jax.jit(functools.partial(rounds_lib.fleet_dispersion,
                                         acc_fn=fleet.acc_fn()))
               if telemetry else None)

    sched = ReduceLROnPlateau(lr=cfg.dfl.lr)
    lr = cfg.dfl.lr
    key = jax.random.PRNGKey(cfg.seed + 2)
    epochs_hist: List[int] = []
    acc_hist: List[float] = []
    lr_hist: List[float] = []
    cache_num_hist: List[float] = []
    cache_age_hist: List[float] = []
    # telemetry-only per-eval series (accuracy dispersion, contact drift)
    disp_hist: Dict[str, List[float]] = {"acc_std": [], "acc_min": [],
                                         "acc_max": []}
    contacts_at_eval: List[float] = []
    metrics = None
    if telemetry and engine in ("fused", "sharded"):
        metrics = metrics_lib.init_metrics(cfg.dfl.num_agents,
                                           cfg.dfl.tau_max + 1)
    best, best_epoch = -1.0, 0
    stop = False
    t0 = time.perf_counter()

    # repro: allow=RPR004 eval boundary: scalars-only host transfer once per eval_every epochs
    def evaluate(ep):
        """Eval at 0-based epoch index ep; returns True to early-stop."""
        nonlocal lr, best, best_epoch
        with (spans.span("eval") if spans is not None
              else contextlib.nullcontext()):
            acc, cache_num, cache_age = eval_fn(state,
                                                test_batch=test_batch)
            if telemetry:
                acc_std, acc_min, acc_max = disp_fn(state,
                                                    test_batch=test_batch)
        if telemetry:
            disp_hist["acc_std"].append(float(acc_std))
            disp_hist["acc_min"].append(float(acc_min))
            disp_hist["acc_max"].append(float(acc_max))
            if metrics is not None:
                contacts_at_eval.append(float(metrics.contacts))
        acc = float(acc)                     # scalars only cross to host
        epochs_hist.append(ep + 1)
        acc_hist.append(acc)
        lr_hist.append(lr)
        if record_cache_stats:
            cache_num_hist.append(float(cache_num))
            cache_age_hist.append(float(cache_age))
        if events is not None:
            events.emit("eval", epoch=ep + 1, acc=acc, lr=lr)
        if cfg.lr_plateau:
            lr = sched.update(acc)           # traced arg: no retrace on change
        if acc > best + 1e-4:
            best, best_epoch = acc, ep
        elif ep - best_epoch >= cfg.early_stop_patience:
            if verbose:
                print(f"early stop at epoch {ep + 1}")
            return True
        if verbose:
            print(f"epoch {ep + 1:4d} acc={acc:.4f} lr={lr:.4f} "
                  f"({time.perf_counter() - t0:.1f}s)")
        return False

    # budget sweeps pass the (traced) cap per engine call — never retraces;
    # None = no flat cap (a duration-derived cap may still apply via
    # link_entries_per_step, bound statically below)
    resolved_budget = cfg.dfl.resolved_transfer_budget
    traced_budget = (force_traced_budget and cfg.algorithm == "cached")
    if traced_budget:
        budget = jnp.float32(resolved_budget if resolved_budget is not None
                             else jnp.inf)
    else:
        budget = (jnp.float32(resolved_budget)
                  if resolved_budget is not None else None)

    span = (spans.span if spans is not None
            else (lambda name: contextlib.nullcontext()))
    traces = 0
    if engine in ("fused", "sharded"):
        key_ = _engine_key(rs, cfg.eval_every, traced_budget, telemetry)
        eng = None if engines is None else engines.get(key_)
        if eng is None:
            with span("compile"):
                if engine == "sharded":
                    from repro.launch import mesh as mesh_lib
                    eng = experiment_lib.make_sharded_engine(
                        cfg,
                        mesh=mesh_lib.make_fleet_mesh(scenario.mesh or None),
                        loss_fn=loss_fn, mob_model=fleet.mob_model,
                        mob_cfg=fleet.mobility,
                        group_slots=fleet.group_slots, telemetry=telemetry)
                else:
                    eng = experiment_lib.make_engine(
                        cfg, loss_fn=loss_fn, mob_model=fleet.mob_model,
                        mob_cfg=fleet.mobility,
                        group_slots=fleet.group_slots, telemetry=telemetry)
            if engines is not None:
                engines[key_] = eng
        traces0 = eng.traces
        ep = 0
        while ep < cfg.epochs and not stop:
            n = min(eng.chunk, cfg.epochs - ep)
            with span("dispatch"):
                if telemetry:
                    state, mstate, key, _, metrics = eng.run(
                        state, mstate, key, lr, data, counts, n, budget,
                        metrics)
                elif budget is None:
                    state, mstate, key, _ = eng.run(state, mstate, key, lr,
                                                    data, counts, n)
                else:
                    state, mstate, key, _ = eng.run(state, mstate, key, lr,
                                                    data, counts, n, budget)
            ep += n
            # evaluate on the cadence AND at the terminal epoch: a tail
            # chunk shorter than eval_every (epochs not a multiple, or an
            # early-stop truncation) must still land in the history
            if ep % cfg.eval_every == 0 or ep == cfg.epochs:
                stop = evaluate(ep - 1)
        traces = eng.traces - traces0
    elif engine == "legacy":
        with span("compile"):
            epoch_fn, counter = experiment_lib.make_epoch_fn(
                cfg, loss_fn=loss_fn, group_slots=fleet.group_slots,
                telemetry=telemetry)
            sim = jax.jit(functools.partial(fleet.mob_model.simulate_epoch,
                                            cfg=fleet.mobility,
                                            seconds=cfg.dfl.epoch_seconds))
        if telemetry:
            metrics = metrics_lib.init_metrics(cfg.dfl.num_agents,
                                               cfg.dfl.tau_max + 1)
            accumulate = jax.jit(metrics_lib.accumulate)
        for ep in range(cfg.epochs):
            # deterministic partner selection keeps the historical key stream
            if cfg.partner_sample == "lowest-id":
                key, k1, k2 = jax.random.split(key, 3)
                k3 = None
            else:
                key, k1, k2, k3 = jax.random.split(key, 4)
            with span("dispatch"):
                mstate, met, dur = sim(mstate, k1)
                if cfg.dfl.churn_enabled:
                    live = rounds_lib.liveness_mask(
                        state.t, cfg.dfl.num_agents, cfg.dfl.churn_period,
                        cfg.dfl.churn_fraction)
                    met = met & live[:, None] & live[None, :]
                    state = dataclasses.replace(state, live=live)
                partners = partners_from_contacts(
                    met, cfg.max_partners, sample=cfg.partner_sample, key=k3)
                if telemetry:
                    state, _, xstats = epoch_fn(state, partners, dur, data,
                                                counts, k2, lr)
                    metrics = accumulate(metrics, state, partners, xstats)
                else:
                    state, _ = epoch_fn(state, partners, dur, data, counts,
                                        k2, lr)
            if (ep + 1) % cfg.eval_every == 0 or (ep + 1) == cfg.epochs:
                if evaluate(ep):
                    break
        traces = counter["traces"]
    else:
        raise ValueError(f"unknown engine {engine!r}")

    wall_s = time.perf_counter() - t0
    phase_s: Dict[str, float] = {}
    telem: Optional[Dict[str, Any]] = None
    if telemetry:
        events.emit("compile", traces=traces)
        events.emit("run_end", best_acc=best,
                    final_acc=acc_hist[-1] if acc_hist else 0.0,
                    wall_s=wall_s)
        phase_s = spans.totals()
        telem = _assemble_telemetry(
            metrics=metrics, spans=spans, events=events,
            epochs_hist=epochs_hist, disp_hist=disp_hist,
            contacts_at_eval=contacts_at_eval)

    return RunResult(
        scenario=scenario, config_hash=scenario.content_hash(),
        engine=engine, epoch=epochs_hist, acc=acc_hist, lr=lr_hist,
        cache_num=cache_num_hist, cache_age=cache_age_hist,
        best_acc=best, best_epoch=best_epoch + 1,
        final_acc=acc_hist[-1] if acc_hist else 0.0,
        traces=traces, wall_s=wall_s, phase_s=phase_s, telemetry=telem)


def _assemble_telemetry(*, metrics, spans, events, epochs_hist, disp_hist,
                        contacts_at_eval) -> Dict[str, Any]:
    """Reduce the run's accumulators into the ``RunResult.telemetry``
    dict: on-device fleet metrics summary, per-eval accuracy dispersion,
    encounter-rate drift (contacts per epoch within each eval window,
    from the cumulative contact counter read at eval points), span
    aggregates and the structured event stream."""
    fleet_summary = (metrics_lib.summarize(metrics)
                     if metrics is not None else None)
    drift: List[float] = []
    if contacts_at_eval and epochs_hist:
        prev_c, prev_ep = 0.0, 0
        for c, ep in zip(contacts_at_eval, epochs_hist):
            n = max(ep - prev_ep, 1)
            drift.append((c - prev_c) / n)
            prev_c, prev_ep = c, ep
    return {
        "schema": events_lib.SCHEMA_VERSION,
        "fleet": fleet_summary,
        "eval": {"epoch": list(epochs_hist),
                 "acc_std": list(disp_hist["acc_std"]),
                 "acc_min": list(disp_hist["acc_min"]),
                 "acc_max": list(disp_hist["acc_max"]),
                 "contacts_per_epoch": drift},
        "spans": spans.summary(),
        "events": events.to_dicts(),
    }


def telemetry_line(result: RunResult) -> str:
    """One-line human summary of a run's telemetry (quickstart / CLI)."""
    t = result.telemetry
    if not t:
        return "telemetry: off"
    f = t.get("fleet") or {}
    util = f.get("budget_utilization")
    util_s = f"{util:.0%}" if util is not None else "n/a"
    phases = " ".join(f"{k}={v:.2f}s"
                      for k, v in sorted(result.phase_s.items()))
    return (f"telemetry: staleness {f.get('staleness_mean', 0.0):.2f} "
            f"(p95 {f.get('staleness_p95', 0)}) "
            f"reach {f.get('reach_fraction', 0.0):.0%} "
            f"admitted/epoch {f.get('admitted_per_epoch', 0.0):.1f} "
            f"budget-util {util_s} "
            f"events {len(t.get('events', []))}; {phases}")


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepCell:
    overrides: Dict[str, Any]     # the axes values this cell ran with
    result: RunResult

    def to_dict(self) -> Dict[str, Any]:
        r = self.result
        out = {
            "overrides": {k: _encode(v) for k, v in self.overrides.items()},
            "config_hash": r.config_hash,
            "best_acc": r.best_acc, "final_acc": r.final_acc,
            "best_epoch": r.best_epoch,
            "cache_num": r.cache_num[-1] if r.cache_num else None,
            "cache_age": r.cache_age[-1] if r.cache_age else None,
            "epochs_run": r.epoch[-1] if r.epoch else 0,
            "traces": r.traces, "wall_s": r.wall_s,
        }
        if r.telemetry is not None:
            out["telemetry"] = _cell_telemetry(r.telemetry)
        return out


#: per-cell telemetry summary columns carried into sweep/bench artifacts
_CELL_TELEMETRY_KEYS = ("staleness_mean", "staleness_p95", "spread_mean",
                        "reach_fraction", "admitted_per_epoch",
                        "budget_utilization", "contacts_per_epoch")


def _cell_telemetry(telem: Mapping[str, Any]) -> Dict[str, Any]:
    """The compact per-cell telemetry record for sweep tables: the fleet
    summary columns a dashboard plots per grid point (staleness vs
    accuracy, budget-utilization frontier), not the full event stream."""
    fleet = telem.get("fleet") or {}
    return {k: fleet.get(k) for k in _CELL_TELEMETRY_KEYS}


@dataclasses.dataclass
class SweepResult:
    """Tidy per-cell records of a grid sweep, with engine accounting."""
    base: Scenario
    axes: Dict[str, List[Any]]
    cells: List[SweepCell]
    engine_traces: Dict[str, int]  # engine key repr -> total traces
    wall_s: float

    @property
    def num_engines(self) -> int:
        return len(self.engine_traces)

    @property
    def retraces(self) -> int:
        """Traces beyond the guaranteed one-per-engine — 0 when the fused
        engine's no-retrace guarantee holds through the sweep."""
        return sum(self.engine_traces.values()) - self.num_engines

    def select(self, **conditions) -> List[SweepCell]:
        """Cells whose overrides match every ``axis=value`` condition
        (axis names may use '_' in place of the group '.', e.g.
        ``dfl_transfer_budget``)."""
        def match(cell):
            for k, v in conditions.items():
                key = k if k in cell.overrides else k.replace("_", ".", 1)
                if cell.overrides.get(key) != v:
                    return False
            return True
        return [c for c in self.cells if match(c)]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "base": self.base.to_dict(),
            "base_config_hash": self.base.content_hash(),
            "axes": {k: [_encode(v) for v in vs]
                     for k, vs in self.axes.items()},
            "cells": [c.to_dict() for c in self.cells],
            "engines": dict(self.engine_traces),
            "num_engines": self.num_engines,
            "retraces": self.retraces,
            "wall_s": self.wall_s,
        }

    def to_json(self, **dumps_kwargs) -> str:
        dumps_kwargs.setdefault("indent", 1)
        dumps_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **dumps_kwargs)

    def write_bench(self, path: str, *, name: str = "",
                    fast: Optional[bool] = None,
                    extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Emit the shared benchmark-artifact schema (config hash,
        per-cell metrics, retrace count) — the one JSON writer every
        ``BENCH_*.json`` benchmark goes through."""
        doc = {"bench": name, "schema": "sweep-v1"}
        if fast is not None:
            doc["fast"] = fast
        doc.update(self.to_dict())
        if extra:
            doc["extra"] = {k: _encode(v) for k, v in extra.items()}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        return doc


def sweep(base: Scenario, axes: Mapping[str, Sequence[Any]], *,
          adjust: Optional[Callable[[Dict[str, Any]],
                                    Optional[Dict[str, Any]]]] = None,
          verbose: bool = False) -> SweepResult:
    """Run the full grid ``axes`` over ``base`` with engine reuse.

    ``axes`` maps dotted override paths (see
    ``Scenario.with_overrides``) to value sequences. Axes in
    :data:`TRACED_AXES` are traced scalars of the fused engine — the
    sweep orders them innermost and reuses one engine per trace-static
    combination, so e.g. a ``transfer_budget x lr`` grid compiles exactly
    once per (algorithm, shape). ``adjust`` may return extra per-cell
    overrides derived from the grid point (e.g. switching to the grouped
    distribution for policies that need group slots); derived overrides
    are recorded in the cell.
    """
    static_axes = [(k, list(v)) for k, v in axes.items()
                   if k not in TRACED_AXES]
    traced_axes = [(k, list(v)) for k, v in axes.items() if k in TRACED_AXES]
    cells: List[SweepCell] = []
    t0 = time.perf_counter()
    # traced budget mode keeps a budget axis from splitting engines
    budget_axis = "dfl.transfer_budget" in axes
    # bounded LRU engine cache: cells that differ only in traced knobs —
    # or repeat a trace-static combination (e.g. a seed axis) — reuse a
    # live engine, while a long static grid doesn't keep every compiled
    # executable alive at once (evicted engines log their trace count)
    retired: List[int] = []
    engines = _EngineCache(maxsize=2,
                           on_evict=lambda e: retired.append(e.traces))

    for static_vals in itertools.product(*(v for _, v in static_axes)):
        for traced_vals in itertools.product(*(v for _, v in traced_axes)):
            overrides: Dict[str, Any] = dict(
                zip((k for k, _ in static_axes), static_vals))
            overrides.update(
                zip((k for k, _ in traced_axes), traced_vals))
            if adjust is not None:
                overrides.update(adjust(dict(overrides)) or {})
            cell_scenario = base.with_overrides(overrides)
            result = run(cell_scenario, engines=engines,
                         force_traced_budget=budget_axis)
            cells.append(SweepCell(overrides=overrides, result=result))
            if verbose:
                label = ",".join(f"{k}={_encode(v)}"
                                 for k, v in overrides.items())
                print(f"sweep[{label}] best={result.best_acc:.4f} "
                      f"traces={result.traces} ({result.wall_s:.1f}s)")

    retired.extend(eng.traces for eng in engines.values())
    engine_traces = {f"engine{idx}": t for idx, t in enumerate(retired)}
    return SweepResult(base=base, axes={k: list(v) for k, v in axes.items()},
                       cells=cells, engine_traces=engine_traces,
                       wall_s=time.perf_counter() - t0)


class _EngineCache(collections.OrderedDict):
    """LRU mapping of engine keys to live FleetEngines; evicted engines
    report their trace count through ``on_evict`` so the sweep's retrace
    accounting stays complete."""

    def __init__(self, *, maxsize: int, on_evict: Callable[[Any], None]):
        super().__init__()
        self.maxsize = maxsize
        self.on_evict = on_evict

    def get(self, key, default=None):
        if key not in self:
            return default
        self.move_to_end(key)
        return super().__getitem__(key)

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            _, evicted = self.popitem(last=False)
            self.on_evict(evicted)
