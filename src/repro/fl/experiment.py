"""Reusable fleet-experiment harness — the engine behind train.py, the
benchmarks (one per paper figure/table) and the examples.

Reproduces the paper's experimental loop: mobility (any registered model,
selected by ``MobilityConfig.model``) → contacts → Cached-DFL / DFL / CFL
epochs → average-test-accuracy metric with ReduceLROnPlateau and early
stopping.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DFLConfig, MobilityConfig
from repro.configs.paper_models import CNNConfig, PAPER_CONFIGS
from repro.core import rounds as rounds_lib
from repro.data.synthetic import make_image_dataset
from repro.fl import partition as part_lib
from repro.mobility import registry as mob_registry
from repro.mobility import stats as mob_stats
from repro.mobility.base import make_bands, partners_from_contacts
from repro.models import cnn as cnn_lib
from repro.optim.schedules import ReduceLROnPlateau
from repro.policies import registry as policy_registry


@dataclasses.dataclass
class ExperimentConfig:
    model: str = "paper-mnist-cnn"
    distribution: str = "noniid"      # iid | noniid | dirichlet | grouped
    algorithm: str = "cached"         # cached | dfl | cfl
    dfl: DFLConfig = dataclasses.field(default_factory=DFLConfig)
    mobility: MobilityConfig = dataclasses.field(
        default_factory=MobilityConfig)
    epochs: int = 50
    eval_every: int = 1
    seed: int = 0
    n_train: int = 6000
    n_test: int = 1000
    image_hw: int = 0                 # 0 -> model default
    max_partners: int = 4
    partner_sample: str = "lowest-id"  # lowest-id | random (radio budget)
    early_stop_patience: int = 20
    dirichlet_pi: float = 0.5
    overlap: int = 0                  # grouped: label overlap between areas
    num_groups: int = 3
    lr_plateau: bool = True


def _area_labels(num_groups: int, overlap: int, num_classes: int = 10):
    """n-overlap label allocation (paper appendix B.1.1)."""
    base = [list(range(0, 4)), list(range(4, 7)), list(range(7, 10))]
    if num_groups != 3:
        per = num_classes // num_groups
        base = [list(range(g * per, min((g + 1) * per, num_classes)))
                for g in range(num_groups)]
    out = []
    for g, labels in enumerate(base):
        l = list(labels)
        for k in range(1, overlap + 1):
            l.append((labels[0] - k) % num_classes)   # borrow neighbors
        out.append(sorted(set(l)))
    return out


def resolve_policy_setup(cfg: ExperimentConfig):
    """Resolve + validate the cache policy once at config resolution.

    Returns ``(policy, policy_params)``. Raises ValueError naming the
    offending config fields for inconsistent setups (instead of failing
    mid-trace inside ``gossip.exchange``), e.g. a group policy without a
    grouped distribution or with fewer cache slots than groups.
    """
    pol = policy_registry.resolve(cfg.dfl.policy)
    params = dict(cfg.dfl.policy_params)
    if cfg.algorithm != "cached" and cfg.dfl.transfer_budget_enabled:
        raise ValueError(
            "DFLConfig.transfer_budget / link_entries_per_step bound the "
            "cached algorithm's cache exchange and have no effect on "
            f"algorithm={cfg.algorithm!r} — unset them (or use "
            "algorithm='cached') rather than sweeping a no-op knob")
    unknown = sorted(set(params) - set(pol.knobs) - {"gamma"})
    if unknown:
        raise ValueError(
            f"DFLConfig.policy_params has unknown knob(s) {unknown} for "
            f"policy {pol.name!r}; accepted: "
            f"{sorted(set(pol.knobs) | {'gamma'})}")
    if cfg.algorithm == "cached" and pol.needs_group_slots:
        if cfg.distribution != "grouped":
            raise ValueError(
                f"DFLConfig.policy={pol.name!r} needs per-group cache "
                f"slots, which require ExperimentConfig.distribution="
                f"'grouped' (got {cfg.distribution!r})")
        if cfg.num_groups <= 0:
            raise ValueError(
                f"DFLConfig.policy={pol.name!r} requires "
                f"ExperimentConfig.num_groups > 0 "
                f"(got {cfg.num_groups})")
        if cfg.dfl.cache_size < cfg.num_groups:
            raise ValueError(
                f"DFLConfig.cache_size={cfg.dfl.cache_size} < "
                f"ExperimentConfig.num_groups={cfg.num_groups}: the "
                f"{pol.name!r} policy needs at least one slot per group")
    return pol, params


def build_fleet(cfg: ExperimentConfig):
    """Returns (model_cfg, state, data, counts, test_batch, mobility_state,
    group_slots, mob_model, mob_cfg)."""
    policy, policy_params = resolve_policy_setup(cfg)  # fail fast if bad
    model_cfg: CNNConfig = PAPER_CONFIGS[cfg.model]
    if cfg.image_hw:
        model_cfg = dataclasses.replace(model_cfg, image_hw=cfg.image_hw)
    rng = np.random.default_rng(cfg.seed)
    N = cfg.dfl.num_agents

    # mobility: select the registered model by name; grouped runs thread the
    # group count into the area-band restriction
    mob_cfg = cfg.mobility
    if cfg.distribution == "grouped" and mob_cfg.num_bands != cfg.num_groups:
        mob_cfg = dataclasses.replace(mob_cfg, num_bands=cfg.num_groups)
    mob_model = mob_registry.get_model(mob_cfg.model)

    tx, ty, ex, ey = make_image_dataset(
        cfg.seed, n_train=cfg.n_train, n_test=cfg.n_test,
        hw=model_cfg.image_hw, channels=model_cfg.in_channels)

    band = group = None
    group_slots = None
    if cfg.distribution == "iid":
        idx, counts = part_lib.iid_partition(rng, ty, N)
    elif cfg.distribution == "noniid":
        idx, counts = part_lib.shards_noniid_partition(rng, ty, N)
    elif cfg.distribution == "dirichlet":
        idx, counts = part_lib.dirichlet_partition(rng, ty, N,
                                                   pi=cfg.dirichlet_pi)
    elif cfg.distribution == "grouped":
        band, group = make_bands(N, cfg.num_groups)
        idx, counts = part_lib.grouped_label_partition(
            rng, ty, N, np.asarray(group),
            _area_labels(cfg.num_groups, cfg.overlap))
        per = cfg.dfl.cache_size // cfg.num_groups
        slots = [per] * cfg.num_groups
        for i in range(cfg.dfl.cache_size - per * cfg.num_groups):
            slots[i] += 1
        group_slots = jnp.asarray(slots, jnp.int32)
    else:
        raise ValueError(cfg.distribution)

    data = part_lib.gather_agent_data({"images": tx, "labels": ty}, idx)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    test_batch = {"images": jnp.asarray(ex), "labels": jnp.asarray(ey)}

    key = jax.random.PRNGKey(cfg.seed)
    params0 = cnn_lib.init_params(model_cfg, key)
    state = rounds_lib.init_fleet(params0, N, cfg.dfl.cache_size,
                                  counts.astype(np.float32), group=group)
    mstate = mob_model.init(jax.random.PRNGKey(cfg.seed + 1), N, mob_cfg,
                            band=band)
    wants_encounters = (policy.needs_encounters
                        or policy_params.get("w_encounter", 0.0) != 0.0)
    if cfg.algorithm == "cached" and wants_encounters:
        # warm-start the per-pair encounter counts from the mobility-stats
        # subsystem: one epoch's contact roll-out on a throwaway copy of
        # the mobility state, so the policy has a rate prior before any
        # exchange happens
        n_steps = min(200, max(1, int(cfg.dfl.epoch_seconds
                                      / mob_cfg.step_seconds)))
        _, seq = mob_stats.collect_contacts(
            mob_model, mstate, jax.random.PRNGKey(cfg.seed + 3), mob_cfg,
            n_steps)
        est = mob_stats.encounter_stats(seq, mob_cfg.step_seconds)
        state = dataclasses.replace(
            state, encounters=est["encounter_counts"].astype(jnp.float32))
    return (model_cfg, state, data, jnp.asarray(counts), test_batch, mstate,
            group_slots, mob_model, mob_cfg)


def make_epoch_fn(cfg: ExperimentConfig, *, loss_fn: Callable,
                  group_slots=None, gather_mode: str = "select"):
    """Jitted single-epoch step for the legacy per-epoch driver.

    ``lr`` is threaded as a *traced* call argument (historically it was
    closed over as a static Python float, so every ReduceLROnPlateau step
    recompiled the whole epoch). ``durations`` is the per-pair
    contact-duration matrix from ``simulate_epoch`` feeding the transfer
    budget. Returns ``(epoch_fn, counter)`` where ``counter["traces"]``
    counts actual retraces — exactly 1 per (algorithm, shape) regardless
    of LR changes.
    """
    counter = {"traces": 0}
    step = rounds_lib.make_epoch_step(
        cfg.algorithm, loss_fn=loss_fn, local_steps=cfg.dfl.local_steps,
        batch_size=cfg.dfl.batch_size, rho=cfg.dfl.rho,
        tau_max=cfg.dfl.tau_max, policy=cfg.dfl.policy,
        group_slots=group_slots, staleness_decay=cfg.dfl.staleness_decay,
        policy_params=dict(cfg.dfl.policy_params), gather_mode=gather_mode,
        transfer_budget=cfg.dfl.resolved_transfer_budget,
        link_entries_per_step=cfg.dfl.link_entries_per_step)

    def fn(state, partners, durations, data, counts, key, lr):
        counter["traces"] += 1
        return step(state, partners, durations, data, counts, key, lr)

    return jax.jit(fn), counter


def make_engine(cfg: ExperimentConfig, *, loss_fn: Callable, mob_model,
                mob_cfg, group_slots=None, gather_mode: str = "select",
                chunk: Optional[int] = None, donate: Optional[bool] = None):
    """Build the fused scan engine for an experiment config."""
    return rounds_lib.make_fleet_engine(
        algorithm=cfg.algorithm, mob_model=mob_model, mob_cfg=mob_cfg,
        epoch_seconds=cfg.dfl.epoch_seconds, max_partners=cfg.max_partners,
        partner_sample=cfg.partner_sample, loss_fn=loss_fn,
        local_steps=cfg.dfl.local_steps, batch_size=cfg.dfl.batch_size,
        rho=cfg.dfl.rho, tau_max=cfg.dfl.tau_max, policy=cfg.dfl.policy,
        group_slots=group_slots, staleness_decay=cfg.dfl.staleness_decay,
        policy_params=dict(cfg.dfl.policy_params), gather_mode=gather_mode,
        transfer_budget=cfg.dfl.resolved_transfer_budget,
        link_entries_per_step=cfg.dfl.link_entries_per_step,
        chunk=cfg.eval_every if chunk is None else chunk, donate=donate)


def run_experiment(cfg: ExperimentConfig, *, verbose: bool = False,
                   record_cache_stats: bool = False,
                   engine: str = "fused") -> Dict:
    """Run one fleet experiment end to end.

    engine="fused" (default) drives `eval_every` epochs per jit call through
    the scanned engine; engine="legacy" keeps the historical 3-dispatch
    per-epoch host loop (the benchmark baseline).
    """
    (model_cfg, state, data, counts, test_batch, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)

    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    acc_fn = lambda p, b: cnn_lib.accuracy(p, model_cfg, b["images"],
                                           b["labels"])
    eval_fn = jax.jit(functools.partial(rounds_lib.fleet_eval,
                                        acc_fn=acc_fn))

    sched = ReduceLROnPlateau(lr=cfg.dfl.lr)
    lr = cfg.dfl.lr
    key = jax.random.PRNGKey(cfg.seed + 2)
    history: Dict[str, List] = {"epoch": [], "acc": [], "lr": [],
                                "cache_num": [], "cache_age": []}
    best, best_epoch = -1.0, 0
    stop = False
    t0 = time.time()

    def evaluate(ep):
        """Eval at 0-based epoch index ep; returns True to early-stop."""
        nonlocal lr, best, best_epoch
        acc, cache_num, cache_age = eval_fn(state, test_batch=test_batch)
        acc = float(acc)                     # scalars only cross to host
        history["epoch"].append(ep + 1)
        history["acc"].append(acc)
        history["lr"].append(lr)
        if record_cache_stats and cfg.algorithm == "cached":
            history["cache_num"].append(float(cache_num))
            history["cache_age"].append(float(cache_age))
        if cfg.lr_plateau:
            lr = sched.update(acc)           # traced arg: no retrace on change
        if acc > best + 1e-4:
            best, best_epoch = acc, ep
        elif ep - best_epoch >= cfg.early_stop_patience:
            if verbose:
                print(f"early stop at epoch {ep + 1}")
            return True
        if verbose:
            print(f"epoch {ep + 1:4d} acc={acc:.4f} lr={lr:.4f} "
                  f"({time.time() - t0:.1f}s)")
        return False

    # budget sweeps pass the (traced) cap per engine call — never retraces;
    # None = no flat cap (a duration-derived cap may still apply via
    # link_entries_per_step, bound statically above)
    budget = (jnp.float32(cfg.dfl.resolved_transfer_budget)
              if cfg.dfl.resolved_transfer_budget is not None else None)

    if engine == "fused":
        eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                          mob_cfg=mob_cfg, group_slots=group_slots)
        ep = 0
        while ep < cfg.epochs and not stop:
            n = min(eng.chunk, cfg.epochs - ep)
            if budget is None:
                state, mstate, key, _ = eng.run(state, mstate, key, lr,
                                                data, counts, n)
            else:
                state, mstate, key, _ = eng.run(state, mstate, key, lr,
                                                data, counts, n, budget)
            ep += n
            if ep % cfg.eval_every == 0:
                stop = evaluate(ep - 1)
        history["epoch_traces"] = eng.traces
    elif engine == "legacy":
        epoch_fn, counter = make_epoch_fn(cfg, loss_fn=loss_fn,
                                          group_slots=group_slots)
        sim = jax.jit(functools.partial(mob_model.simulate_epoch,
                                        cfg=mob_cfg,
                                        seconds=cfg.dfl.epoch_seconds))
        for ep in range(cfg.epochs):
            # deterministic partner selection keeps the historical key stream
            if cfg.partner_sample == "lowest-id":
                key, k1, k2 = jax.random.split(key, 3)
                k3 = None
            else:
                key, k1, k2, k3 = jax.random.split(key, 4)
            mstate, met, dur = sim(mstate, k1)
            partners = partners_from_contacts(
                met, cfg.max_partners, sample=cfg.partner_sample, key=k3)
            state, _ = epoch_fn(state, partners, dur, data, counts, k2, lr)
            if (ep + 1) % cfg.eval_every == 0:
                if evaluate(ep):
                    break
        history["epoch_traces"] = counter["traces"]
    else:
        raise ValueError(f"unknown engine {engine!r}")

    history["engine"] = engine
    history["best_acc"] = best
    history["final_acc"] = history["acc"][-1] if history["acc"] else 0.0
    history["wall_s"] = time.time() - t0
    return history
