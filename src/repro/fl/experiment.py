"""Compatibility layer over the Scenario API (``repro.api``).

The experiment surface now lives in :mod:`repro.fl.scenario` (declarative
``Scenario`` specs, validation, the named :class:`Fleet` struct) and
:mod:`repro.fl.runner` (``run``/``sweep`` with typed results). This
module keeps the historical entry points working unmodified:

- ``ExperimentConfig`` — re-exported from ``scenario`` (same dataclass);
- ``build_fleet(cfg)`` — returns the named ``Fleet`` struct, which still
  unpacks as the historical 9-tuple;
- ``resolve_policy_setup(cfg)`` — delegates to the consolidated
  ``Scenario.resolve`` validation;
- ``run_experiment(cfg, ...)`` — thin shim over ``runner.run`` returning
  the legacy history dict;
- ``make_epoch_fn`` / ``make_engine`` — the jitted-driver builders, used
  by the runner and by engine-level tests/benchmarks.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax

from repro.core import rounds as rounds_lib
from repro.fl.scenario import (  # noqa: F401  (re-exports)
    ExperimentConfig, Fleet, ResolvedScenario, Scenario, _area_labels,
    _resolve_policy_setup)


def resolve_policy_setup(cfg: ExperimentConfig):
    """Resolve + validate the cache policy once at config resolution.

    Returns ``(policy, policy_params)``; raises ValueError naming the
    offending config fields. Kept as a shim over the consolidated
    ``Scenario.resolve`` validation.
    """
    return _resolve_policy_setup(cfg)


def build_fleet(cfg: ExperimentConfig) -> Fleet:
    """Build the fleet for an ExperimentConfig.

    Returns the named :class:`Fleet` struct ``(model_cfg, state, data,
    counts, test_batch, mobility_state, group_slots, mob_model,
    mobility)`` — field order matches the historical 9-tuple.
    """
    return Scenario(experiment=cfg).resolve().build_fleet()


def make_epoch_fn(cfg: ExperimentConfig, *, loss_fn: Callable,
                  group_slots=None, gather_mode: str = "select",
                  telemetry: bool = False):
    """Jitted single-epoch step for the legacy per-epoch driver.

    ``lr`` is threaded as a *traced* call argument (historically it was
    closed over as a static Python float, so every ReduceLROnPlateau step
    recompiled the whole epoch). ``durations`` is the per-pair
    contact-duration matrix from ``simulate_epoch`` feeding the transfer
    budget. Returns ``(epoch_fn, counter)`` where ``counter["traces"]``
    counts actual retraces — exactly 1 per (algorithm, shape) regardless
    of LR changes. With ``telemetry`` the step also returns per-epoch
    :class:`~repro.telemetry.metrics.ExchangeStats`.
    """
    counter = {"traces": 0}
    step = rounds_lib.make_epoch_step(
        cfg.algorithm, loss_fn=loss_fn, local_steps=cfg.dfl.local_steps,
        batch_size=cfg.dfl.batch_size, rho=cfg.dfl.rho,
        tau_max=cfg.dfl.tau_max, policy=cfg.dfl.policy,
        group_slots=group_slots, staleness_decay=cfg.dfl.staleness_decay,
        policy_params=dict(cfg.dfl.policy_params), gather_mode=gather_mode,
        transfer_budget=cfg.dfl.resolved_transfer_budget,
        link_entries_per_step=cfg.dfl.link_entries_per_step,
        telemetry=telemetry, churn=cfg.dfl.churn_enabled)

    def fn(state, partners, durations, data, counts, key, lr):
        counter["traces"] += 1
        return step(state, partners, durations, data, counts, key, lr)

    return jax.jit(fn), counter


def make_engine(cfg: ExperimentConfig, *, loss_fn: Callable, mob_model,
                mob_cfg, group_slots=None, gather_mode: str = "select",
                chunk: Optional[int] = None, donate: Optional[bool] = None,
                telemetry: bool = False):
    """Build the fused scan engine for an experiment config."""
    return rounds_lib.make_fleet_engine(
        algorithm=cfg.algorithm, mob_model=mob_model, mob_cfg=mob_cfg,
        epoch_seconds=cfg.dfl.epoch_seconds, max_partners=cfg.max_partners,
        partner_sample=cfg.partner_sample, loss_fn=loss_fn,
        local_steps=cfg.dfl.local_steps, batch_size=cfg.dfl.batch_size,
        rho=cfg.dfl.rho, tau_max=cfg.dfl.tau_max, policy=cfg.dfl.policy,
        group_slots=group_slots, staleness_decay=cfg.dfl.staleness_decay,
        policy_params=dict(cfg.dfl.policy_params), gather_mode=gather_mode,
        transfer_budget=cfg.dfl.resolved_transfer_budget,
        link_entries_per_step=cfg.dfl.link_entries_per_step,
        chunk=cfg.eval_every if chunk is None else chunk, donate=donate,
        telemetry=telemetry, churn_period=cfg.dfl.churn_period,
        churn_fraction=cfg.dfl.churn_fraction)


def make_sharded_engine(cfg: ExperimentConfig, *, mesh, loss_fn: Callable,
                        mob_model, mob_cfg, group_slots=None,
                        gather_mode: str = "select",
                        chunk: Optional[int] = None,
                        donate: Optional[bool] = None,
                        telemetry: bool = False):
    """Build the shard_map fleet engine over an agent mesh
    (``launch.mesh.make_fleet_mesh``); ``cfg.dfl.shard_halo`` picks exact
    (0) vs block-sparse halo gossip."""
    return rounds_lib.make_sharded_fleet_engine(
        mesh=mesh, algorithm=cfg.algorithm, mob_model=mob_model,
        mob_cfg=mob_cfg, epoch_seconds=cfg.dfl.epoch_seconds,
        max_partners=cfg.max_partners, partner_sample=cfg.partner_sample,
        loss_fn=loss_fn, local_steps=cfg.dfl.local_steps,
        batch_size=cfg.dfl.batch_size, rho=cfg.dfl.rho,
        tau_max=cfg.dfl.tau_max, policy=cfg.dfl.policy,
        group_slots=group_slots, staleness_decay=cfg.dfl.staleness_decay,
        policy_params=dict(cfg.dfl.policy_params), gather_mode=gather_mode,
        transfer_budget=cfg.dfl.resolved_transfer_budget,
        link_entries_per_step=cfg.dfl.link_entries_per_step,
        halo=cfg.dfl.shard_halo,
        chunk=cfg.eval_every if chunk is None else chunk, donate=donate,
        telemetry=telemetry, churn_period=cfg.dfl.churn_period,
        churn_fraction=cfg.dfl.churn_fraction)


def run_experiment(cfg: ExperimentConfig, *, verbose: bool = False,
                   record_cache_stats: bool = False,
                   engine: str = "fused") -> Dict:
    """Run one fleet experiment end to end (legacy dict interface).

    Thin shim over ``repro.fl.runner.run``: wraps the config in a
    Scenario (the kwargs became Scenario fields) and flattens the typed
    ``RunResult`` back into the historical history dict.
    """
    from repro.fl import runner  # local import: runner imports this module
    scenario = Scenario(experiment=cfg, engine=engine, verbose=verbose,
                        record_cache_stats=record_cache_stats)
    return runner.run(scenario).history()
