"""Declarative experiment specs: the single source of truth for what a
fleet experiment *is*.

A :class:`Scenario` is a frozen, fully serializable wrapper around
``ExperimentConfig`` / ``DFLConfig`` / ``MobilityConfig`` plus the
run-level knobs (``engine``, ``verbose``, ``record_cache_stats``) that
used to ride as ``run_experiment`` kwargs. It round-trips through
``to_dict``/``from_dict``/``to_json``/``from_json`` losslessly, supports
dotted-path overrides built generically from dataclass introspection
(``with_overrides({"dfl.policy": "mobility_aware",
"mobility.levy_alpha": 1.2})`` — unknown keys raise, naming the valid
fields), and resolves once into a validated :class:`ResolvedScenario`
(registry lookups, the ``num_groups``→``num_bands`` threading, policy /
budget consistency checks) whose ``build_fleet()`` replaces the old
9-tuple with the named :class:`Fleet` struct.

Downstream consumers (CLI, benchmarks, examples, tools, tests) go
through ``repro.api`` → :mod:`repro.fl.runner`, which executes a
``Scenario`` into a typed ``RunResult``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import typing
from typing import Any, Dict, List, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DFLConfig, MobilityConfig
from repro.configs.paper_models import CNNConfig, PAPER_CONFIGS
from repro.core import rounds as rounds_lib
from repro.data.synthetic import make_image_dataset
from repro.fl import partition as part_lib
from repro.mobility import registry as mob_registry
from repro.mobility import stats as mob_stats
from repro.mobility.base import make_bands
from repro.models import cnn as cnn_lib
from repro.policies import registry as policy_registry

ALGORITHMS = ("cached", "dfl", "cfl")
DISTRIBUTIONS = ("iid", "noniid", "dirichlet", "grouped")
ENGINES = ("fused", "legacy", "sharded")


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    model: str = "paper-mnist-cnn"
    distribution: str = "noniid"      # iid | noniid | dirichlet | grouped
    algorithm: str = "cached"         # cached | dfl | cfl
    dfl: DFLConfig = dataclasses.field(default_factory=DFLConfig)
    mobility: MobilityConfig = dataclasses.field(
        default_factory=MobilityConfig)
    epochs: int = 50
    eval_every: int = 1
    seed: int = 0
    n_train: int = 6000
    n_test: int = 1000
    image_hw: int = 0                 # 0 -> model default
    max_partners: int = 4
    partner_sample: str = "lowest-id"  # lowest-id | random (radio budget)
    early_stop_patience: int = 20
    dirichlet_pi: float = 0.5
    overlap: int = 0                  # grouped: label overlap between areas
    num_groups: int = 3
    lr_plateau: bool = True


def _area_labels(num_groups: int, overlap: int, num_classes: int = 10):
    """n-overlap label allocation (paper appendix B.1.1).

    For ``num_groups`` that do not divide ``num_classes`` the remainder
    classes are spread one-per-group from the front, so every class is
    owned by at least one group (groups beyond ``num_classes`` stay
    empty).
    """
    base = [list(range(0, 4)), list(range(4, 7)), list(range(7, 10))]
    if num_groups != 3:
        per, rem = divmod(num_classes, num_groups)
        sizes = [per + (1 if g < rem else 0) for g in range(num_groups)]
        starts = [sum(sizes[:g]) for g in range(num_groups)]
        base = [list(range(starts[g], starts[g] + sizes[g]))
                for g in range(num_groups)]
    out = []
    for g, labels in enumerate(base):
        l = list(labels)
        for k in range(1, overlap + 1):
            if labels:
                l.append((labels[0] - k) % num_classes)  # borrow neighbors
        out.append(sorted(set(l)))
    return out


# ---------------------------------------------------------------------------
# generic dataclass <-> dict plumbing (serialization + dotted overrides)
# ---------------------------------------------------------------------------

def _encode(value):
    """JSON-safe encoding: nested dataclasses -> dicts, tuples -> lists,
    non-finite floats -> "inf"/"-inf"/"nan" sentinels (strict RFC 8259)."""
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {f.name: _encode(getattr(value, f.name))
                for f in dataclasses.fields(value)}
    if isinstance(value, (tuple, list)):
        return [_encode(v) for v in value]
    if isinstance(value, float) and not math.isfinite(value):
        return "nan" if math.isnan(value) else (
            "inf" if value > 0 else "-inf")
    return value


_FLOAT_SENTINELS = {"inf": float("inf"), "-inf": float("-inf"),
                    "nan": float("nan")}


def _coerce(hint, value, *, path: str):
    """Coerce ``value`` (possibly a string from JSON / the CLI ``--set``
    flag) to the annotated field type ``hint``."""
    if dataclasses.is_dataclass(hint):
        if isinstance(value, hint):
            return value
        if isinstance(value, Mapping):
            return _dataclass_from_dict(hint, value, path=path)
        raise ValueError(
            f"{path!r} expects a {hint.__name__} (or a mapping of its "
            f"fields), got {value!r}")
    origin = typing.get_origin(hint)
    if origin is tuple:  # DFLConfig.policy_params
        return _coerce_policy_params(value, path=path)
    if hint is bool:
        if isinstance(value, bool):
            return value
        if isinstance(value, str):
            low = value.strip().lower()
            if low in ("1", "true", "yes", "on"):
                return True
            if low in ("0", "false", "no", "off"):
                return False
        raise ValueError(f"{path!r} expects a bool, got {value!r}")
    if hint is int:
        if isinstance(value, bool) or not isinstance(value, (int, str)):
            raise ValueError(f"{path!r} expects an int, got {value!r}")
        try:
            return int(value)
        except ValueError:
            raise ValueError(
                f"{path!r} expects an int, got {value!r}") from None
    if hint is float:
        if isinstance(value, str):
            if value.strip().lower() in _FLOAT_SENTINELS:
                return _FLOAT_SENTINELS[value.strip().lower()]
            try:
                return float(value)
            except ValueError:
                raise ValueError(
                    f"{path!r} expects a float, got {value!r}") from None
        if isinstance(value, (int, float)) and not isinstance(value, bool):
            return float(value)
        raise ValueError(f"{path!r} expects a float, got {value!r}")
    if hint is str:
        if not isinstance(value, str):
            raise ValueError(f"{path!r} expects a string, got {value!r}")
        return value
    return value


def _coerce_policy_params(value, *, path: str) -> Tuple[Tuple[str, float], ...]:
    """policy_params accepts ((name, value), ...), [[name, value], ...]
    (JSON) or the CLI string form "name=1.0,other=2"."""
    if isinstance(value, str):
        if not value.strip():
            return ()
        pairs = []
        for item in value.replace(";", ",").split(","):
            name, sep, raw = item.partition("=")
            if not sep or not name.strip():
                raise ValueError(
                    f"{path!r} expects NAME=VALUE[,NAME=VALUE...], got "
                    f"{value!r}")
            try:
                pairs.append((name.strip(), float(raw)))
            except ValueError:
                raise ValueError(
                    f"{path!r} expects a numeric value for "
                    f"{name.strip()!r}, got {raw!r}") from None
        return tuple(pairs)
    try:
        return tuple((str(n), float(v)) for n, v in value)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"{path!r} expects (name, value) pairs, got {value!r}") from e


def _dataclass_from_dict(cls, d: Mapping, *, path: str = ""):
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        where = f" under {path!r}" if path else ""
        raise ValueError(
            f"unknown {cls.__name__} field(s) {unknown}{where}; "
            f"valid fields: {sorted(names)}")
    hints = typing.get_type_hints(cls)
    kwargs = {k: _coerce(hints[k], v,
                         path=f"{path}.{k}" if path else k)
              for k, v in d.items()}
    return cls(**kwargs)


_GROUPS = {"dfl": DFLConfig, "mobility": MobilityConfig}


def valid_override_paths() -> List[str]:
    """Every dotted path ``with_overrides`` / the CLI ``--set`` accept."""
    paths = [f.name for f in dataclasses.fields(Scenario)
             if f.name != "experiment"]
    for f in dataclasses.fields(ExperimentConfig):
        paths.append(f.name)
        if f.name in _GROUPS:
            paths.extend(f"{f.name}.{g.name}"
                         for g in dataclasses.fields(_GROUPS[f.name]))
    return sorted(paths)


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """A frozen, serializable experiment spec.

    ``experiment`` carries the full ``ExperimentConfig`` (which nests
    ``DFLConfig``/``MobilityConfig``); the remaining fields are run-level
    knobs that previously rode as ``run_experiment`` keyword arguments.
    """
    experiment: ExperimentConfig = dataclasses.field(
        default_factory=ExperimentConfig)
    name: str = ""
    engine: str = "fused"             # fused | legacy | sharded
    mesh: int = 0                     # sharded: device count (0 = all visible)
    verbose: bool = False
    record_cache_stats: bool = False
    telemetry: bool = False           # fleet observability (repro.telemetry)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "engine": self.engine,
                "mesh": self.mesh,
                "verbose": self.verbose,
                "record_cache_stats": self.record_cache_stats,
                "telemetry": self.telemetry,
                "experiment": _encode(self.experiment)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Scenario":
        return _dataclass_from_dict(cls, d)

    def to_json(self, **dumps_kwargs) -> str:
        dumps_kwargs.setdefault("indent", 1)
        dumps_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), allow_nan=False, **dumps_kwargs)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    def content_hash(self) -> str:
        """Stable provenance hash of what the run *computes*: the
        experiment spec + engine choice. Presentation-only fields
        (``name``, ``verbose``, ``record_cache_stats``, ``telemetry`` —
        observability never changes the model trajectory) are excluded;
        so is ``mesh``, which is device *placement* — the math is fixed
        by the spec (``dfl.shard_halo`` lives in the experiment),
        so a preset, a spec file, and a verbose CLI run of the same
        experiment all report the same hash."""
        canon = json.dumps({"experiment": _encode(self.experiment),
                            "engine": self.engine},
                           sort_keys=True, separators=(",", ":"),
                           allow_nan=False)
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    # -- dotted-path overrides ---------------------------------------------

    def with_overrides(self, overrides: Mapping[str, Any]) -> "Scenario":
        """Return a new Scenario with dotted-path overrides applied.

        Paths: ``dfl.<field>`` / ``mobility.<field>`` reach the nested
        configs, bare ``ExperimentConfig`` field names (``epochs``,
        ``algorithm``, ...) reach the experiment, and Scenario-level
        knobs (``engine``, ``verbose``, ...) are addressed directly.
        ``dfl`` / ``mobility`` / ``experiment`` accept a whole config
        object (or mapping). String values are coerced to the field type,
        so the CLI can feed ``--set dfl.cache_size=8`` verbatim. Unknown
        paths raise, naming the valid fields.
        """
        scen_fields = {f.name for f in dataclasses.fields(Scenario)}
        exp_fields = {f.name: f for f in
                      dataclasses.fields(ExperimentConfig)}
        exp_hints = typing.get_type_hints(ExperimentConfig)
        scen_hints = typing.get_type_hints(Scenario)

        scen_kw: Dict[str, Any] = {}
        exp_kw: Dict[str, Any] = {}
        group_kw: Dict[str, Dict[str, Any]] = {g: {} for g in _GROUPS}
        exp_base: Optional[ExperimentConfig] = None

        for key, value in overrides.items():
            head, _, rest = key.partition(".")
            if head == "experiment" and rest:
                head, _, rest = rest.partition(".")
            if head in _GROUPS:
                gcls = _GROUPS[head]
                if not rest:
                    exp_kw[head] = _coerce(gcls, value, path=key)
                    continue
                gfields = {f.name for f in dataclasses.fields(gcls)}
                if rest not in gfields:
                    raise ValueError(
                        f"unknown override path {key!r}: {gcls.__name__} "
                        f"has no field {rest!r}; valid: "
                        f"{sorted(f'{head}.{n}' for n in gfields)}")
                ghints = typing.get_type_hints(gcls)
                group_kw[head][rest] = _coerce(ghints[rest], value, path=key)
            elif head == "experiment":
                exp_base = _coerce(ExperimentConfig, value, path=key)
            elif head in exp_fields and not rest:
                exp_kw[head] = _coerce(exp_hints[head], value, path=key)
            elif head in scen_fields and head != "experiment" and not rest:
                scen_kw[head] = _coerce(scen_hints[head], value, path=key)
            else:
                raise ValueError(
                    f"unknown override path {key!r}; valid paths: "
                    f"{valid_override_paths()}")

        exp = self.experiment if exp_base is None else exp_base
        for g, kw in group_kw.items():
            if kw:
                base = exp_kw.get(g, getattr(exp, g))
                exp_kw[g] = dataclasses.replace(base, **kw)
        if exp_kw:
            exp = dataclasses.replace(exp, **exp_kw)
        return dataclasses.replace(self, experiment=exp, **scen_kw)

    # -- resolution ---------------------------------------------------------

    def resolve(self) -> "ResolvedScenario":
        """Validate the spec once and bind registry objects.

        Consolidates the checks that used to live in
        ``resolve_policy_setup``, the ``num_groups``→``num_bands``
        replace-hack in ``build_fleet``, and the late registry/model
        lookups — every inconsistency fails here, naming the config
        fields, instead of mid-trace.
        """
        cfg = self.experiment
        if self.engine not in ENGINES:
            raise ValueError(f"Scenario.engine={self.engine!r}; "
                             f"valid engines: {list(ENGINES)}")
        if cfg.algorithm not in ALGORITHMS:
            raise ValueError(
                f"ExperimentConfig.algorithm={cfg.algorithm!r}; "
                f"valid algorithms: {list(ALGORITHMS)}")
        if cfg.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"ExperimentConfig.distribution={cfg.distribution!r}; "
                f"valid distributions: {list(DISTRIBUTIONS)}")
        if cfg.model not in PAPER_CONFIGS:
            raise ValueError(
                f"ExperimentConfig.model={cfg.model!r}; registered models: "
                f"{sorted(PAPER_CONFIGS)}")
        if cfg.partner_sample not in ("lowest-id", "random"):
            raise ValueError(
                f"ExperimentConfig.partner_sample={cfg.partner_sample!r}; "
                f"valid: ['lowest-id', 'random']")
        if cfg.epochs <= 0 or cfg.eval_every <= 0:
            raise ValueError(
                f"ExperimentConfig.epochs={cfg.epochs} and "
                f"eval_every={cfg.eval_every} must both be positive")
        if self.mesh < 0:
            raise ValueError(f"Scenario.mesh={self.mesh} must be >= 0 "
                             "(0 = all visible devices)")
        if cfg.dfl.shard_halo < 0:
            raise ValueError(
                f"DFLConfig.shard_halo={cfg.dfl.shard_halo} must be >= 0")
        if cfg.dfl.churn_period < 0:
            raise ValueError(
                f"DFLConfig.churn_period={cfg.dfl.churn_period} must be "
                ">= 0 (0 = no churn)")
        if not 0.0 <= cfg.dfl.churn_fraction < 1.0:
            raise ValueError(
                f"DFLConfig.churn_fraction={cfg.dfl.churn_fraction} must "
                "be in [0, 1): 1 would take every agent out of coverage "
                "for the whole cycle")
        if cfg.dfl.churn_period > 0 and (
                round(cfg.dfl.churn_fraction * cfg.dfl.churn_period)
                >= cfg.dfl.churn_period):
            raise ValueError(
                f"churn_fraction={cfg.dfl.churn_fraction} rounds to the "
                f"whole churn_period={cfg.dfl.churn_period} — every agent "
                "would be permanently out of coverage; lower the fraction "
                "or lengthen the period")
        if not 0.0 <= cfg.mobility.diurnal_amplitude <= 1.0:
            raise ValueError(
                "MobilityConfig.diurnal_amplitude="
                f"{cfg.mobility.diurnal_amplitude} must be in [0, 1]")
        if cfg.mobility.diurnal_period <= 0.0:
            raise ValueError(
                "MobilityConfig.diurnal_period="
                f"{cfg.mobility.diurnal_period} must be positive seconds")
        if self.engine == "sharded" and cfg.partner_sample != "lowest-id":
            raise ValueError(
                "Scenario.engine='sharded' requires "
                "ExperimentConfig.partner_sample='lowest-id' (got "
                f"{cfg.partner_sample!r}): randomized partner draws key the "
                "PRNG per contact *row*, which is not reproducible across "
                "shard layouts — set partner_sample='lowest-id' or use "
                "engine='fused'")
        policy, policy_params = _resolve_policy_setup(cfg)
        mob_cfg = cfg.mobility
        if cfg.distribution == "grouped" and mob_cfg.num_bands != cfg.num_groups:
            # grouped runs thread the group count into the area-band
            # restriction so band == data group
            mob_cfg = dataclasses.replace(mob_cfg, num_bands=cfg.num_groups)
        mob_model = mob_registry.get_model(mob_cfg.model)
        model_cfg: CNNConfig = PAPER_CONFIGS[cfg.model]
        if cfg.image_hw:
            model_cfg = dataclasses.replace(model_cfg, image_hw=cfg.image_hw)
        _check_fleet_memory(self, model_cfg)
        return ResolvedScenario(
            scenario=self, policy=policy, policy_params=policy_params,
            mobility=mob_cfg, mob_model=mob_model, model_cfg=model_cfg)


def _fleet_memory_estimate(scenario: "Scenario", model_cfg) -> Dict[str, float]:
    """Rough device-memory footprint (bytes) of the resolved fleet.

    Sized from the dominant working sets, per term so the error can name
    the knob that moves it: per-agent model copies (params + aggregation
    scratch), the model cache ``[N, C, ...]`` (with exchange scratch), and
    the quadratic arrays — contact/duration blocks ``[rows, W]`` (the
    window ``W`` shrinks under the sharded engine's halo gossip) plus the
    ``[N, N]`` encounter counts (and the telemetry origin latch).
    Parameter count comes from ``jax.eval_shape`` on the model init —
    no FLOPs, exact shapes.
    """
    cfg = scenario.experiment
    N, C = cfg.dfl.num_agents, cfg.dfl.cache_size
    shapes = jax.eval_shape(lambda k: cnn_lib.init_params(model_cfg, k),
                            jax.random.PRNGKey(0))
    p_floats = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(shapes))
    halo = cfg.dfl.shard_halo
    if scenario.engine == "sharded":
        ndev = scenario.mesh or jax.device_count()
        n_local = max(1, N // max(ndev, 1))
        full = halo == 0 or n_local + 2 * halo >= N
        W = N if full else n_local + 2 * halo
    else:
        W = N
    return {
        "param_floats": float(p_floats),
        # params + aggregation scratch (tilde / grads) per agent
        "models": 3.0 * N * p_floats * 4,
        # cache [N, C, ...] + candidate/pool scratch during the exchange
        "cache": 4.0 * N * C * p_floats * 4,
        # met (bool) + durations (f32) contact blocks over the window
        "contacts": float(N) * W * 5,
        # per-pair encounter counts (f32) + telemetry origin latch (bool)
        "quadratic": float(N) * N * (5 if scenario.telemetry else 4),
    }


def _check_fleet_memory(scenario: "Scenario", model_cfg) -> None:
    """Fail fast, with the knobs named, instead of an opaque XLA OOM.

    The budget is ``REPRO_FLEET_MEM_GB`` when set (``0`` disables the
    check) and ~80% of physical RAM otherwise.
    """
    import os

    env = os.environ.get("REPRO_FLEET_MEM_GB", "").strip()
    if env:
        try:
            limit_gb = float(env)
        except ValueError:
            raise ValueError(
                f"REPRO_FLEET_MEM_GB={env!r} is not a number") from None
        if limit_gb <= 0:
            return
    else:
        try:
            limit_gb = 0.8 * (os.sysconf("SC_PHYS_PAGES")
                              * os.sysconf("SC_PAGE_SIZE")) / 2**30
        except (ValueError, OSError, AttributeError):
            return  # platform without sysconf: skip the guard
    est = _fleet_memory_estimate(scenario, model_cfg)
    total_gb = (est["models"] + est["cache"] + est["contacts"]
                + est["quadratic"]) / 2**30
    if total_gb <= limit_gb:
        return
    cfg = scenario.experiment
    raise ValueError(
        f"estimated fleet memory ~{total_gb:.1f} GiB exceeds the "
        f"{limit_gb:.1f} GiB budget before tracing "
        f"(dfl.num_agents={cfg.dfl.num_agents}, "
        f"dfl.cache_size={cfg.dfl.cache_size}, "
        f"model={cfg.model!r} ~{int(est['param_floats']):,} params; "
        f"models ~{est['models'] / 2**30:.1f} + cache "
        f"~{est['cache'] / 2**30:.1f} + contact window "
        f"~{est['contacts'] / 2**30:.1f} + per-pair counts "
        f"~{est['quadratic'] / 2**30:.1f} GiB). Reduce dfl.num_agents / "
        "dfl.cache_size, or switch to engine='sharded' with mesh=<devices> "
        "and dfl.shard_halo=<H> so contact blocks cover an "
        "(N/devices + 2H)-wide window instead of all N columns. Set "
        "REPRO_FLEET_MEM_GB to override the budget (0 disables this check).")


def _resolve_policy_setup(cfg: ExperimentConfig):
    """Resolve + validate the cache policy once at config resolution.

    Returns ``(policy, policy_params)``. Raises ValueError naming the
    offending config fields for inconsistent setups (instead of failing
    mid-trace inside ``gossip.exchange``), e.g. a group policy without a
    grouped distribution or with fewer cache slots than groups.
    """
    pol = policy_registry.resolve(cfg.dfl.policy)
    params = dict(cfg.dfl.policy_params)
    if cfg.algorithm != "cached" and cfg.dfl.transfer_budget_enabled:
        raise ValueError(
            "DFLConfig.transfer_budget / link_entries_per_step bound the "
            "cached algorithm's cache exchange and have no effect on "
            f"algorithm={cfg.algorithm!r} — unset them (or use "
            "algorithm='cached') rather than sweeping a no-op knob")
    unknown = sorted(set(params) - set(pol.knobs) - {"gamma"})
    if unknown:
        raise ValueError(
            f"DFLConfig.policy_params has unknown knob(s) {unknown} for "
            f"policy {pol.name!r}; accepted: "
            f"{sorted(set(pol.knobs) | {'gamma'})}")
    if cfg.algorithm == "cached" and pol.needs_group_slots:
        if cfg.distribution != "grouped":
            raise ValueError(
                f"DFLConfig.policy={pol.name!r} needs per-group cache "
                f"slots, which require ExperimentConfig.distribution="
                f"'grouped' (got {cfg.distribution!r})")
        if cfg.num_groups <= 0:
            raise ValueError(
                f"DFLConfig.policy={pol.name!r} requires "
                f"ExperimentConfig.num_groups > 0 "
                f"(got {cfg.num_groups})")
        if cfg.dfl.cache_size < cfg.num_groups:
            raise ValueError(
                f"DFLConfig.cache_size={cfg.dfl.cache_size} < "
                f"ExperimentConfig.num_groups={cfg.num_groups}: the "
                f"{pol.name!r} policy needs at least one slot per group")
    return pol, params


# ---------------------------------------------------------------------------
# Fleet
# ---------------------------------------------------------------------------

class Fleet(NamedTuple):
    """Everything a runner needs to drive one experiment.

    Field order matches the historical ``build_fleet`` 9-tuple, so legacy
    ``(model_cfg, state, ...) = build_fleet(cfg)`` unpacking keeps
    working while new code uses the named fields.
    """
    model_cfg: CNNConfig
    state: Any                 # rounds.FleetState
    data: Dict[str, jax.Array]
    counts: jax.Array
    test_batch: Dict[str, jax.Array]
    mobility_state: Any
    group_slots: Optional[jax.Array]
    mob_model: Any
    mobility: MobilityConfig   # normalized (num_bands threaded)

    @property
    def num_agents(self) -> int:
        return int(self.state.samples.shape[0])

    def loss_fn(self):
        cfg = self.model_cfg
        return lambda p, b: cnn_lib.loss_fn(p, cfg, b["images"], b["labels"])

    def acc_fn(self):
        cfg = self.model_cfg
        return lambda p, b: cnn_lib.accuracy(p, cfg, b["images"],
                                             b["labels"])


@dataclasses.dataclass(frozen=True)
class ResolvedScenario:
    """A validated Scenario with registry objects bound."""
    scenario: Scenario
    policy: Any                       # policies.base.CachePolicy
    policy_params: Dict[str, float]
    mobility: MobilityConfig          # num_bands threaded for grouped runs
    mob_model: Any                    # mobility.base.MobilityModel
    model_cfg: CNNConfig

    @property
    def experiment(self) -> ExperimentConfig:
        return self.scenario.experiment

    def build_fleet(self) -> Fleet:
        """Materialize data, models, caches and mobility state."""
        cfg = self.experiment
        model_cfg = self.model_cfg
        mob_cfg = self.mobility
        rng = np.random.default_rng(cfg.seed)
        N = cfg.dfl.num_agents

        tx, ty, ex, ey = make_image_dataset(
            cfg.seed, n_train=cfg.n_train, n_test=cfg.n_test,
            hw=model_cfg.image_hw, channels=model_cfg.in_channels)

        band = group = None
        group_slots = None
        if cfg.distribution == "iid":
            idx, counts = part_lib.iid_partition(rng, ty, N)
        elif cfg.distribution == "noniid":
            idx, counts = part_lib.shards_noniid_partition(rng, ty, N)
        elif cfg.distribution == "dirichlet":
            idx, counts = part_lib.dirichlet_partition(rng, ty, N,
                                                       pi=cfg.dirichlet_pi)
        else:  # grouped (resolve() validated membership)
            band, group = make_bands(N, cfg.num_groups)
            idx, counts = part_lib.grouped_label_partition(
                rng, ty, N, np.asarray(group),
                _area_labels(cfg.num_groups, cfg.overlap))
            per = cfg.dfl.cache_size // cfg.num_groups
            slots = [per] * cfg.num_groups
            for i in range(cfg.dfl.cache_size - per * cfg.num_groups):
                slots[i] += 1
            group_slots = jnp.asarray(slots, jnp.int32)

        data = part_lib.gather_agent_data({"images": tx, "labels": ty}, idx)
        data = {k: jnp.asarray(v) for k, v in data.items()}
        test_batch = {"images": jnp.asarray(ex), "labels": jnp.asarray(ey)}

        key = jax.random.PRNGKey(cfg.seed)
        params0 = cnn_lib.init_params(model_cfg, key)
        state = rounds_lib.init_fleet(params0, N, cfg.dfl.cache_size,
                                      counts.astype(np.float32), group=group)
        mstate = self.mob_model.init(jax.random.PRNGKey(cfg.seed + 1), N,
                                     mob_cfg, band=band)
        wants_encounters = (
            self.policy.needs_encounters
            or self.policy_params.get("w_encounter", 0.0) != 0.0)
        if cfg.algorithm == "cached" and wants_encounters:
            # warm-start the per-pair encounter counts from the
            # mobility-stats subsystem: one epoch's contact roll-out on a
            # throwaway copy of the mobility state, so the policy has a
            # rate prior before any exchange happens
            n_steps = min(200, max(1, int(cfg.dfl.epoch_seconds
                                          / mob_cfg.step_seconds)))
            _, seq = mob_stats.collect_contacts(
                self.mob_model, mstate, jax.random.PRNGKey(cfg.seed + 3),
                mob_cfg, n_steps)
            est = mob_stats.encounter_stats(seq, mob_cfg.step_seconds)
            state = dataclasses.replace(
                state,
                encounters=est["encounter_counts"].astype(jnp.float32))
        return Fleet(model_cfg, state, data, jnp.asarray(counts), test_batch,
                     mstate, group_slots, self.mob_model, mob_cfg)
