"""Federated data partitioners (paper §4.1).

Three settings, matching the paper:
  * iid        — uniform random allocation;
  * non-iid    — extreme label-shard scheme (after Su et al.): data sorted
                 by label, split into 2N shards of 1-2 labels each, assigned
                 unevenly (10% of agents get 4 shards, 20% get 3, 30% get 2,
                 40% get 1);
  * dirichlet  — per-class Dirichlet(π) allocation across agents
                 (after Xiong et al.), default π = 0.5.

All partitioners return (index [N, cap] int32, counts [N] int32): fixed-
shape padded index arrays into the training set, ready for device-resident
per-agent sampling.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def _pad_indices(per_agent, cap=None):
    N = len(per_agent)
    cap = cap or max(1, max(len(a) for a in per_agent))
    idx = np.zeros((N, cap), np.int32)
    counts = np.zeros((N,), np.int32)
    for i, a in enumerate(per_agent):
        a = np.asarray(a[:cap], np.int32)
        idx[i, : len(a)] = a
        counts[i] = len(a)
    return idx, counts


def iid_partition(rng: np.random.Generator, labels: np.ndarray,
                  num_agents: int) -> Tuple[np.ndarray, np.ndarray]:
    n = len(labels)
    perm = rng.permutation(n)
    per_agent = np.array_split(perm, num_agents)
    return _pad_indices(per_agent)


def shards_noniid_partition(rng: np.random.Generator, labels: np.ndarray,
                            num_agents: int, shards_per_agent=(4, 3, 2, 1),
                            fractions=(0.1, 0.2, 0.3, 0.4)):
    """Paper's extreme non-iid: sort by label -> 2N shards -> uneven assign."""
    order = np.argsort(labels, kind="stable")
    # shard counts per agent (10% x4, 20% x3, 30% x2, 40% x1) -> total 2N
    counts = []
    for frac, spa in zip(fractions, shards_per_agent):
        counts += [spa] * int(round(frac * num_agents))
    while len(counts) < num_agents:
        counts.append(1)
    counts = np.asarray(counts[:num_agents])
    num_shards = int(counts.sum())
    shards = np.array_split(order, num_shards)
    shard_ids = rng.permutation(num_shards)
    per_agent, k = [], 0
    agent_order = rng.permutation(num_agents)
    agent_counts = counts[np.argsort(agent_order, kind="stable")]
    for i in range(num_agents):
        take = shard_ids[k : k + agent_counts[i]]
        k += agent_counts[i]
        per_agent.append(np.concatenate([shards[s] for s in take]))
    return _pad_indices(per_agent)


def dirichlet_partition(rng: np.random.Generator, labels: np.ndarray,
                        num_agents: int, pi: float = 0.5):
    """Per-class Dirichlet(π) proportions across agents."""
    classes = np.unique(labels)
    per_agent = [[] for _ in range(num_agents)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_agents, pi))
        splits = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, splits)):
            per_agent[i].extend(part.tolist())
    per_agent = [np.asarray(a, np.int32) for a in per_agent]
    # guarantee ≥1 sample per agent
    for i, a in enumerate(per_agent):
        if len(a) == 0:
            per_agent[i] = np.asarray([rng.integers(len(labels))], np.int32)
    return _pad_indices(per_agent)


def grouped_label_partition(rng: np.random.Generator, labels: np.ndarray,
                            num_agents: int, group_of_agent: np.ndarray,
                            area_labels: Sequence[Sequence[int]]):
    """Area-restricted label allocation for the GB-cache case study (§5.5).

    area_labels[g] lists the label classes available in area g (with
    n-overlap between areas, appendix B.1.1). Within each area, the paper's
    shard scheme distributes that area's data among its agents.
    """
    num_groups = len(area_labels)
    per_agent = [None] * num_agents
    for g in range(num_groups):
        agents = np.where(group_of_agent == g)[0]
        mask = np.isin(labels, np.asarray(area_labels[g]))
        idx = np.where(mask)[0]
        order = idx[np.argsort(labels[idx], kind="stable")]
        shards = np.array_split(order, 2 * len(agents))
        sid = rng.permutation(2 * len(agents))
        for i, a in enumerate(agents):
            per_agent[a] = np.concatenate(
                [shards[sid[2 * i]], shards[sid[2 * i + 1]]])
    cap = max(len(a) for a in per_agent)
    return _pad_indices(per_agent, cap)


def gather_agent_data(arrays: dict, idx: np.ndarray) -> dict:
    """Materialize per-agent data: {k: v[idx]} with leaves [N, cap, ...]."""
    return {k: np.asarray(v)[idx] for k, v in arrays.items()}
