from repro.fl.partition import (  # noqa: F401
    iid_partition, shards_noniid_partition, dirichlet_partition,
    grouped_label_partition, gather_agent_data,
)
