"""Named scenario presets — the curated entry points of the Scenario API.

A preset is a zero-argument factory returning a fully-formed
:class:`~repro.fl.scenario.Scenario`. Factories (not instances) are
registered so presets that need side artifacts (e.g. the synthetic
contact trace of ``trace-replay``) can materialize them lazily. Every
registered preset must ``resolve()`` without error — ``tests/
test_presets.py`` enforces that in tier-1 and ``tools/
check_scenarios.py`` smoke-runs each one.

    from repro import api
    result = api.run(api.get_preset("paper-noniid"))
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Callable, Dict, List, NamedTuple

import numpy as np

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.scenario import ExperimentConfig, Scenario


class Preset(NamedTuple):
    factory: Callable[[], Scenario]
    doc: str


_PRESETS: Dict[str, Preset] = {}


def register_preset(name: str, factory: Callable[[], Scenario],
                    doc: str = "") -> None:
    """Register a preset factory (third parties call this at import time)."""
    _PRESETS[name] = Preset(factory, doc)


def available_presets() -> List[str]:
    return sorted(_PRESETS)


def preset_doc(name: str) -> str:
    return _get(name).doc


def get_preset(name: str) -> Scenario:
    """Instantiate a registered preset (a fresh Scenario each call)."""
    scenario = _get(name).factory()
    return scenario if scenario.name else dataclasses.replace(scenario,
                                                              name=name)


def _get(name: str) -> Preset:
    if name not in _PRESETS:
        raise ValueError(f"unknown preset {name!r}; registered presets: "
                         f"{available_presets()}")
    return _PRESETS[name]


# ---------------------------------------------------------------------------
# built-in presets
# ---------------------------------------------------------------------------

def _paper_noniid() -> Scenario:
    """Paper §4.1 regime: 100 vehicles, Manhattan grid, non-iid shards,
    LRU caching (Alg. 2), ReduceLROnPlateau + early stop."""
    return Scenario(
        name="paper-noniid",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(), mobility=MobilityConfig(),
            epochs=200, early_stop_patience=20))


def _grouped_overlap() -> Scenario:
    """Paper Alg. 3 regime: grouped label areas with 1-label overlap and
    the group cache policy (per-group slots)."""
    return Scenario(
        name="grouped-overlap",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="grouped", num_groups=3,
            overlap=1,
            dfl=DFLConfig(policy="group", cache_size=9),
            mobility=MobilityConfig(),
            epochs=200))


def _budget_limited() -> Scenario:
    """Bandwidth-constrained exchange: a flat 2-entries-per-link cap
    (the middle of the BENCH_budget.json frontier)."""
    return Scenario(
        name="budget-limited",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(transfer_budget=2.0),
            epochs=200))


def _duration_budget() -> Scenario:
    """Physically-grounded budget: link capacity derived from the measured
    per-pair contact durations (entries = 0.1 x steps in contact)."""
    return Scenario(
        name="duration-budget",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(link_entries_per_step=0.1),
            epochs=200))


def _levy_sparse() -> Scenario:
    """Lévy-walk mobility on a large plane: heavy-tailed flights, sparse
    encounters — the stress case for cache staleness."""
    return Scenario(
        name="levy-sparse",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(policy="mobility_aware"),
            mobility=MobilityConfig(model="levy_walk", area_w=3000.0,
                                    area_h=3000.0, levy_max_flight=3000.0),
            epochs=200))


def _community_grouped() -> Scenario:
    """RPGM community mobility with the grouped distribution: band ==
    community id, so data groups and movement clusters coincide."""
    return Scenario(
        name="community-grouped",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="grouped", num_groups=3,
            dfl=DFLConfig(policy="group", cache_size=9),
            mobility=MobilityConfig(model="community", area_w=2000.0,
                                    area_h=2000.0, community_radius=200.0),
            epochs=200))


_TRACE_AGENTS = 8


def _synthetic_trace_path() -> str:
    """Materialize a bursty synthetic contact schedule for the
    trace-replay preset at a *stable* path: the schedule is seeded and
    the location deterministic, so the serialized spec reruns in other
    processes and its ``content_hash`` stays stable (the version tag
    bumps when the generator changes)."""
    path = os.path.join(tempfile.gettempdir(),
                        "repro-preset-trace-v1.npz")
    if os.path.exists(path):
        return path
    from repro.mobility import trace as trace_lib
    rng = np.random.default_rng(0)
    T, n = 600, _TRACE_AGENTS
    seq = np.zeros((T, n, n), bool)
    for _ in range(8 * n):
        i, j = rng.choice(n, size=2, replace=False)
        t0 = int(rng.integers(0, T - 6))
        seq[t0:t0 + int(rng.integers(2, 6)), i, j] = True
    # write-then-rename: a process killed mid-save must not leave a
    # truncated file at the stable path (exists() would trust it forever)
    scratch = tempfile.mktemp(suffix=".npz", prefix="repro-preset-trace-",
                              dir=tempfile.gettempdir())
    trace_lib.save_trace(scratch, seq | seq.transpose(0, 2, 1))
    os.replace(scratch, path)
    return path


def _trace_replay() -> Scenario:
    """Contact-schedule replay: the synthetic DTN-style trace stands in
    for real taxi/bus traces until a redistributable one is vendored."""
    return Scenario(
        name="trace-replay",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(num_agents=_TRACE_AGENTS, cache_size=4),
            mobility=MobilityConfig(model="trace",
                                    trace_path=_synthetic_trace_path(),
                                    trace_frames_per_epoch=30),
            epochs=100))


def _rush_hour() -> Scenario:
    """Diurnal contact load: a cosine activity envelope over the mobility
    clock gates contacts outside the rush-hour window. The period is 2x
    the default 120 s epoch span, so with amplitude 0.5 the first half of
    every epoch is rush hour and the second half radio silence — cached
    gossip must ride out the off-peak gaps."""
    return Scenario(
        name="rush-hour",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(),
            mobility=MobilityConfig(diurnal_period=240.0,
                                    diurnal_amplitude=0.5),
            epochs=200))


def _churn_city() -> Scenario:
    """Open-world fleet: staggered join/leave churn (each agent out of
    coverage 25% of every 8-epoch cycle) on the paper's Manhattan regime
    — dead agents freeze and stop meeting, but their cached models keep
    spreading through carriers (the DTN effect)."""
    return Scenario(
        name="churn-city",
        experiment=ExperimentConfig(
            algorithm="cached", distribution="noniid",
            dfl=DFLConfig(churn_period=8, churn_fraction=0.25),
            mobility=MobilityConfig(),
            epochs=200, early_stop_patience=20))


for _name, _factory in (
        ("paper-noniid", _paper_noniid),
        ("grouped-overlap", _grouped_overlap),
        ("budget-limited", _budget_limited),
        ("duration-budget", _duration_budget),
        ("levy-sparse", _levy_sparse),
        ("community-grouped", _community_grouped),
        ("trace-replay", _trace_replay),
        ("rush-hour", _rush_hour),
        ("churn-city", _churn_city)):
    register_preset(_name, _factory, (_factory.__doc__ or "").strip())
