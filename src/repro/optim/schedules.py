"""Learning-rate schedules, incl. a JAX/host reimplementation of PyTorch's
ReduceLROnPlateau, which the paper uses for all experiments."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReduceLROnPlateau:
    """Host-side plateau scheduler (mode='max' on average test accuracy)."""
    lr: float
    factor: float = 0.5
    patience: int = 5
    min_lr: float = 1e-4
    threshold: float = 1e-4
    mode: str = "max"
    _best: float = -np.inf
    _bad: int = 0

    def update(self, metric: float) -> float:
        improved = (metric > self._best + self.threshold
                    if self.mode == "max"
                    else metric < self._best - self.threshold)
        if improved:
            self._best = metric
            self._bad = 0
        else:
            self._bad += 1
            if self._bad > self.patience:
                self.lr = max(self.lr * self.factor, self.min_lr)
                self._bad = 0
        return self.lr


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0):
    def lr(step):
        if warmup and step < warmup:
            return base_lr * (step + 1) / warmup
        p = (step - warmup) / max(1, total_steps - warmup)
        return 0.5 * base_lr * (1 + np.cos(np.pi * min(p, 1.0)))
    return lr
