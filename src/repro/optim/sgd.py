"""SGD (paper's optimizer) with optional momentum and weight decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_zeros_like


def sgd_init(params, momentum: float = 0.0):
    return tree_zeros_like(params) if momentum else None


def sgd_update(params, grads, state, *, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    """Returns (new_params, new_state)."""
    if weight_decay:
        grads = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
    if momentum:
        state = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(m.dtype), state, grads)
        step = state
    else:
        step = grads
    params = jax.tree_util.tree_map(
        lambda p, s: (p.astype(jnp.float32)
                      - lr * s.astype(jnp.float32)).astype(p.dtype),
        params, step)
    return params, state
