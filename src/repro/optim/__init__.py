from repro.optim.sgd import sgd_init, sgd_update  # noqa: F401
from repro.optim.schedules import ReduceLROnPlateau, cosine_schedule  # noqa: F401
