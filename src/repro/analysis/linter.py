"""AST linter for the JAX footguns this repo actually has (RPR001-005).

Pure-``ast`` analysis — importing this module never imports jax, so
``tools/analyze.py --no-contracts`` stays sub-second. The rules encode
the repo's hard-won discipline (see ``docs/ANALYSIS.md`` for the
catalog and rationale):

RPR001  PRNG key reuse — a key consumed twice without a ``split`` /
        reassignment between uses, including single keys captured by
        closures passed to ``lax.scan`` / ``fori_loop`` /
        ``while_loop`` (each iteration would redraw the same stream).
RPR002  retrace hazards — config fields named in the traced-axes set
        (``dfl.lr``, ``dfl.transfer_budget``, ``epochs``) read as
        static closures inside jitted code or engine builders, and
        ``if`` / ``while`` on tracer-typed values (function parameters
        of jitted / loop-body functions). Shape-derived scalars
        (``x.shape[0]``, ``len(x)``) and ``is None`` tests are static
        and exempt.
RPR003  donation-after-use — reading a variable that was passed at a
        ``donate_argnums`` position of a donating jit call after that
        call, without rebinding it first (the buffer may be invalid).
RPR004  host-device sync in hot paths (``core/``, ``kernels/``, the
        engine loop in ``fl/runner.py``, ``telemetry/metrics.py``):
        ``.item()`` / ``.tolist()``, ``float()`` / ``int()`` /
        ``bool()`` on non-constant values, ``np.asarray`` /
        ``np.array``, ``jax.device_get``. Shape arithmetic
        (``x.shape[...]``, ``len(x)``) is static and exempt.
RPR005  dead code — unused imports (``# noqa`` re-exports, ``__all__``
        members and ``TYPE_CHECKING`` blocks are respected) and
        unreachable statements (code after return/raise/break/continue,
        ``if False:`` bodies).

Suppressions: ``# repro: allow=RPR004 <why>`` on the finding's line,
on the line directly above it, or on a ``def`` line (covers the whole
function). The justification text is mandatory in spirit and carried
into the finding.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

#: rule id -> one-line description (the catalog lives in docs/ANALYSIS.md)
RULES: Dict[str, str] = {
    "RPR001": "PRNG key reuse",
    "RPR002": "retrace hazard",
    "RPR003": "donation after use",
    "RPR004": "host-device sync in a hot path",
    "RPR005": "dead code / unused import",
}

#: dotted config paths the engines treat as traced scalars. Kept literal
#: here so the linter never imports jax; the contract verifier
#: (``repro.analysis.contracts``) pins it equal to
#: ``repro.fl.runner.TRACED_AXES``.
DEFAULT_TRACED_AXES = frozenset({"dfl.lr", "dfl.transfer_budget", "epochs"})

#: names that read like experiment configs (for the 1-component traced
#: axis ``epochs``, which would otherwise match any ``.epochs`` attr)
_CONFIG_NAMES = frozenset({"cfg", "config", "scenario", "experiment",
                           "exp", "rs"})

#: RPR004 scope: path fragments of the jit-hot files (normalized to "/")
HOT_PATH_PARTS = ("core/", "kernels/", "fl/runner.py",
                  "telemetry/metrics.py")

#: jax.random callees that do NOT consume a key's stream position
#: (fold_in derives an independent stream; the constructors create keys)
_NONCONSUMING = frozenset({"fold_in", "PRNGKey", "key", "key_data",
                           "wrap_key_data", "key_impl", "clone"})

_LOOP_COMBINATORS = {
    "jax.lax.scan": (0,), "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1), "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1,),
}
_TRACING_TRANSFORMS = ("jax.jit", "jax.vmap", "jax.pmap", "jax.grad",
                       "jax.value_and_grad")

#: attributes of array values that are static at trace time
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow=([A-Za-z0-9_,]+)\s*(.*)")
_NOQA_RE = re.compile(r"#\s*noqa\b", re.IGNORECASE)


# ---------------------------------------------------------------------------
# shared AST utilities
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` Attribute/Name chain -> ``"a.b.c"``, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _alias_map(tree: ast.Module) -> Dict[str, str]:
    """Import-alias resolution: ``jnp`` -> ``jax.numpy``, ``np`` ->
    ``numpy``, ``lax`` -> ``jax.lax`` (from-imports), etc."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom) and node.module \
                and not node.level:
            for a in node.names:
                if a.name != "*":
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _canon(dotted: Optional[str], aliases: Dict[str, str]) -> Optional[str]:
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def _call_name(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return _canon(_dotted(node.func), aliases)


def _target_names(target: ast.AST) -> List[str]:
    """Bare names bound by an assignment target (tuples flattened)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_target_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _functions(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _own_statements(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
    """The statements of a block, nested function bodies excluded."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield stmt


def _walk_shallow(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk that does not descend into nested function/class defs."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            stack.append(child)


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------

class Suppressions:
    """``# repro: allow=RPRnnn[,RPRmmm] why`` comments of one file.

    Matching order: the finding's own line, the line directly above, or
    a ``def``-line comment covering the whole function body."""

    def __init__(self, src: str, tree: Optional[ast.Module]):
        self.line_rules: Dict[int, Set[str]] = {}
        self.line_reason: Dict[int, str] = {}
        self.noqa_lines: Set[int] = set()
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(src).readline))
        except (tokenize.TokenError, IndentationError):
            tokens = []
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            if _NOQA_RE.search(tok.string):
                self.noqa_lines.add(tok.start[0])
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                rules = {r.strip().upper() for r in m.group(1).split(",")
                         if r.strip()}
                line = tok.start[0]
                self.line_rules.setdefault(line, set()).update(rules)
                self.line_reason[line] = m.group(2).strip()
        # def-scoped: a suppression on the def line (or a decorator line)
        # covers the function body
        self.ranges: List[Tuple[int, int, Set[str], str]] = []
        for fn in _functions(tree) if tree is not None else []:
            # the def line, decorator lines, or the line directly above
            # the def/first decorator all scope to the whole function
            head = [fn.lineno] + [d.lineno for d in fn.decorator_list]
            head.append(min(head) - 1)
            for line in head:
                if line in self.line_rules:
                    self.ranges.append(
                        (fn.lineno, fn.end_lineno or fn.lineno,
                         self.line_rules[line],
                         self.line_reason.get(line, "")))

    def match(self, rule: str, line: int) -> Optional[str]:
        """The justification text when (rule, line) is suppressed."""
        for cand in (line, line - 1):
            if rule in self.line_rules.get(cand, ()):
                return self.line_reason.get(cand, "") or "(no reason)"
        for start, end, rules, reason in self.ranges:
            if rule in rules and start <= line <= end:
                return reason or "(no reason)"
        return None


# ---------------------------------------------------------------------------
# RPR001 — PRNG key reuse
# ---------------------------------------------------------------------------

def _key_consumptions(stmt: ast.stmt, aliases: Dict[str, str]
                      ) -> List[Tuple[str, int]]:
    """(name, line) for every bare-Name key consumed by a jax.random
    call inside ``stmt`` (nested defs excluded)."""
    out: List[Tuple[str, int]] = []
    for node in _walk_shallow(stmt):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node, aliases)
        if not name or not name.startswith("jax.random."):
            continue
        if name.rsplit(".", 1)[1] in _NONCONSUMING:
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            out.append((node.args[0].id, node.lineno))
    return out


def _assigned_names(stmt: ast.stmt) -> List[str]:
    if isinstance(stmt, ast.Assign):
        out: List[str] = []
        for t in stmt.targets:
            out.extend(_target_names(t))
        return out
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return _target_names(stmt.target)
    if isinstance(stmt, ast.For):
        return _target_names(stmt.target)
    if isinstance(stmt, ast.With):
        out = []
        for item in stmt.items:
            if item.optional_vars is not None:
                out.extend(_target_names(item.optional_vars))
        return out
    return []


def _scan_key_block(body: Sequence[ast.stmt], consumed: Dict[str, int],
                    aliases: Dict[str, str],
                    hits: Set[Tuple[str, int, int]]) -> None:
    """Linear abstract scan: flag a second consumption of a key name
    with no rebinding in between. ``hits`` dedupes loop double-passes."""
    for stmt in _own_statements(body):
        if isinstance(stmt, ast.If):
            for node in _walk_shallow(stmt.test):
                pass  # consumptions in the test are handled below
            for name, line in _key_consumptions_expr(stmt.test, aliases):
                _consume(name, line, consumed, hits)
            before = dict(consumed)
            _scan_key_block(stmt.body, consumed, aliases, hits)
            other = dict(before)
            _scan_key_block(stmt.orelse, other, aliases, hits)
            for name, line in other.items():  # union of branch outcomes
                consumed.setdefault(name, line)
            continue
        if isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.While):
                for name, line in _key_consumptions_expr(stmt.test, aliases):
                    _consume(name, line, consumed, hits)
            # two passes catch a consume-without-rebind across iterations
            for _ in range(2):
                for t in _assigned_names(stmt):
                    consumed.pop(t, None)
                _scan_key_block(stmt.body, consumed, aliases, hits)
            _scan_key_block(stmt.orelse, consumed, aliases, hits)
            continue
        if isinstance(stmt, ast.Try):
            _scan_key_block(stmt.body, consumed, aliases, hits)
            for handler in stmt.handlers:
                _scan_key_block(handler.body, consumed, aliases, hits)
            _scan_key_block(stmt.orelse, consumed, aliases, hits)
            _scan_key_block(stmt.finalbody, consumed, aliases, hits)
            continue
        if isinstance(stmt, ast.With):
            for name, line in _key_consumptions(stmt, aliases):
                _consume(name, line, consumed, hits)
            for t in _assigned_names(stmt):
                consumed.pop(t, None)
            _scan_key_block(stmt.body, consumed, aliases, hits)
            continue
        # plain statement: consumptions first, then rebindings clear
        for name, line in _key_consumptions(stmt, aliases):
            _consume(name, line, consumed, hits)
        for t in _assigned_names(stmt):
            consumed.pop(t, None)


def _key_consumptions_expr(expr: ast.expr, aliases: Dict[str, str]
                           ) -> List[Tuple[str, int]]:
    wrapper = ast.Expr(value=expr)
    return _key_consumptions(wrapper, aliases)


def _consume(name: str, line: int, consumed: Dict[str, int],
             hits: Set[Tuple[str, int, int]]) -> None:
    if name in consumed:
        hits.add((name, consumed[name], line))
    else:
        consumed[name] = line


def _single_key_names(fn_body: Sequence[ast.stmt],
                      aliases: Dict[str, str]) -> Set[str]:
    """Names bound to a *single* PRNG key in this scope: PRNGKey /
    fold_in results, or elements of a tuple-unpacked split. A plain
    ``keys = split(k, n)`` binds a key *array* (safe to capture and
    index per-iteration) and is excluded."""
    out: Set[str] = set()
    for stmt in fn_body:
        for node in _walk_shallow(stmt):
            if not isinstance(node, ast.Assign):
                continue
            val = node.value
            if not isinstance(val, ast.Call):
                continue
            name = _call_name(val, aliases)
            if not name or not name.startswith("jax.random."):
                continue
            kind = name.rsplit(".", 1)[1]
            for t in node.targets:
                if kind in ("PRNGKey", "key", "fold_in") \
                        and isinstance(t, ast.Name):
                    out.add(t.id)
                elif kind == "split" and isinstance(t, (ast.Tuple, ast.List)):
                    out.update(_target_names(t))
    return out


def _loop_body_functions(fn: ast.FunctionDef, aliases: Dict[str, str]
                         ) -> List[ast.AST]:
    """Nested functions / lambdas passed as loop-combinator bodies."""
    named: Set[str] = set()
    inline: List[ast.AST] = []
    for node in _walk_shallow(ast.Module(body=list(fn.body),
                                         type_ignores=[])):
        if not isinstance(node, ast.Call):
            continue
        cname = _call_name(node, aliases)
        positions = _LOOP_COMBINATORS.get(cname or "")
        if not positions:
            continue
        for pos in positions:
            if pos < len(node.args):
                arg = node.args[pos]
                if isinstance(arg, ast.Name):
                    named.add(arg.id)
                elif isinstance(arg, (ast.Lambda,)):
                    inline.append(arg)
    for stmt in fn.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and stmt.name in named:
            inline.append(stmt)
    # also catch bodies defined anywhere within fn (e.g. inside an if)
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn and node.name in named \
                and node not in inline:
            inline.append(node)
    return inline


def check_rpr001(tree: ast.Module, aliases: Dict[str, str], path: str
                 ) -> List[Finding]:
    findings: List[Finding] = []
    scopes: List[Tuple[Sequence[ast.stmt], Optional[ast.FunctionDef]]] = \
        [(tree.body, None)]
    scopes += [(fn.body, fn) for fn in _functions(tree)]
    for body, fn in scopes:
        hits: Set[Tuple[str, int, int]] = set()
        _scan_key_block(body, {}, aliases, hits)
        for name, first, second in sorted(hits, key=lambda h: h[2]):
            findings.append(Finding(
                rule="RPR001", path=path, line=second,
                message=(f"PRNG key '{name}' consumed again without a "
                         f"split (first used at line {first})"),
                hint="split/fold_in the key (or rebind it from split) "
                     "between uses; identical keys draw identical "
                     "streams"))
        if fn is None:
            continue
        # keys captured by closures passed to lax loop combinators
        key_names = _single_key_names(fn.body, aliases)
        key_names.update(a.arg for a in fn.args.args
                         if a.arg in ("key", "rng"))
        for body_fn in _loop_body_functions(fn, aliases):
            params = {a.arg for a in body_fn.args.args} \
                if hasattr(body_fn, "args") else set()
            inner = body_fn.body if isinstance(body_fn, ast.Lambda) \
                else ast.Module(body=list(body_fn.body), type_ignores=[])
            # names rebound inside the body (e.g. carry unpacking) are
            # locals, not captures of the enclosing key
            if not isinstance(inner, ast.expr):
                for node in ast.walk(inner):
                    if isinstance(node, ast.stmt):
                        params.update(_assigned_names(node))
            for node in ast.walk(inner):
                if not isinstance(node, ast.Call):
                    continue
                cname = _call_name(node, aliases)
                if not cname or not cname.startswith("jax.random."):
                    continue
                if cname.rsplit(".", 1)[1] in _NONCONSUMING:
                    continue
                if node.args and isinstance(node.args[0], ast.Name):
                    kname = node.args[0].id
                    if kname in key_names and kname not in params:
                        findings.append(Finding(
                            rule="RPR001", path=path, line=node.lineno,
                            message=(f"PRNG key '{kname}' captured by a "
                                     "loop-body closure: every iteration "
                                     "draws from the same key"),
                            hint="fold_in the loop index, or thread the "
                                 "key through the scan/fori carry"))
    return findings


# ---------------------------------------------------------------------------
# RPR002 — retrace hazards
# ---------------------------------------------------------------------------

_BUILDER_RE = re.compile(r"^make_.*(engine|epoch|step|fn)", re.IGNORECASE)


def _is_jit_decorator(dec: ast.expr, aliases: Dict[str, str]) -> bool:
    name = _canon(_dotted(dec), aliases)
    if name in _TRACING_TRANSFORMS:
        return True
    if isinstance(dec, ast.Call):
        cname = _call_name(dec, aliases)
        if cname in _TRACING_TRANSFORMS:
            return True
        if cname == "functools.partial" and dec.args:
            return _canon(_dotted(dec.args[0]), aliases) \
                in _TRACING_TRANSFORMS
    return False


def _jit_connected(tree: ast.Module, aliases: Dict[str, str]
                   ) -> List[ast.FunctionDef]:
    """Functions whose bodies are traced: @jax.jit-decorated, wrapped by
    a same-module ``jax.jit(f, ...)`` / ``jax.vmap(f)`` call, passed as
    a lax loop-combinator body, or nested inside an engine/epoch builder
    (``make_*engine*`` etc. — those closures become the jitted engine).
    """
    marked: List[ast.FunctionDef] = []
    wrapped_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            cname = _call_name(node, aliases)
            if cname in _TRACING_TRANSFORMS and node.args \
                    and isinstance(node.args[0], ast.Name):
                wrapped_names.add(node.args[0].id)
            positions = _LOOP_COMBINATORS.get(cname or "")
            if positions:
                for pos in positions:
                    if pos < len(node.args) \
                            and isinstance(node.args[pos], ast.Name):
                        wrapped_names.add(node.args[pos].id)
    for fn in _functions(tree):
        if any(_is_jit_decorator(d, aliases) for d in fn.decorator_list):
            marked.append(fn)
        elif fn.name in wrapped_names:
            marked.append(fn)
    # nested defs inside engine builders
    for fn in _functions(tree):
        if _BUILDER_RE.match(fn.name):
            for node in ast.walk(fn):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node is not fn and node not in marked:
                    marked.append(node)
    return marked


def _tainted_expr(expr: ast.expr, tainted: Set[str]) -> Optional[str]:
    """The first tainted (tracer-typed) name read by ``expr`` outside a
    static context, or None. Static contexts: ``x.shape`` / ``.ndim`` /
    ``.dtype`` / ``.size`` attribute chains, ``len()`` / ``isinstance()``
    calls, and ``is (not) None`` comparisons."""

    def visit(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return None
        if isinstance(node, ast.Call):
            cname = _dotted(node.func)
            if cname in ("len", "isinstance", "getattr", "hasattr",
                         "type"):
                return None
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot))
                for op in node.ops):
            return None
        if isinstance(node, ast.Name) and node.id in tainted:
            return node.id
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            hit = visit(child)
            if hit:
                return hit
        return None

    return visit(expr)


def check_rpr002(tree: ast.Module, aliases: Dict[str, str], path: str,
                 traced_axes: Iterable[str] = DEFAULT_TRACED_AXES
                 ) -> List[Finding]:
    findings: List[Finding] = []
    axes = tuple(traced_axes)
    for fn in _jit_connected(tree, aliases):
        params = {a.arg for a in fn.args.args}
        params.update(a.arg for a in fn.args.kwonlyargs)
        params.update(a.arg for a in fn.args.posonlyargs)
        assigned: Set[str] = set()
        for stmt in ast.walk(fn):
            for name in _assigned_names(stmt) \
                    if isinstance(stmt, ast.stmt) else []:
                assigned.add(name)

        # (a) traced-axis config fields read as static closures
        for node in _walk_shallow(fn):
            dotted = _dotted(node) if isinstance(node, ast.Attribute) \
                else None
            if not dotted:
                continue
            for axis in axes:
                if dotted == axis or dotted.endswith("." + axis):
                    base = dotted[: -(len(axis) + 1)] \
                        if dotted.endswith("." + axis) else ""
                    if "." in base:
                        continue           # only cfg-rooted chains
                    if base and (base in params or base in assigned):
                        continue           # threaded in, not closed over
                    if "." not in axis and base not in _CONFIG_NAMES:
                        continue           # `.epochs` needs a cfg-ish base
                    findings.append(Finding(
                        rule="RPR002", path=path, line=node.lineno,
                        message=(f"traced-axis config field '{dotted}' "
                                 f"closed over statically inside jitted "
                                 f"code ('{axis}' is in TRACED_AXES)"),
                        hint="thread it through the jitted function's "
                             "arguments so sweeps don't retrace"))
                    break

        # (b) Python control flow on tracer-typed values
        taint = set(params)
        for stmt in _walk_shallow(fn):
            if isinstance(stmt, ast.Assign):
                if _tainted_expr(stmt.value, taint):
                    for t in stmt.targets:
                        taint.update(_target_names(t))
            elif isinstance(stmt, (ast.If, ast.While)):
                hit = _tainted_expr(stmt.test, taint)
                if hit:
                    kind = "while" if isinstance(stmt, ast.While) else "if"
                    findings.append(Finding(
                        rule="RPR002", path=path, line=stmt.lineno,
                        message=(f"`{kind}` on tracer-typed value "
                                 f"'{hit}' inside jitted code (traced "
                                 "booleans cannot branch at trace time)"),
                        hint="use jnp.where / lax.cond / lax.select, or "
                             "derive the branch from static shape info"))
            elif isinstance(stmt, ast.Assert):
                hit = _tainted_expr(stmt.test, taint)
                if hit:
                    findings.append(Finding(
                        rule="RPR002", path=path, line=stmt.lineno,
                        message=(f"`assert` on tracer-typed value "
                                 f"'{hit}' inside jitted code"),
                        hint="use checkify or a static precondition"))
    return findings


# ---------------------------------------------------------------------------
# RPR003 — donation after use
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call, aliases: Dict[str, str]
                       ) -> Optional[Set[int]]:
    """donate_argnums positions of a ``jax.jit(f, donate_argnums=...)``
    call (ints collected from any literal inside the kwarg), else None."""
    if _call_name(call, aliases) != "jax.jit":
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            positions = {n.value for n in ast.walk(kw.value)
                         if isinstance(n, ast.Constant)
                         and isinstance(n.value, int)
                         and not isinstance(n.value, bool)}
            return positions or None
    return None


def _scan_donate_block(body: Sequence[ast.stmt],
                       donated_fns: Dict[str, Set[int]],
                       dead: Dict[str, int],
                       aliases: Dict[str, str],
                       hits: Set[Tuple[str, int, int]]) -> None:
    for stmt in _own_statements(body):
        if isinstance(stmt, ast.If):
            before = dict(dead)
            _scan_donate_block(stmt.body, donated_fns, dead, aliases, hits)
            other = dict(before)
            _scan_donate_block(stmt.orelse, donated_fns, other, aliases,
                               hits)
            for name, line in other.items():
                dead.setdefault(name, line)
            continue
        if isinstance(stmt, (ast.For, ast.While)):
            for _ in range(2):
                _scan_donate_block(stmt.body, donated_fns, dead, aliases,
                                   hits)
            continue
        # 1) reads of dead names in this statement (before rebinding)
        reads = {n.id for n in _walk_shallow(stmt)
                 if isinstance(n, ast.Name)
                 and isinstance(n.ctx, ast.Load)}
        for name in sorted(reads & set(dead)):
            hits.add((name, dead[name], stmt.lineno))
            dead.pop(name)
        # 2) record donating calls; 3) new donated-jit bindings
        for node in _walk_shallow(stmt):
            if not isinstance(node, ast.Call):
                continue
            positions = _donated_positions(node, aliases)
            if positions is not None and isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    tname = _dotted(t)
                    if tname:
                        donated_fns[tname] = positions
            fname = _dotted(node.func)
            if fname in donated_fns:
                for pos in donated_fns[fname]:
                    if pos < len(node.args) \
                            and isinstance(node.args[pos], ast.Name):
                        dead[node.args[pos].id] = node.lineno
        # 4) rebindings resurrect names
        for t in _assigned_names(stmt):
            dead.pop(t, None)


def check_rpr003(tree: ast.Module, aliases: Dict[str, str], path: str
                 ) -> List[Finding]:
    findings: List[Finding] = []
    # donated-jit bindings visible anywhere (closures call them from
    # enclosing scopes); scope-local rebinds still override
    global_donated: Dict[str, Set[int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            positions = _donated_positions(node.value, aliases)
            if positions:
                for t in node.targets:
                    tname = _dotted(t)
                    if tname:
                        global_donated[tname] = positions
    scopes: List[Sequence[ast.stmt]] = [tree.body]
    scopes += [fn.body for fn in _functions(tree)]
    for body in scopes:
        hits: Set[Tuple[str, int, int]] = set()
        _scan_donate_block(body, dict(global_donated), {}, aliases, hits)
        for name, donated_line, use_line in sorted(hits,
                                                   key=lambda h: h[2]):
            findings.append(Finding(
                rule="RPR003", path=path, line=use_line,
                message=(f"'{name}' read after being donated at line "
                         f"{donated_line} (donate_argnums invalidates "
                         "the buffer)"),
                hint="rebind the variable from the donating call's "
                     "result, or drop it from donate_argnums"))
    return findings


# ---------------------------------------------------------------------------
# RPR004 — host-device sync in hot paths
# ---------------------------------------------------------------------------

_NP_SYNC = frozenset({"numpy.asarray", "numpy.array", "jax.device_get"})
_SYNC_METHODS = frozenset({"item", "tolist"})
_CAST_BUILTINS = frozenset({"float", "int", "bool"})


def is_hot_path(path: str) -> bool:
    norm = path.replace(os.sep, "/")
    return any(part in norm for part in HOT_PATH_PARTS)


def _static_cast_arg(arg: ast.expr) -> bool:
    """Casts of shape arithmetic / constants are trace-static."""
    if isinstance(arg, ast.Constant):
        return True
    for node in ast.walk(arg):
        if isinstance(node, ast.Attribute) and node.attr == "shape":
            return True
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name == "len":
                return True
    return False


def check_rpr004(tree: ast.Module, aliases: Dict[str, str], path: str
                 ) -> List[Finding]:
    if not is_hot_path(path):
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SYNC_METHODS and not node.args:
            findings.append(Finding(
                rule="RPR004", path=path, line=node.lineno,
                message=f".{node.func.attr}() forces a device-to-host "
                        "sync in a hot path",
                hint="keep the reduction on device (jnp) or move the "
                     "transfer to the eval boundary; suppress with "
                     "`# repro: allow=RPR004 <why>` if intentional"))
            continue
        cname = _call_name(node, aliases)
        if cname in _NP_SYNC and node.args \
                and not isinstance(node.args[0], ast.Constant):
            findings.append(Finding(
                rule="RPR004", path=path, line=node.lineno,
                message=f"{cname.split('.')[0]}.{cname.split('.')[-1]} "
                        "on a (potential) device value blocks on "
                        "transfer in a hot path",
                hint="use jnp on device, or suppress with "
                     "`# repro: allow=RPR004 <why>` at the host "
                     "boundary"))
        elif cname in _CAST_BUILTINS and len(node.args) == 1 \
                and not _static_cast_arg(node.args[0]):
            findings.append(Finding(
                rule="RPR004", path=path, line=node.lineno,
                message=f"{cname}() on a (potential) device value "
                        "forces a host sync in a hot path",
                hint="keep it as a jnp scalar, or suppress with "
                     "`# repro: allow=RPR004 <why>` if this is the "
                     "intended host boundary"))
    return findings


# ---------------------------------------------------------------------------
# RPR005 — dead code / unused imports
# ---------------------------------------------------------------------------

def _module_all(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    for node in ast.walk(stmt.value):
                        if isinstance(node, ast.Constant) \
                                and isinstance(node.value, str):
                            names.add(node.value)
    return names


def _type_checking_ranges(tree: ast.Module) -> List[Tuple[int, int]]:
    out: List[Tuple[int, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.If):
            dotted = _dotted(node.test)
            if dotted in ("TYPE_CHECKING", "typing.TYPE_CHECKING"):
                out.append((node.lineno, node.end_lineno or node.lineno))
    return out


def check_rpr005(tree: ast.Module, aliases: Dict[str, str], path: str,
                 suppressions: Optional[Suppressions] = None
                 ) -> List[Finding]:
    findings: List[Finding] = []
    exported = _module_all(tree)
    tc_ranges = _type_checking_ranges(tree)
    noqa = suppressions.noqa_lines if suppressions else set()

    # --- unused imports ---------------------------------------------------
    imports: List[Tuple[str, ast.stmt]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports.append((a.asname or a.name.split(".")[0], node))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                imports.append((a.asname or a.name, node))
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and not isinstance(node.ctx,
                                                         ast.Store):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            pass  # the base Name is visited separately
    for name, node in imports:
        if name in used or name in exported or name == "_":
            continue
        lines = range(node.lineno, (node.end_lineno or node.lineno) + 1)
        if any(ln in noqa for ln in lines):
            continue  # explicit re-export convention
        if any(start <= node.lineno <= end for start, end in tc_ranges):
            continue  # typing-only imports live in string annotations
        findings.append(Finding(
            rule="RPR005", path=path, line=node.lineno,
            message=f"unused import '{name}'",
            hint="remove it, or mark an intentional re-export with "
                 "`# noqa: F401`"))

    # --- unreachable statements -------------------------------------------
    def scan_block(body: Sequence[ast.stmt]) -> None:
        terminated = False
        for stmt in body:
            if terminated:
                findings.append(Finding(
                    rule="RPR005", path=path, line=stmt.lineno,
                    message="unreachable code (a break in control flow "
                            "precedes it)",
                    hint="delete it or restructure the early exit"))
                break
            if isinstance(stmt, (ast.Return, ast.Raise, ast.Break,
                                 ast.Continue)):
                terminated = True
            if isinstance(stmt, (ast.If, ast.While)) \
                    and isinstance(stmt.test, ast.Constant) \
                    and not stmt.test.value and stmt.body:
                findings.append(Finding(
                    rule="RPR005", path=path, line=stmt.body[0].lineno,
                    message="unreachable branch (constant-false test)",
                    hint="delete the dead branch"))

    for fn in _functions(tree):
        scan_block(fn.body)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.For)):
                scan_block(node.body)
                scan_block(node.orelse)
    scan_block(tree.body)
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

_CHECKS = {
    "RPR001": check_rpr001,
    "RPR002": check_rpr002,
    "RPR003": check_rpr003,
    "RPR004": check_rpr004,
    "RPR005": None,  # needs suppressions; dispatched explicitly below
}


def lint_source(src: str, path: str,
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one file's source text. ``select`` limits to those rule ids
    (default: all). Suppression comments mark findings, never drop them.
    """
    rules = set(select) if select else set(RULES)
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(rule="RPR000", path=path, line=e.lineno or 0,
                        message=f"syntax error: {e.msg}",
                        hint="the file does not parse; fix it first")]
    aliases = _alias_map(tree)
    supp = Suppressions(src, tree)
    findings: List[Finding] = []
    for rule in sorted(rules & set(RULES)):
        if rule == "RPR005":
            findings.extend(check_rpr005(tree, aliases, path, supp))
        else:
            check = _CHECKS[rule]
            findings.extend(check(tree, aliases, path))
    for f in findings:
        reason = supp.match(f.rule, f.line)
        if reason is not None:
            f.suppressed = True
            f.reason = reason
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(paths: Sequence[str],
               select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    findings: List[Finding] = []
    for fname in files:
        with open(fname, encoding="utf-8") as fh:
            src = fh.read()
        findings.extend(lint_source(src, fname, select=select))
    return findings
