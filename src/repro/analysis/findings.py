"""Finding objects shared by the AST linter and the contract verifier.

A :class:`Finding` is one diagnostic: rule id, file:line, a one-line
message and a fix hint. Findings are JSON-able (``to_dict``) and carry a
line-independent ``fingerprint`` so a baseline file keeps matching after
unrelated edits shift line numbers.

Baselines are plain JSON: ``{"schema": "repro-analysis-baseline-v1",
"fingerprints": [...]}``. ``apply_baseline`` marks (not drops) matching
findings, so ``--json`` output still shows what the baseline is hiding.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Sequence

SCHEMA = "repro-analysis-v1"
BASELINE_SCHEMA = "repro-analysis-baseline-v1"


@dataclasses.dataclass
class Finding:
    rule: str                 # "RPR001" .. "RPR005", "RPR1xx" (contracts)
    path: str                 # file the finding is anchored to
    line: int                 # 1-based; 0 = file/registry-level finding
    message: str
    hint: str = ""
    suppressed: bool = False  # matched a `# repro: allow=<rule>` comment
    reason: str = ""          # the suppression justification text
    baselined: bool = False   # matched a --baseline fingerprint

    @property
    def fingerprint(self) -> str:
        """Line-independent identity used by baseline files."""
        return f"{self.rule}:{self.path}:{self.message}"

    @property
    def active(self) -> bool:
        """True when the finding should fail the gate."""
        return not (self.suppressed or self.baselined)

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint,
                "suppressed": self.suppressed, "reason": self.reason,
                "baselined": self.baselined,
                "fingerprint": self.fingerprint}

    def format(self) -> str:
        mark = ""
        if self.suppressed:
            mark = f" [suppressed: {self.reason or 'no reason given'}]"
        elif self.baselined:
            mark = " [baselined]"
        loc = f"{self.path}:{self.line}" if self.line else self.path
        text = f"{loc}: {self.rule} {self.message}{mark}"
        if self.hint and not (self.suppressed or self.baselined):
            text += f"\n    hint: {self.hint}"
        return text


def to_document(findings: Sequence[Finding], *, wall_s: float = 0.0
                ) -> Dict[str, Any]:
    """The ``--json`` artifact (and what ``tools/report.py`` renders)."""
    active = [f for f in findings if f.active]
    per_rule: Dict[str, int] = {}
    for f in active:
        per_rule[f.rule] = per_rule.get(f.rule, 0) + 1
    return {
        "schema": SCHEMA,
        "findings": [f.to_dict() for f in findings],
        "counts": {
            "total": len(findings),
            "active": len(active),
            "suppressed": sum(f.suppressed for f in findings),
            "baselined": sum(f.baselined for f in findings),
            "per_rule": dict(sorted(per_rule.items())),
        },
        "wall_s": round(wall_s, 3),
    }


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Record every *active* finding's fingerprint as accepted debt."""
    doc = {"schema": BASELINE_SCHEMA,
           "fingerprints": sorted({f.fingerprint for f in findings
                                   if f.active})}
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


def load_baseline(path: str) -> List[str]:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: not a {BASELINE_SCHEMA} baseline file")
    return list(doc.get("fingerprints", []))


def apply_baseline(findings: Sequence[Finding],
                   fingerprints: Iterable[str]) -> List[Finding]:
    """Mark findings whose fingerprint the baseline accepts."""
    known = set(fingerprints)
    for f in findings:
        if f.fingerprint in known:
            f.baselined = True
    return list(findings)
