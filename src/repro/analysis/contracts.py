"""Abstract contract verifier: ``jax.eval_shape`` checks, zero FLOPs.

Where the linter (``repro.analysis.linter``) reads source text, this half
*traces* the registered subsystems abstractly and checks the protocol
contracts the runtime tests only catch slowly:

RPR101  mobility protocol — every registered model's ``simulate_epoch``
        returns ``(state, [N,N] bool, [N,N] int32)`` with the state
        treedef preserved, and every ``simulate_epoch_rows`` returns the
        matching ``[num_rows, W]`` block dtypes/shapes (``row_start``
        traced, so the block variant stays shard-compatible).
RPR102  cache-policy protocol — every registered policy's ``priority``
        returns ``(key [M] int32|float32, keep [M] bool)`` given exactly
        the context its ``needs_*`` flags declare, and ``retain``
        truncates to ``[capacity]`` with metadata structure preserved.
RPR103  shard-spec coverage — ``sharding.rules.fleet_specs`` covers a
        real ``FleetState`` pytree exactly (agent-leading leaves sharded,
        everything else replicated, no leaf missed) and
        ``telemetry.metrics.shard_specs`` mirrors the ``FleetMetrics``
        structure field-for-field.
RPR104  engine run contract — fused and sharded engines for every
        algorithm return ``(state, mstate, key, losses [chunk] f32)``
        with the fleet-state structure unchanged (donation and shard_map
        cannot silently alter the carry).
RPR105  engine-cache key — ``fl.runner._engine_key`` changes for every
        static binding the engine closes over, and does NOT change for
        traced scalars (lr, epochs, seed), so sweeps neither retrace nor
        wrongly share an engine. Also pins the linter's literal
        ``DEFAULT_TRACED_AXES`` equal to ``api.TRACED_AXES``.
RPR106  open-world contract — the churn liveness schedule traces on a
        traced epoch counter (no retrace per epoch), ``FleetState.live``
        exists and shards on the agent axis, churn-enabled engines keep
        the RPR104 run contract, and the diurnal envelope gates every
        registered mobility model (amplitude 1 silences all contacts;
        a fully-active envelope is bit-exact with envelope-off). The
        envelope checks run tiny *concrete* sims (4 agents, <= 4 steps)
        — the one exception to the zero-FLOPs rule, since gating is a
        value property eval_shape cannot see.

Every check is wrapped so a violation becomes a :class:`Finding`
anchored at the offending callable's def line, not a crashed run.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional

from repro.analysis.findings import Finding

CONTRACT_RULES = {
    "RPR101": "mobility protocol contract",
    "RPR102": "cache-policy protocol contract",
    "RPR103": "shard-spec pytree coverage",
    "RPR104": "engine run contract",
    "RPR105": "engine-cache key completeness",
    "RPR106": "open-world contract (churn + diurnal envelope)",
}


def _loc(fn: Callable) -> tuple:
    """(path, line) of a callable for finding anchors."""
    code = getattr(fn, "__code__", None)
    if code is None:  # partial / builtin — fall back to the module file
        mod = getattr(fn, "__module__", "")
        return (mod or "<unknown>", 0)
    return (code.co_filename, code.co_firstlineno)


def _finding(rule: str, fn: Optional[Callable], message: str,
             hint: str) -> Finding:
    path, line = _loc(fn) if fn is not None else ("<registry>", 0)
    return Finding(rule=rule, path=path, line=line, message=message,
                   hint=hint)


# ---------------------------------------------------------------------------
# RPR101 — mobility models
# ---------------------------------------------------------------------------

def _mobility_state(name: str, model, cfg, key, num_agents: int):
    import numpy as np
    if name == "trace":
        from repro.mobility import trace as trace_lib
        frames = np.zeros((4, num_agents, num_agents), bool)
        frames[:, 0, 1] = frames[:, 1, 0] = True
        return trace_lib.init_from_contacts(frames)
    return model.init(key, num_agents, cfg)


def verify_mobility(num_agents: int = 6) -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import MobilityConfig
    from repro.mobility import registry

    findings: List[Finding] = []
    key = jax.random.PRNGKey(0)
    rows_sigs = {}   # name -> (met dtype, dur dtype) for cross-model drift
    for name in registry.available():
        model = registry.get_model(name)
        cfg = MobilityConfig(model=name, trace_frames_per_epoch=2)
        try:
            state = _mobility_state(name, model, cfg, key, num_agents)
        except Exception as e:  # pragma: no cover - init itself broken
            findings.append(_finding(
                "RPR101", model.init,
                f"mobility model '{name}': init failed abstractly: {e}",
                "init(key, num_agents, cfg) must build a state pytree"))
            continue

        # --- dense simulate_epoch -> (state, [N,N] bool, [N,N] int32) ---
        try:
            out = jax.eval_shape(
                lambda s, k: model.simulate_epoch(s, k, cfg, 4.0),
                state, key)
        except Exception as e:
            findings.append(_finding(
                "RPR101", model.simulate_epoch,
                f"mobility model '{name}': simulate_epoch does not trace: "
                f"{e}",
                "signature must be (state, key, cfg, seconds)"))
            continue
        if not (isinstance(out, tuple) and len(out) == 3):
            findings.append(_finding(
                "RPR101", model.simulate_epoch,
                f"mobility model '{name}': simulate_epoch returned "
                f"{type(out).__name__}, expected a 3-tuple "
                "(state, met, dur)",
                "return (state, [N,N] bool union, [N,N] int32 durations)"))
            continue
        new_state, met, dur = out
        td_in = jax.tree_util.tree_structure(state)
        td_out = jax.tree_util.tree_structure(new_state)
        if td_in != td_out:
            findings.append(_finding(
                "RPR101", model.simulate_epoch,
                f"mobility model '{name}': simulate_epoch changed the "
                f"state treedef ({td_in} -> {td_out})",
                "the state pytree must round-trip unchanged"))
        checks = ((met, (num_agents, num_agents), jnp.bool_, "met"),
                  (dur, (num_agents, num_agents), jnp.int32, "dur"))
        for arr, shape, dtype, label in checks:
            if tuple(arr.shape) != shape or arr.dtype != dtype:
                findings.append(_finding(
                    "RPR101", model.simulate_epoch,
                    f"mobility model '{name}': simulate_epoch {label} is "
                    f"{arr.dtype}{list(arr.shape)}, expected "
                    f"{jnp.dtype(dtype).name}{list(shape)}",
                    "met must be [N,N] bool, dur [N,N] int32"))

        # --- block-local simulate_epoch_rows ----------------------------
        if model.simulate_epoch_rows is None:
            findings.append(_finding(
                "RPR101", model.simulate_epoch,
                f"mobility model '{name}': no simulate_epoch_rows — the "
                "sharded engine cannot run this model",
                "wire generic_simulate_epoch_rows(step, positions) or a "
                "bespoke block variant"))
            continue
        num_rows, W = 3, 4
        col_ids = jnp.arange(W, dtype=jnp.int32)
        row_start = jnp.zeros((), jnp.int32)   # traced: shard-compatible
        try:
            rout = jax.eval_shape(
                lambda s, k, rs, ci: model.simulate_epoch_rows(
                    s, k, cfg, 4.0, row_start=rs, num_rows=num_rows,
                    col_ids=ci),
                state, key, row_start, col_ids)
        except Exception as e:
            findings.append(_finding(
                "RPR101", model.simulate_epoch_rows,
                f"mobility model '{name}': simulate_epoch_rows does not "
                f"trace with a traced row_start: {e}",
                "signature must be (state, key, cfg, seconds, *, "
                "row_start, num_rows, col_ids) with row_start traced "
                "(use dynamic_slice, not static indexing)"))
            continue
        if not (isinstance(rout, tuple) and len(rout) == 3):
            findings.append(_finding(
                "RPR101", model.simulate_epoch_rows,
                f"mobility model '{name}': simulate_epoch_rows returned "
                f"{type(rout).__name__}, expected (state, met, dur)",
                "match the generic_simulate_epoch_rows contract"))
            continue
        _, rmet, rdur = rout
        for arr, dtype, label in ((rmet, jnp.bool_, "met"),
                                  (rdur, jnp.int32, "dur")):
            if tuple(arr.shape) != (num_rows, W) or arr.dtype != dtype:
                findings.append(_finding(
                    "RPR101", model.simulate_epoch_rows,
                    f"mobility model '{name}': simulate_epoch_rows "
                    f"{label} is {arr.dtype}{list(arr.shape)}, expected "
                    f"{jnp.dtype(dtype).name}[{num_rows}, {W}]",
                    "the block must be [num_rows, len(col_ids)]"))
        rows_sigs[name] = (str(rmet.dtype), str(rdur.dtype))
    if len(set(rows_sigs.values())) > 1:
        findings.append(_finding(
            "RPR101", None,
            "simulate_epoch_rows block dtypes drift across models: "
            + ", ".join(f"{n}={s}" for n, s in sorted(rows_sigs.items())),
            "all registered models must agree on (bool, int32) blocks"))
    return findings


# ---------------------------------------------------------------------------
# RPR102 — cache policies
# ---------------------------------------------------------------------------

def verify_policies(num_candidates: int = 7, capacity: int = 4,
                    num_agents: int = 6) -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.core.cache import CacheMeta
    from repro.policies import registry
    from repro.policies.base import PolicyContext, retain

    findings: List[Finding] = []
    M = num_candidates
    meta = CacheMeta(
        ts=jnp.arange(M, dtype=jnp.int32),
        origin=jnp.where(jnp.arange(M) < M - 1,
                         jnp.arange(M, dtype=jnp.int32) % num_agents,
                         -1).astype(jnp.int32),
        samples=jnp.full((M,), 8.0, jnp.float32),
        group=jnp.zeros((M,), jnp.int32),
        arrival=jnp.arange(M, dtype=jnp.int32))
    key = jax.random.PRNGKey(0)
    for name in registry.available():
        policy = registry.get_policy(name)
        ctx = PolicyContext(
            t=jnp.asarray(5, jnp.int32), capacity=capacity,
            rng=key if policy.needs_rng else None,
            group_slots=(jnp.asarray([2, 2], jnp.int32)
                         if policy.needs_group_slots else None),
            encounters=(jnp.ones((num_agents,), jnp.float32)
                        if policy.needs_encounters else None),
            params={})
        valid = meta.origin >= 0
        try:
            out = jax.eval_shape(
                lambda m, v: policy.priority(m, ctx, v), meta, valid)
        except Exception as e:
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': priority does not trace with "
                f"its declared context (needs_rng={policy.needs_rng}, "
                f"needs_group_slots={policy.needs_group_slots}, "
                f"needs_encounters={policy.needs_encounters}): {e}",
                "priority(meta, ctx, valid) must use only the context "
                "its needs_* flags request"))
            continue
        if not (isinstance(out, tuple) and len(out) == 2):
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': priority returned "
                f"{type(out).__name__}, expected (key, keep)",
                "return (score [M] int32|float32, keep [M] bool)"))
            continue
        score, keep = out
        if tuple(score.shape) != (M,) or score.dtype not in (
                jnp.int32, jnp.float32):
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': priority key is "
                f"{score.dtype}{list(score.shape)}, expected int32[{M}] "
                f"or float32[{M}]",
                "the sort score must be per-candidate, int32 or float32"))
        if tuple(keep.shape) != (M,) or keep.dtype != jnp.bool_:
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': priority keep mask is "
                f"{keep.dtype}{list(keep.shape)}, expected bool[{M}]",
                "keep must be a per-candidate bool mask"))
        # the shared retain engine must truncate to [capacity]
        try:
            sel, meta_sel = jax.eval_shape(
                lambda m: retain(m, policy, ctx), meta)
        except Exception as e:
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': retain() fails abstractly: {e}",
                "the policy must compose with policies.base.retain"))
            continue
        if tuple(sel.shape) != (capacity,):
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': retain sel is "
                f"{list(sel.shape)}, expected [{capacity}]",
                "retain must truncate to ctx.capacity"))
        if jax.tree_util.tree_structure(meta_sel) \
                != jax.tree_util.tree_structure(meta):
            findings.append(_finding(
                "RPR102", policy.priority,
                f"cache policy '{name}': retain changed the CacheMeta "
                "structure",
                "retain must return metadata with the input treedef"))
    return findings


# ---------------------------------------------------------------------------
# RPR103 — shard-spec pytree coverage
# ---------------------------------------------------------------------------

def verify_spec_coverage(num_agents: int = 6) -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.rounds import init_fleet
    from repro.sharding.rules import fleet_specs
    from repro.telemetry import metrics as metrics_lib

    findings: List[Finding] = []
    axis = "agents"

    # --- fleet_specs over a real FleetState --------------------------------
    template = {"w": jnp.zeros((3,), jnp.float32),
                "b": jnp.zeros((), jnp.float32)}
    state = jax.eval_shape(
        lambda: init_fleet(template, num_agents, 2,
                           jnp.ones((num_agents,), jnp.float32)))
    specs = fleet_specs(state, num_agents, axis)
    s_leaves, s_def = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    x_leaves, x_def = jax.tree_util.tree_flatten(state)
    if s_def != x_def or len(s_leaves) != len(x_leaves):
        findings.append(_finding(
            "RPR103", fleet_specs,
            f"fleet_specs does not cover the FleetState pytree: "
            f"{len(x_leaves)} leaves vs {len(s_leaves)} specs",
            "every FleetState leaf needs exactly one PartitionSpec"))
        return findings
    for leaf, spec in zip(x_leaves, s_leaves):
        if not isinstance(spec, P):
            findings.append(_finding(
                "RPR103", fleet_specs,
                f"fleet_specs produced a non-PartitionSpec leaf: "
                f"{spec!r}",
                "specs must be jax.sharding.PartitionSpec"))
            continue
        agent_leading = leaf.ndim >= 1 and leaf.shape[0] == num_agents
        want = P(axis, *([None] * (leaf.ndim - 1))) if agent_leading \
            else P()
        if spec != want:
            findings.append(_finding(
                "RPR103", fleet_specs,
                f"fleet_specs gave {spec} to a "
                f"{leaf.dtype}{list(leaf.shape)} leaf, expected {want}",
                "agent-leading leaves shard on the agent axis; "
                "everything else replicates"))

    # --- telemetry shard_specs mirrors FleetMetrics ------------------------
    m_template = jax.eval_shape(
        lambda: metrics_lib.init_metrics(num_agents, 11))
    try:
        m_specs = metrics_lib.shard_specs(axis)
    except TypeError as e:
        findings.append(_finding(
            "RPR103", metrics_lib.shard_specs,
            f"shard_specs no longer matches the FleetMetrics fields: {e}",
            "add a PartitionSpec for every FleetMetrics field"))
        return findings
    ms_leaves, ms_def = jax.tree_util.tree_flatten(
        m_specs, is_leaf=lambda x: isinstance(x, P))
    mt_leaves, mt_def = jax.tree_util.tree_flatten(m_template)
    if ms_def != mt_def or len(ms_leaves) != len(mt_leaves):
        findings.append(_finding(
            "RPR103", metrics_lib.shard_specs,
            f"shard_specs structure drifts from init_metrics: "
            f"{len(mt_leaves)} metric leaves vs {len(ms_leaves)} specs",
            "shard_specs must build the same FleetMetrics structure"))
        return findings
    for leaf, spec in zip(mt_leaves, ms_leaves):
        if not isinstance(spec, P):
            findings.append(_finding(
                "RPR103", metrics_lib.shard_specs,
                f"shard_specs produced a non-PartitionSpec leaf: "
                f"{spec!r}",
                "every FleetMetrics field needs an explicit spec"))
            continue
        is_origins = leaf.ndim == 2 and \
            leaf.shape == (num_agents, num_agents)
        want = P(axis, None) if is_origins else P()
        if spec != want:
            findings.append(_finding(
                "RPR103", metrics_lib.shard_specs,
                f"shard_specs gave {spec} to a "
                f"{leaf.dtype}{list(leaf.shape)} metrics leaf, expected "
                f"{want}",
                "only origins_seen rows follow the agent axis; the "
                "psum-reduced accumulators replicate"))
    return findings


# ---------------------------------------------------------------------------
# RPR104 — engine run contract (fused + sharded, every algorithm)
# ---------------------------------------------------------------------------

def _toy_setup(num_agents: int = 4):
    import jax.numpy as jnp

    from repro.core.rounds import init_fleet

    template = {"w": jnp.zeros((3,), jnp.float32)}
    state = init_fleet(template, num_agents, 2,
                       jnp.full((num_agents,), 8.0, jnp.float32))
    data = {"x": jnp.zeros((num_agents, 8, 3), jnp.float32),
            "y": jnp.zeros((num_agents, 8), jnp.float32)}
    counts = jnp.full((num_agents,), 8, jnp.int32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    return state, data, counts, loss_fn


def _toy_config(algorithm: str, num_agents: int = 4):
    from repro.configs.base import DFLConfig, MobilityConfig
    from repro.fl.scenario import ExperimentConfig

    return ExperimentConfig(
        algorithm=algorithm,
        dfl=DFLConfig(num_agents=num_agents, cache_size=2, local_steps=1,
                      batch_size=4, epoch_seconds=4.0),
        mobility=MobilityConfig(model="random_waypoint"),
        max_partners=2, eval_every=2, n_train=32, n_test=8)


def verify_engines(num_agents: int = 4, chunk: int = 2) -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.fl import experiment as experiment_lib
    from repro.launch.mesh import make_fleet_mesh
    from repro.mobility import registry as mob_registry

    findings: List[Finding] = []
    state, data, counts, loss_fn = _toy_setup(num_agents)
    key = jax.random.PRNGKey(0)
    mob_model = mob_registry.get_model("random_waypoint")
    mesh = make_fleet_mesh(1)
    for algorithm in ("cached", "dfl", "cfl"):
        cfg = _toy_config(algorithm, num_agents)
        mob_cfg = cfg.mobility
        mstate = mob_model.init(key, num_agents, mob_cfg)
        builders = {
            "fused": lambda: experiment_lib.make_engine(
                cfg, loss_fn=loss_fn, mob_model=mob_model,
                mob_cfg=mob_cfg, chunk=chunk, donate=False),
            "sharded": lambda: experiment_lib.make_sharded_engine(
                cfg, mesh=mesh, loss_fn=loss_fn, mob_model=mob_model,
                mob_cfg=mob_cfg, chunk=chunk, donate=False),
        }
        for kind, build in builders.items():
            anchor = experiment_lib.make_engine if kind == "fused" \
                else experiment_lib.make_sharded_engine
            try:
                eng = build()
                out = jax.eval_shape(
                    eng.run, state, mstate, key,
                    jnp.asarray(0.1, jnp.float32), data, counts,
                    jnp.asarray(chunk, jnp.int32))
            except Exception as e:
                findings.append(_finding(
                    "RPR104", anchor,
                    f"{kind} engine ({algorithm}): run does not trace "
                    f"abstractly: {e}",
                    "run(state, mstate, key, lr, data, counts, "
                    "num_epochs) must trace for every algorithm"))
                continue
            if not (isinstance(out, (tuple, list)) and len(out) == 4):
                findings.append(_finding(
                    "RPR104", anchor,
                    f"{kind} engine ({algorithm}): run returned "
                    f"{len(out) if isinstance(out, (tuple, list)) else type(out).__name__}"
                    " values, expected (state, mstate, key, losses)",
                    "telemetry-off engines return the 4-tuple contract"))
                continue
            new_state, _, _, losses = out
            in_shapes = [(tuple(x.shape), str(x.dtype))
                         for x in jax.tree_util.tree_leaves(state)]
            out_shapes = [(tuple(x.shape), str(x.dtype))
                          for x in jax.tree_util.tree_leaves(new_state)]
            if jax.tree_util.tree_structure(new_state) \
                    != jax.tree_util.tree_structure(state) \
                    or in_shapes != out_shapes:
                findings.append(_finding(
                    "RPR104", anchor,
                    f"{kind} engine ({algorithm}): run changed the "
                    "FleetState structure or leaf shapes/dtypes",
                    "the fleet-state carry must round-trip unchanged "
                    "(donation relies on matching buffers)"))
            if tuple(losses.shape) != (chunk,) \
                    or losses.dtype != jnp.float32:
                findings.append(_finding(
                    "RPR104", anchor,
                    f"{kind} engine ({algorithm}): losses is "
                    f"{losses.dtype}{list(losses.shape)}, expected "
                    f"float32[{chunk}]",
                    "losses must be the [chunk] per-epoch mean-loss "
                    "buffer (NaN past num_epochs)"))
    return findings


# ---------------------------------------------------------------------------
# RPR105 — engine-cache key completeness
# ---------------------------------------------------------------------------

#: static knobs the engines close over; each entry perturbs a resolved
#: scenario and must flip the engine-cache key. (field-path, new value)
_STATIC_KNOBS = [
    ("algorithm", "dfl"),
    ("distribution", "iid"),
    ("num_groups", 5),
    ("max_partners", 7),
    ("partner_sample", "random"),
    ("n_train", 1234),
    ("n_test", 321),
    ("dfl.num_agents", 12),
    ("dfl.cache_size", 3),
    ("dfl.tau_max", 4),
    ("dfl.local_steps", 2),
    ("dfl.batch_size", 16),
    ("dfl.rho", 0.5),
    ("dfl.epoch_seconds", 60.0),
    ("dfl.policy", "fifo"),
    ("dfl.policy_params", (("gamma", 0.5),)),
    ("dfl.staleness_decay", 0.9),
    ("dfl.link_entries_per_step", 2.0),
    ("dfl.shard_halo", 1),
    ("dfl.churn_period", 4),
    ("dfl.churn_fraction", 0.25),
    ("mobility.model", "levy_walk"),
    ("mobility.comm_range", 42.0),
    ("mobility.diurnal_amplitude", 0.5),
    ("mobility.diurnal_period", 500.0),
]

#: traced scalars — perturbing these must NOT flip the key
_TRACED_KNOBS = [("dfl.lr", 0.5), ("epochs", 99), ("seed", 7)]


def _replace_path(cfg, path: str, value):
    import dataclasses as _dc
    if "." in path:
        head, field = path.split(".", 1)
        sub = _dc.replace(getattr(cfg, head), **{field: value})
        return _dc.replace(cfg, **{head: sub})
    return _dc.replace(cfg, **{path: value})


def verify_engine_key() -> List[Finding]:
    import dataclasses as _dc

    from repro.fl import runner as runner_lib
    from repro.fl.scenario import Scenario

    findings: List[Finding] = []
    key_fn = runner_lib._engine_key
    base_rs = Scenario().resolve()
    base = key_fn(base_rs, chunk=2, traced_budget=False)

    def rs_with(cfg):
        # thread the perturbation into both the experiment and the
        # *resolved* mobility config (the key reads rs.mobility)
        sc = _dc.replace(base_rs.scenario, experiment=cfg)
        return _dc.replace(base_rs, scenario=sc, mobility=cfg.mobility)

    for path, value in _STATIC_KNOBS:
        cfg = _replace_path(base_rs.experiment, path, value)
        if key_fn(rs_with(cfg), chunk=2, traced_budget=False) == base:
            findings.append(_finding(
                "RPR105", key_fn,
                f"engine-cache key ignores static binding '{path}' — "
                "two scenarios differing only in it would share one "
                "compiled engine",
                "add the field to _engine_key's tuple"))
    for path, value in _TRACED_KNOBS:
        cfg = _replace_path(base_rs.experiment, path, value)
        if key_fn(rs_with(cfg), chunk=2, traced_budget=False) != base:
            findings.append(_finding(
                "RPR105", key_fn,
                f"engine-cache key changes with traced scalar '{path}' "
                "— sweeps over it would rebuild engines needlessly",
                "zero the traced scalar out of the key (see dfl_static)"))
    # traced-budget mode: transfer_budget becomes a traced scalar
    base_tb = key_fn(base_rs, chunk=2, traced_budget=True)
    cfg = _replace_path(base_rs.experiment, "dfl.transfer_budget", 3.0)
    if key_fn(rs_with(cfg), chunk=2, traced_budget=True) != base_tb:
        findings.append(_finding(
            "RPR105", key_fn,
            "engine-cache key changes with dfl.transfer_budget in "
            "traced-budget mode — the budget sweep would retrace",
            "zero transfer_budget out of the key when traced_budget"))
    if key_fn(rs_with(cfg), chunk=2, traced_budget=False) == base:
        findings.append(_finding(
            "RPR105", key_fn,
            "engine-cache key ignores dfl.transfer_budget in static "
            "mode — budget cells would wrongly share an engine",
            "keep transfer_budget in the key when not traced"))
    # engine kind / mesh / chunk / telemetry are static bindings too
    sc_engine = _dc.replace(base_rs.scenario, engine="sharded")
    if key_fn(_dc.replace(base_rs, scenario=sc_engine), chunk=2,
              traced_budget=False) == base:
        findings.append(_finding(
            "RPR105", key_fn,
            "engine-cache key ignores the engine kind",
            "fused and sharded engines must never share a cache slot"))
    if key_fn(base_rs, chunk=3, traced_budget=False) == base:
        findings.append(_finding(
            "RPR105", key_fn,
            "engine-cache key ignores the chunk size",
            "chunk sets the losses-buffer shape; include it"))
    if key_fn(base_rs, chunk=2, traced_budget=False,
              telemetry=True) == base:
        findings.append(_finding(
            "RPR105", key_fn,
            "engine-cache key ignores the telemetry flag",
            "the metrics carry changes the trace; include telemetry"))

    # linter's literal traced-axes set must match the runtime's
    from repro.analysis.linter import DEFAULT_TRACED_AXES
    if DEFAULT_TRACED_AXES != runner_lib.TRACED_AXES:
        findings.append(_finding(
            "RPR105", key_fn,
            "analysis.linter.DEFAULT_TRACED_AXES drifts from "
            f"api.TRACED_AXES: {sorted(DEFAULT_TRACED_AXES)} vs "
            f"{sorted(runner_lib.TRACED_AXES)}",
            "keep the linter's literal copy in sync with "
            "fl.runner.TRACED_AXES"))
    return findings


# ---------------------------------------------------------------------------
# RPR106 — open-world contract (churn liveness + diurnal envelope)
# ---------------------------------------------------------------------------

def verify_open_world(num_agents: int = 4, chunk: int = 2) -> List[Finding]:
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import MobilityConfig
    from repro.core import rounds as rounds_lib
    from repro.fl import experiment as experiment_lib
    from repro.launch.mesh import make_fleet_mesh
    from repro.mobility import registry as mob_registry
    from repro.sharding.rules import fleet_specs

    findings: List[Finding] = []
    key = jax.random.PRNGKey(0)

    # --- liveness schedule traces on a traced t -> [N] bool ----------------
    try:
        mask = jax.eval_shape(
            lambda t: rounds_lib.liveness_mask(t, num_agents, 4, 0.25),
            jax.ShapeDtypeStruct((), jnp.int32))
    except Exception as e:
        findings.append(_finding(
            "RPR106", rounds_lib.liveness_mask,
            f"liveness_mask does not trace on a traced epoch counter: {e}",
            "the schedule must be closed-form int32 arithmetic on t "
            "(no PRNG splits, no host round-trips)"))
    else:
        if tuple(mask.shape) != (num_agents,) or mask.dtype != jnp.bool_:
            findings.append(_finding(
                "RPR106", rounds_lib.liveness_mask,
                f"liveness_mask returns {mask.dtype}{list(mask.shape)}, "
                f"expected bool[{num_agents}]",
                "return one bool per agent, by global agent id"))

    # --- FleetState.live exists and shards on the agent axis ---------------
    template = {"w": jnp.zeros((3,), jnp.float32)}
    state = jax.eval_shape(
        lambda: rounds_lib.init_fleet(
            template, num_agents, 2, jnp.ones((num_agents,), jnp.float32)))
    live = getattr(state, "live", None)
    if live is None or tuple(live.shape) != (num_agents,) \
            or live.dtype != jnp.bool_:
        findings.append(_finding(
            "RPR106", rounds_lib.init_fleet,
            "init_fleet carries no bool[N] 'live' leaf — churn cannot "
            "thread through the fleet state",
            "FleetState.live must be an agent-leading bool mask"))
    else:
        spec = getattr(fleet_specs(state, num_agents, "agents"),
                       "live", None)
        if spec != P("agents"):
            findings.append(_finding(
                "RPR106", fleet_specs,
                f"fleet_specs gives {spec} to FleetState.live, expected "
                "P('agents')",
                "the liveness mask is agent-leading: shard its rows"))

    # --- churn-enabled engines keep the RPR104 run contract ----------------
    toy_state, data, counts, loss_fn = _toy_setup(num_agents)
    mob_model = mob_registry.get_model("random_waypoint")
    mesh = make_fleet_mesh(1)
    for algorithm in ("cached", "dfl", "cfl"):
        cfg = _toy_config(algorithm, num_agents)
        cfg = _dc.replace(cfg, dfl=_dc.replace(cfg.dfl, churn_period=4,
                                               churn_fraction=0.25))
        mstate = mob_model.init(key, num_agents, cfg.mobility)
        builders = {
            "fused": lambda: experiment_lib.make_engine(
                cfg, loss_fn=loss_fn, mob_model=mob_model,
                mob_cfg=cfg.mobility, chunk=chunk, donate=False),
            "sharded": lambda: experiment_lib.make_sharded_engine(
                cfg, mesh=mesh, loss_fn=loss_fn, mob_model=mob_model,
                mob_cfg=cfg.mobility, chunk=chunk, donate=False),
        }
        for kind, build in builders.items():
            anchor = experiment_lib.make_engine if kind == "fused" \
                else experiment_lib.make_sharded_engine
            try:
                eng = build()
                out = jax.eval_shape(
                    eng.run, toy_state, mstate, key,
                    jnp.asarray(0.1, jnp.float32), data, counts,
                    jnp.asarray(chunk, jnp.int32))
            except Exception as e:
                findings.append(_finding(
                    "RPR106", anchor,
                    f"{kind} engine ({algorithm}) with churn enabled does "
                    f"not trace abstractly: {e}",
                    "churn must stay a static gate over the existing "
                    "run(state, mstate, key, lr, data, counts, n) path"))
                continue
            new_state = out[0]
            in_s = [(tuple(x.shape), str(x.dtype))
                    for x in jax.tree_util.tree_leaves(toy_state)]
            out_s = [(tuple(x.shape), str(x.dtype))
                     for x in jax.tree_util.tree_leaves(new_state)]
            if jax.tree_util.tree_structure(new_state) \
                    != jax.tree_util.tree_structure(toy_state) \
                    or in_s != out_s:
                findings.append(_finding(
                    "RPR106", anchor,
                    f"{kind} engine ({algorithm}) with churn enabled "
                    "changed the FleetState structure or leaf "
                    "shapes/dtypes",
                    "the live mask must replace FleetState.live in place, "
                    "not grow the carry"))

    # --- diurnal envelope gates every registered mobility model ------------
    # tiny concrete sims: gating is a value property eval_shape cannot see.
    # period = 4x the 4 s epoch span keeps the float32 envelope measurably
    # below peak at every step time, so amplitude 1.0 must gate everything.
    for name in mob_registry.available():
        model = mob_registry.get_model(name)
        base_cfg = MobilityConfig(model=name, trace_frames_per_epoch=2,
                                  diurnal_period=16.0)
        outs = {}
        for amplitude in (1.0, 0.0, 1e-12):
            cfg_m = _dc.replace(base_cfg, diurnal_amplitude=amplitude)
            st = _mobility_state(name, model, cfg_m, key, num_agents)
            _, met, dur = model.simulate_epoch(st, key, cfg_m, 4.0)
            outs[amplitude] = (np.asarray(met), np.asarray(dur))
        met1, dur1 = outs[1.0]
        if met1.any() or dur1.sum() != 0:
            findings.append(_finding(
                "RPR106", model.simulate_epoch,
                f"mobility model '{name}': diurnal amplitude 1.0 leaks "
                f"{int(met1.sum())} contacts / {int(dur1.sum())} duration "
                "steps — the envelope does not gate this model",
                "mask each step's contacts with contact_envelope_active "
                "before the union/duration accumulation"))
        if not all(np.array_equal(a, b) for a, b
                   in zip(outs[0.0], outs[1e-12])):
            findings.append(_finding(
                "RPR106", model.simulate_epoch,
                f"mobility model '{name}': a fully-active envelope "
                "(amplitude 1e-12) diverges from the envelope-off path",
                "the diurnal gate must add masking only — never perturb "
                "the key stream or trajectories"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

_VERIFIERS = {
    "RPR101": lambda: verify_mobility(),
    "RPR102": lambda: verify_policies(),
    "RPR103": lambda: verify_spec_coverage(),
    "RPR104": lambda: verify_engines(),
    "RPR105": lambda: verify_engine_key(),
    "RPR106": lambda: verify_open_world(),
}


def verify_all(select: Optional[Iterable[str]] = None,
               root: Optional[str] = None) -> List[Finding]:
    """Run the contract verifiers (all, or the selected rule ids).

    ``root`` rewrites absolute finding paths to be relative to it, so
    findings match the linter's path style.
    """
    import os

    rules = set(select) if select else set(CONTRACT_RULES)
    findings: List[Finding] = []
    for rule in sorted(rules & set(CONTRACT_RULES)):
        findings.extend(_VERIFIERS[rule]())
    if root:
        root = os.path.abspath(root)
        for f in findings:
            if os.path.isabs(f.path):
                try:
                    f.path = os.path.relpath(f.path, root)
                except ValueError:
                    pass
    return findings
