"""repro.analysis — static analysis for the repo's JAX discipline.

Two halves behind one findings vocabulary (see ``docs/ANALYSIS.md``):

- :mod:`repro.analysis.linter` — pure-AST rules RPR001–RPR005 (PRNG key
  reuse, retrace hazards, donation-after-use, host syncs in hot paths,
  dead code). Importing it never imports jax.
- :mod:`repro.analysis.contracts` — ``jax.eval_shape`` contract
  verifiers RPR101–RPR105 (mobility/policy protocols, shard-spec
  coverage, engine run contract, engine-cache key completeness). Zero
  FLOPs: everything is checked abstractly.

``tools/analyze.py`` is the CLI; the tier-1 gate lives in
``tests/test_analysis.py`` (the repo ships analyzer-clean).
"""
from repro.analysis.findings import (  # noqa: F401
    BASELINE_SCHEMA, SCHEMA, Finding, apply_baseline, load_baseline,
    to_document, write_baseline)
from repro.analysis.linter import (  # noqa: F401
    DEFAULT_TRACED_AXES, RULES, Suppressions, lint_paths, lint_source)

__all__ = [
    "Finding", "SCHEMA", "BASELINE_SCHEMA", "RULES",
    "DEFAULT_TRACED_AXES", "Suppressions", "lint_paths", "lint_source",
    "verify_all", "to_document", "write_baseline", "load_baseline",
    "apply_baseline",
]


def verify_all(select=None, root=None):
    """Run the contract verifiers (lazy import: needs jax)."""
    from repro.analysis import contracts
    return contracts.verify_all(select=select, root=root)
