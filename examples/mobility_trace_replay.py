"""Drive Cached-DFL from a contact-trace schedule instead of simulated
motion — the workflow for replaying real DTN traces or crafted stress
scenarios through the unchanged experiment loop.

The demo builds a "commuter" schedule with community structure: agents
mostly meet inside their home cluster, plus a sparse set of cross-cluster
"commute" contacts — exactly the regime where model caching carries
information between communities. It saves the schedule as .npz (the
edge-list layout real traces arrive in), replays it end-to-end, and
prints the measured encounter statistics next to the learning curve.

    PYTHONPATH=src python examples/mobility_trace_replay.py [--epochs 12]
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import ExperimentConfig, run_experiment
from repro.mobility import stats
from repro.mobility import trace as trace_lib


def commuter_edges(n_agents: int, n_clusters: int, T: int, seed: int = 0):
    """Edge list [time, src, dst]: dense in-cluster meetings + rare bridges."""
    rng = np.random.default_rng(seed)
    cluster = np.arange(n_agents) % n_clusters
    time, src, dst = [], [], []
    for t in range(T):
        # in-cluster: each cluster holds one random rendezvous per frame
        for c in range(n_clusters):
            members = np.flatnonzero(cluster == c)
            if len(members) >= 2 and rng.random() < 0.6:
                i, j = rng.choice(members, size=2, replace=False)
                time.append(t), src.append(i), dst.append(j)
        # commute: occasionally a random cross-cluster pair meets
        if rng.random() < 0.15:
            i, j = rng.choice(n_agents, size=2, replace=False)
            time.append(t), src.append(i), dst.append(j)
    return np.asarray(time), np.asarray(src), np.asarray(dst)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--agents", type=int, default=12)
    ap.add_argument("--clusters", type=int, default=3)
    ap.add_argument("--trace", default="", help="existing .npz to replay")
    args = ap.parse_args()

    frames_per_epoch, T = 20, 20 * 40
    if args.trace:
        path = args.trace
    else:
        path = os.path.join(tempfile.mkdtemp(prefix="trace_replay_"),
                            "commuter.npz")
        t, i, j = commuter_edges(args.agents, args.clusters, T)
        np.savez_compressed(path, time=t, src=i, dst=j, num_steps=T,
                            num_agents=args.agents)
        print(f"wrote synthetic commuter trace: {path} "
              f"({len(t)} contact events, {T} frames)")

    mobility = MobilityConfig(model="trace", trace_path=path,
                              trace_frames_per_epoch=frames_per_epoch)

    # encounter statistics of the schedule we are about to replay
    seq, _ = trace_lib.load_trace(path)
    st = stats.encounter_stats(jax.numpy.asarray(seq), mobility.step_seconds)
    print("trace stats:", stats.summarize(st))

    cfg = ExperimentConfig(
        algorithm="cached",
        distribution="noniid",
        dfl=DFLConfig(num_agents=args.agents, cache_size=5, local_steps=5,
                      batch_size=32, epoch_seconds=frames_per_epoch),
        mobility=mobility,
        epochs=args.epochs,
        n_train=2000, n_test=400, image_hw=16,
        partner_sample="random",
        lr_plateau=False,
    )
    hist = run_experiment(cfg, verbose=True)
    print(f"replay: best_acc={hist['best_acc']:.4f} "
          f"epochs={len(hist['epoch'])} wall={hist['wall_s']:.0f}s")


if __name__ == "__main__":
    main()
