"""Quickstart: the Cached-DFL public API in ~60 lines.

Builds a 8-vehicle fleet on the Manhattan grid, trains the paper's MNIST
CNN on synthetic non-iid data with LRU model caching, and prints the
average-test-accuracy curve.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MobilityConfig
from repro.configs.paper_models import MNIST_CNN
from repro.core import rounds
from repro.data.synthetic import make_image_dataset
from repro.fl.partition import gather_agent_data, shards_noniid_partition
from repro.mobility import manhattan as mob
from repro.models import cnn

N, EPOCHS, CACHE, TAU_MAX = 8, 10, 4, 10

# 1) data: synthetic MNIST-like, extreme non-iid label shards
tx, ty, ex, ey = make_image_dataset(0, n_train=1500, n_test=300, hw=16)
idx, counts = shards_noniid_partition(np.random.default_rng(0), ty, N)
data = {k: jnp.asarray(v) for k, v in
        gather_agent_data({"images": tx, "labels": ty}, idx).items()}

# 2) fleet: N agents, each with its own model + model cache
model_cfg = MNIST_CNN.__class__(**{**MNIST_CNN.__dict__, "image_hw": 16})
params0 = cnn.init_params(model_cfg, jax.random.PRNGKey(0))
state = rounds.init_fleet(params0, N, cache_size=CACHE,
                          samples=counts.astype(np.float32))

# 3) mobility: Manhattan grid, 100 m DSRC range
mcfg = MobilityConfig(grid_w=4, grid_h=6)
mstate = mob.init_mobility(jax.random.PRNGKey(1), N, mcfg)

# 4) one compiled program per epoch: local SGD + exchange + aggregation
loss_fn = lambda p, b: cnn.loss_fn(p, model_cfg, b["images"], b["labels"])
acc_fn = lambda p, b: cnn.accuracy(p, model_cfg, b["images"], b["labels"])
epoch = jax.jit(functools.partial(
    rounds.cached_dfl_epoch, loss_fn=loss_fn, local_steps=5, batch_size=32,
    lr=0.1, tau_max=TAU_MAX, policy="lru"))
simulate = jax.jit(functools.partial(mob.simulate_epoch, cfg=mcfg,
                                     seconds=60.0))
test = {"images": jnp.asarray(ex), "labels": jnp.asarray(ey)}

key = jax.random.PRNGKey(2)
for ep in range(EPOCHS):
    key, k1, k2 = jax.random.split(key, 3)
    mstate, met, _dur = simulate(mstate, k1)
    partners = mob.partners_from_contacts(met, 4)
    state, _ = epoch(state, partners, data, jnp.asarray(counts), k2)
    acc, _ = rounds.fleet_accuracy(state, acc_fn, test)
    cached = float(jnp.mean(jnp.sum(state.cache.valid, 1)))
    print(f"epoch {ep + 1:2d}  avg_acc={float(acc):.3f} "
          f"avg_cached_models={cached:.1f}")
