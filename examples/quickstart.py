"""Quickstart: the Cached-DFL Scenario API in ~30 lines.

A declarative, serializable experiment spec drives everything: build a
Scenario (8 vehicles on the Manhattan grid, the paper's MNIST CNN on
synthetic non-iid data, LRU model caching), run it through the fused
fleet engine, and print the typed result.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import api

# 1) the spec: start from defaults, override via dotted paths — any
#    ExperimentConfig / DFLConfig / MobilityConfig field is reachable
scenario = api.Scenario(record_cache_stats=True,
                        telemetry=True).with_overrides({
    "algorithm": "cached",
    "distribution": "noniid",        # extreme label shards (paper §4.1)
    "dfl.num_agents": 8,
    "dfl.cache_size": 4,
    "dfl.local_steps": 5,
    "dfl.batch_size": 32,
    "dfl.epoch_seconds": 60.0,
    "mobility.grid_w": 4,            # Manhattan grid, 100 m DSRC range
    "mobility.grid_h": 6,
    "epochs": 10,
    "n_train": 1500,
    "n_test": 300,
    "image_hw": 16,
    "lr_plateau": False,
})

# 2) specs are serializable artifacts: share them, diff them, rerun them
print(f"config hash {scenario.content_hash()}")
# open("scenario.json", "w").write(scenario.to_json())
# scenario = api.Scenario.from_json(open("scenario.json").read())

# 3) run: mobility sim -> contacts -> local SGD + cache exchange +
#    aggregation, fused into one compiled program per eval chunk
result = api.run(scenario)

# 4) a typed RunResult instead of an untyped dict
for ep, acc, cached in zip(result.epoch, result.acc, result.cache_num):
    print(f"epoch {ep:2d}  avg_acc={acc:.3f} avg_cached_models={cached:.1f}")
print(f"best {result.best_acc:.3f} (epoch {result.best_epoch}) "
      f"in {result.wall_s:.1f}s, {result.traces} compile(s)")

# 5) telemetry=True adds on-device fleet metrics (staleness, spread,
#    gossip traffic), phase timings and a structured event stream —
#    bit-exact with a telemetry-off run
print(api.telemetry_line(result))

# 6) city-scale fleets: shard the epoch over a device mesh (engine +
#    mesh are Scenario fields; --engine/--mesh on the train.py CLI).
#    On CPU, force host devices before jax starts:
#      XLA_FLAGS=--xla_force_host_platform_device_count=4 \
#        PYTHONPATH=src python examples/quickstart.py
# result = api.run(dataclasses.replace(
#     scenario.with_overrides({"partner_sample": "lowest-id"}),
#     engine="sharded", mesh=0))   # 0 = all visible devices

# 7) hacking on the engines/policies/mobility models? gate your change
#    statically first — trace discipline, PRNG hygiene, protocol/shard
#    contracts (rule catalog: docs/ANALYSIS.md):
#      python tools/analyze.py src/

# 8) many scenarios? stream specs through the scenario service: it
#    groups same-engine-key specs into waves sharing one compiled
#    engine and emits schema-validated JSONL (repro-fleet-serve-v1).
#    lr/epochs are traced knobs, so both specs below share one engine
#    (the second row reports traces: 0). Same thing over stdin:
#      echo '{"rid":"a","preset":"paper-noniid"}' | \
#        PYTHONPATH=src python -m repro.launch.fleet_serve
import sys
svc = api.ScenarioService(out=sys.stdout)
svc.submit({"rid": "base", "scenario": scenario.to_dict()})
svc.submit({"rid": "hot-lr", "scenario": scenario.to_dict(),
            "overrides": {"dfl.lr": 0.05}})
summary = svc.drain()
assert summary["retraces"] == 0, summary   # one engine, two runs
