"""Pod-scale Cached-DFL on a language model: the production deployment
pattern the multi-pod dry-run proves out, runnable on CPU with reduced
configs — multiple pod-agents each fine-tune a transformer on their own
token stream, exchange models DTN-style, and aggregate their caches.

    PYTHONPATH=src python examples/pod_dfl_lm.py --arch qwen2-7b --rounds 8
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry as R
from repro.data.synthetic import make_lm_dataset
from repro.launch import steps as steps_lib
from repro.models import registry as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=R.ARCH_IDS)
    ap.add_argument("--agents", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = R.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    A = args.agents

    # each agent has its own (non-iid) bigram universe
    streams = [jnp.asarray(make_lm_dataset(seed, vocab=cfg.vocab,
                                           seq_len=args.seq_len, n_seq=64))
               for seed in range(A)]

    params = jax.vmap(lambda k: M.init_params(cfg, k))(
        jax.random.split(key, A))
    cache = steps_lib.init_pod_cache(cfg, M.init_params(cfg, key), 2,
                                     agents=A)
    step = jax.jit(steps_lib.make_train_step(cfg, lr=0.1, multi_pod=True,
                                             tau_max=6))

    for t in range(args.rounds):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (A, args.batch), 0, streams[0].shape[0])
        toks = jnp.stack([s[i] for s, i in zip(streams, idx)])
        batch = {"tokens": toks}
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (A, args.batch, cfg.image_tokens, cfg.d_model))
        params, cache, loss = step(params, cache, batch,
                                   jnp.asarray(t, jnp.int32))
        ages = jnp.where(cache.valid, t - cache.ts, -1)
        print(f"round {t:2d}  loss={float(loss):.4f}  "
              f"cache_entries={int(jnp.sum(cache.valid))}  "
              f"max_staleness={int(jnp.max(ages))}")


if __name__ == "__main__":
    main()
