"""Group-Based cache update case study (paper §5.5): three areas with
area-restricted vehicles and non-overlapping label distributions; compares
GB caching vs vanilla LRU.

    PYTHONPATH=src python examples/group_caching.py [--overlap 0]
"""
import argparse
import dataclasses

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import ExperimentConfig, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--overlap", type=int, default=0,
                    help="label classes shared between areas (paper: 0-3)")
    ap.add_argument("--epochs", type=int, default=25)
    args = ap.parse_args()

    base = ExperimentConfig(
        distribution="grouped",
        overlap=args.overlap,
        dfl=DFLConfig(num_agents=12, cache_size=6, tau_max=10,
                      local_steps=5, lr=0.1, batch_size=32,
                      epoch_seconds=60.0),
        mobility=MobilityConfig(grid_w=4, grid_h=9),
        epochs=args.epochs,
        n_train=3000,
        n_test=600,
        image_hw=16,
        lr_plateau=False,
    )
    for policy in ("group", "lru"):
        cfg = dataclasses.replace(
            base, dfl=dataclasses.replace(base.dfl, policy=policy))
        hist = run_experiment(cfg)
        print(f"{policy:>5}: best_acc={hist['best_acc']:.4f} "
              f"curve={[round(a, 3) for a in hist['acc']]}")


if __name__ == "__main__":
    main()
