"""End-to-end driver (the paper's kind of workload): train a vehicle fleet
for a few hundred global epochs with Cached-DFL vs the DFL baseline, with
ReduceLROnPlateau + early stopping exactly as §4.3/§B.4 prescribe —
expressed as one Scenario spec swept over the algorithm axis.

    PYTHONPATH=src python examples/vehicular_cached_dfl.py [--epochs 200]
"""
import argparse
import json

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--agents", type=int, default=20)
    ap.add_argument("--distribution", default="noniid")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    base = api.Scenario(verbose=True).with_overrides({
        "model": "paper-mnist-cnn",
        "distribution": args.distribution,
        "dfl.num_agents": args.agents,
        "dfl.cache_size": 10,
        "dfl.tau_max": 10,
        "dfl.local_steps": 10,
        "dfl.lr": 0.1,
        "dfl.batch_size": 64,
        "dfl.epoch_seconds": 120.0,
        "mobility.grid_w": 6,
        "mobility.grid_h": 12,
        "epochs": args.epochs,
        "n_train": 6000,
        "n_test": 1000,
        "image_hw": 20,
        "early_stop_patience": 20,   # paper's early stop
    })
    results = {}
    for alg in ("cached", "dfl"):
        print(f"=== {alg} ===")
        result = api.run(base.with_overrides({"algorithm": alg}))
        results[alg] = result
        print(f"{alg}: best={result.best_acc:.4f} "
              f"epochs={len(result.epoch)} wall={result.wall_s:.0f}s\n")
    print("summary:",
          {k: round(v.best_acc, 4) for k, v in results.items()})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({k: v.to_dict() for k, v in results.items()}, f,
                      indent=1)


if __name__ == "__main__":
    main()
