"""End-to-end driver (the paper's kind of workload): train a vehicle fleet
for a few hundred global epochs with Cached-DFL vs the DFL baseline, with
ReduceLROnPlateau + early stopping exactly as §4.3/§B.4 prescribe.

    PYTHONPATH=src python examples/vehicular_cached_dfl.py [--epochs 200]
"""
import argparse
import dataclasses
import json

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import ExperimentConfig, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--agents", type=int, default=20)
    ap.add_argument("--distribution", default="noniid")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    base = ExperimentConfig(
        model="paper-mnist-cnn",
        distribution=args.distribution,
        dfl=DFLConfig(num_agents=args.agents, cache_size=10, tau_max=10,
                      local_steps=10, lr=0.1, batch_size=64,
                      epoch_seconds=120.0),
        mobility=MobilityConfig(grid_w=6, grid_h=12),
        epochs=args.epochs,
        n_train=6000,
        n_test=1000,
        image_hw=20,
        early_stop_patience=20,   # paper's early stop
    )
    results = {}
    for alg in ("cached", "dfl"):
        cfg = dataclasses.replace(base, algorithm=alg)
        print(f"=== {alg} ===")
        hist = run_experiment(cfg, verbose=True)
        results[alg] = hist
        print(f"{alg}: best={hist['best_acc']:.4f} "
              f"epochs={len(hist['epoch'])} wall={hist['wall_s']:.0f}s\n")
    print("summary:",
          {k: round(v["best_acc"], 4) for k, v in results.items()})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
