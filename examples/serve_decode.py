"""Batched serving example: prefill + autoregressive decode on a reduced
assigned architecture, through the same decode path the dry-run lowers for
decode_32k/long_500k (incl. the Pallas decode-attention kernel).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.models import registry as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    choices=R.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()

    cfg = R.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B = args.batch
    batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0,
                                          cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.image_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch = {"frames": jax.random.normal(
            key, (B, cfg.enc_context, cfg.d_model))}

    logits, state = (None, M.prefill(params, cfg, batch,
                                     max_len=args.prompt_len + args.tokens)[1]) \
        if cfg.enc_dec else M.prefill(params, cfg, batch,
                                      max_len=args.prompt_len + args.tokens)
    decode = jax.jit(lambda p, s, t: M.decode_step(
        p, cfg, s, t, use_kernel=args.use_kernel))
    tok = (jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
           if logits is not None else jnp.zeros((B, 1), jnp.int32))
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{args.arch}: {B} seqs x {args.tokens} tokens in {dt:.2f}s "
          f"({B * args.tokens / dt:.1f} tok/s, "
          f"kernel={'pallas' if args.use_kernel else 'jnp'})")
    print("generated:", np.asarray(jnp.concatenate(out, 1))[0][:12], "...")


if __name__ == "__main__":
    main()
