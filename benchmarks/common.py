"""Shared scaled-down fleet settings for the paper-figure benchmarks.

The paper trains 100 vehicles for 1000 epochs on real MNIST; on this CPU
container each benchmark uses a 10-vehicle fleet, 16×16 synthetic images
and ~12 epochs — enough to reproduce the paper's *qualitative orderings*
(EXPERIMENTS.md maps each benchmark to its paper figure/table).

``base_scenario()`` is the Scenario-API entry point — the sweep-driven
benchmarks (`bench_cache_policies`, `bench_mobility_models`,
`bench_transfer_budget`) build their grids on it and emit artifacts via
``SweepResult.write_bench``; ``run()`` keeps the historical dict
interface for the single-run benchmarks.
"""
from __future__ import annotations

import dataclasses
import os

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

BASE = dict(
    dfl=DFLConfig(num_agents=10, cache_size=5, tau_max=10, local_steps=5,
                  lr=0.1, batch_size=32, epoch_seconds=60.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=6 if FAST else 14,
    n_train=2000,
    n_test=400,
    image_hw=16,
    lr_plateau=False,
    early_stop_patience=100,
)


def base_scenario(algorithm="cached", distribution="noniid", seed=0,
                  **overrides) -> api.Scenario:
    """The benchmarks' shared scaled-down fleet as a Scenario spec."""
    kw = {**BASE, **overrides}
    return api.Scenario(
        experiment=api.ExperimentConfig(
            algorithm=algorithm, distribution=distribution, seed=seed, **kw),
        record_cache_stats=True)


def run(algorithm="cached", distribution="noniid", seed=0, **overrides):
    """Historical dict interface (single-run benchmarks)."""
    scenario = base_scenario(algorithm=algorithm, distribution=distribution,
                             seed=seed, **overrides)
    return api.run(scenario).history()


def bench_out(filename: str) -> str:
    """Repo-root path for a BENCH_*.json artifact."""
    return os.path.join(os.path.dirname(__file__), "..", filename)


def emit(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line
