"""Roofline report: reads the dry-run artifacts (experiments/dryrun) and
emits the three roofline terms per (arch × shape) on the single-pod mesh.
us_per_call = the dominant (bottleneck) term in µs for one step.
Run `python -m repro.launch.dryrun --all --mesh both` first to (re)generate.
"""
import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def main():
    lines = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*_single.json")))
    if not files:
        lines.append(emit("roofline_missing", 0.0,
                          f"no dry-run artifacts in {DRYRUN_DIR}"))
        return lines
    n_ok = n_skip = 0
    for path in files:
        with open(path) as f:
            r = json.load(f)
        name = f"roofline_{r['arch']}_{r['shape']}"
        if r["status"] == "skip":
            n_skip += 1
            lines.append(emit(name, 0.0, "skip_sanctioned"))
            continue
        if r["status"] != "ok" or "roofline" not in r:
            lines.append(emit(name, 0.0, f"status={r['status']}"))
            continue
        n_ok += 1
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        lines.append(emit(
            name, dom * 1e6,
            f"bottleneck={t['bottleneck']};comp_ms={t['compute_s']*1e3:.2f};"
            f"mem_ms={t['memory_s']*1e3:.2f};"
            f"coll_ms={t['collective_s']*1e3:.2f};"
            f"useful={t['useful_flops_ratio']:.2f}"))
    lines.append(emit("roofline_coverage", 0.0,
                      f"ok={n_ok};skip={n_skip};total={len(files)}"))
    return lines


if __name__ == "__main__":
    main()
