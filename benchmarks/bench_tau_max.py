"""Paper Fig. 4: impact of τ_max on convergence (non-iid vs iid).

Claims: larger τ_max speeds early convergence under non-iid; under iid it
stops helping (staleness only hurts).
"""
import dataclasses

from benchmarks.common import BASE, emit, run


from repro.configs.base import MobilityConfig

# Sparse contacts so cached entries actually age (τ_max binds).
SPARSE = MobilityConfig(grid_w=8, grid_h=16)


def main():
    lines = []
    res = {}
    for dist in ("noniid", "iid"):
        for tau in (1, 10):
            dfl = dataclasses.replace(BASE["dfl"], tau_max=tau,
                                      num_agents=12, epoch_seconds=30.0)
            hist = run(algorithm="cached", distribution=dist, seed=3,
                       dfl=dfl, mobility=SPARSE,
                       epochs=BASE["epochs"] + 6, max_partners=3)
            res[(dist, tau)] = hist
            us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
            mid = len(hist["acc"]) // 2
            lines.append(emit(
                f"fig4_{dist}_tau{tau}", us,
                f"best_acc={hist['best_acc']:.4f};"
                f"mid_acc={hist['acc'][mid]:.4f}"))
    mid = len(res[("noniid", 10)]["acc"]) // 2
    early_gain = (res[("noniid", 10)]["acc"][mid]
                  >= res[("noniid", 1)]["acc"][mid] - 0.03)
    lines.append(emit("fig4_claim_tau_helps_early_noniid", 0.0,
                      f"holds={early_gain}"))
    return lines


if __name__ == "__main__":
    main()
