"""Paper contribution #3: "design and compare different model caching
algorithms". Compares the paper's LRU against the FIFO (most recently
received) and Random retention baselines implemented in core/policies —
same fleet, same mobility, same data.

Expectation from the paper's design rationale: LRU (freshest-trained
models) ≥ FIFO ≥ Random under non-iid data, because staleness directly
enters the convergence bound (Theorem 4).
"""
import dataclasses

from benchmarks.common import BASE, emit, run
from repro.configs.base import MobilityConfig

SPARSE = MobilityConfig(grid_w=8, grid_h=16)


def main():
    lines = []
    accs = {}
    for policy in ("lru", "fifo", "random"):
        dfl = dataclasses.replace(BASE["dfl"], policy=policy,
                                  num_agents=12, epoch_seconds=30.0,
                                  tau_max=20)
        hist = run(algorithm="cached", distribution="noniid", seed=8,
                   dfl=dfl, mobility=SPARSE, epochs=BASE["epochs"] + 8,
                   max_partners=3)
        accs[policy] = hist["best_acc"]
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"policies_{policy}", us,
                          f"best_acc={hist['best_acc']:.4f}"))
    lines.append(emit(
        "policies_summary", 0.0,
        f"lru={accs['lru']:.3f} fifo={accs['fifo']:.3f} "
        f"random={accs['random']:.3f};lru_ge_random="
        f"{accs['lru'] >= accs['random'] - 0.03}"))
    return lines


if __name__ == "__main__":
    main()
