"""Paper contribution #3: "design and compare different model caching
algorithms" — generalized into a full policy study on the sweep API.

One ``api.sweep`` grid covers EVERY registered cache policy
(``repro.policies.registry``) × mobility models — same fleet, same data —
and ``SweepResult.write_bench`` emits ``BENCH_policies.json`` (shared
schema: config hash, per-cell metrics, engine/retrace accounting) with
per-combination best accuracy, cache occupancy/staleness and epoch
wall-time.

Expectation from the paper's design rationale: LRU (freshest-trained
models) ≥ FIFO ≥ Random under non-iid data, because staleness directly
enters the convergence bound (Theorem 4). The beyond-paper policies
(mobility_aware / staleness_weighted / priority) probe the
distribution-aware caching direction of arXiv:2505.18866.

Run:  PYTHONPATH=src python -m benchmarks.bench_cache_policies
"""
from repro import api
from repro.configs.base import MobilityConfig
from repro.policies import registry as policy_registry

from benchmarks.common import FAST, base_scenario, bench_out, emit

MOBILITIES = {
    "manhattan": MobilityConfig(grid_w=8, grid_h=16),
    "random_waypoint": MobilityConfig(model="random_waypoint",
                                      area_w=1500.0, area_h=1500.0),
    "community": MobilityConfig(model="community",
                                area_w=1500.0, area_h=1500.0,
                                community_radius=200.0),
}
OUT = bench_out("BENCH_policies.json")


def adjust(overrides):
    """Group-slot policies need the grouped distribution (per-cell)."""
    pol = policy_registry.get_policy(overrides["dfl.policy"])
    return {"distribution": "grouped"} if pol.needs_group_slots else {}


def main():
    lines = []
    base = base_scenario(seed=8, max_partners=3).with_overrides({
        "dfl.num_agents": 12, "dfl.cache_size": 6,
        "dfl.epoch_seconds": 30.0, "dfl.tau_max": 20})
    mobilities = ({"manhattan": MOBILITIES["manhattan"]} if FAST
                  else MOBILITIES)
    sw = api.sweep(base, {"dfl.policy": policy_registry.available(),
                          "mobility": list(mobilities.values())},
                   adjust=adjust)
    by_pol = {}
    for cell in sw.cells:
        policy = cell.overrides["dfl.policy"]
        mob = cell.result.scenario.experiment.mobility.model
        us = (cell.result.wall_s / max(len(cell.result.epoch), 1)) * 1e6
        by_pol.setdefault(policy, []).append(cell.result.best_acc)
        lines.append(emit(f"policies_{policy}_{mob}", us,
                          f"best_acc={cell.result.best_acc:.4f}"))
    mean = {p: sum(a) / len(a) for p, a in by_pol.items()}
    summary = (";".join(f"{p}={mean[p]:.3f}" for p in sorted(mean))
               + f";lru_ge_random={mean['lru'] >= mean['random'] - 0.03}")
    sw.write_bench(OUT, name="cache_policies", fast=FAST,
                   extra={"mean_best_acc_by_policy": mean,
                          "lru_ge_random":
                          bool(mean["lru"] >= mean["random"] - 0.03),
                          "papers": {p: policy_registry.get_policy(p).paper
                                     for p in policy_registry.available()}})
    lines.append(emit("policies_summary", 0.0, summary))
    lines.append(emit("policies_retraces", 0.0,
                      f"engines={sw.num_engines};retraces={sw.retraces}"))
    return lines


if __name__ == "__main__":
    main()
