"""Paper contribution #3: "design and compare different model caching
algorithms" — generalized into a full policy study.

Sweeps EVERY registered cache policy (``repro.policies.registry``) across
mobility models — same fleet, same data — and emits ``BENCH_policies.json``
with per-combination best accuracy, cache occupancy/staleness, and
epoch wall-time.

Expectation from the paper's design rationale: LRU (freshest-trained
models) ≥ FIFO ≥ Random under non-iid data, because staleness directly
enters the convergence bound (Theorem 4). The beyond-paper policies
(mobility_aware / staleness_weighted / priority) probe the
distribution-aware caching direction of arXiv:2505.18866.
"""
import dataclasses
import json
import os

from benchmarks.common import BASE, FAST, emit, run
from repro.configs.base import MobilityConfig
from repro.policies import registry as policy_registry

MOBILITIES = {
    "manhattan": MobilityConfig(grid_w=8, grid_h=16),
    "random_waypoint": MobilityConfig(model="random_waypoint",
                                      area_w=1500.0, area_h=1500.0),
    "community": MobilityConfig(model="community",
                                area_w=1500.0, area_h=1500.0,
                                community_radius=200.0),
}
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_policies.json")


def main():
    lines = []
    results = {}
    mobilities = (("manhattan",) if FAST else tuple(MOBILITIES))
    for policy_name in policy_registry.available():
        pol = policy_registry.get_policy(policy_name)
        for mob_name in mobilities:
            dfl = dataclasses.replace(
                BASE["dfl"], policy=policy_name, num_agents=12,
                cache_size=6, epoch_seconds=30.0, tau_max=20)
            dist = "grouped" if pol.needs_group_slots else "noniid"
            hist = run(algorithm="cached", distribution=dist, seed=8,
                       dfl=dfl, mobility=MOBILITIES[mob_name],
                       epochs=BASE["epochs"], max_partners=3)
            us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
            results[f"{policy_name}/{mob_name}"] = {
                "policy": policy_name,
                "mobility": mob_name,
                "paper": pol.paper,
                "distribution": dist,
                "best_acc": hist["best_acc"],
                "final_acc": hist["final_acc"],
                "cache_num": (hist["cache_num"][-1]
                              if hist["cache_num"] else None),
                "cache_age": (hist["cache_age"][-1]
                              if hist["cache_age"] else None),
                "epoch_us": us,
                "traces": hist["epoch_traces"],
            }
            lines.append(emit(f"policies_{policy_name}_{mob_name}", us,
                              f"best_acc={hist['best_acc']:.4f}"))
    with open(OUT, "w") as f:
        json.dump({"fast": FAST, "results": results}, f, indent=1,
                  sort_keys=True)
    by_pol = {}
    for r in results.values():
        by_pol.setdefault(r["policy"], []).append(r["best_acc"])
    mean = {p: sum(a) / len(a) for p, a in by_pol.items()}
    lines.append(emit(
        "policies_summary", 0.0,
        ";".join(f"{p}={mean[p]:.3f}" for p in sorted(mean))
        + f";lru_ge_random={mean['lru'] >= mean['random'] - 0.03}"))
    return lines


if __name__ == "__main__":
    main()
