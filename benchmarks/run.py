"""Benchmark harness — one module per paper table/figure plus the kernel
micro-benches and the roofline report. Prints ``name,us_per_call,derived``
CSV. Set REPRO_BENCH_FAST=1 for a quicker pass.
"""
import sys
import time
import traceback

BENCHES = [
    ("bench_caching", "paper Fig. 2 (caching vs no caching vs CFL)"),
    ("bench_cache_size", "paper Fig. 3 (cache size sweep)"),
    ("bench_staleness_stats", "paper Table 2 (τ_max vs #cached/age)"),
    ("bench_tau_max", "paper Fig. 4 (τ_max vs convergence)"),
    ("bench_mobility", "paper Fig. 5 (mobility speed)"),
    ("bench_mobility_models", "beyond-paper: convergence across mobility "
                              "models + encounter stats"),
    ("bench_group_cache", "paper Fig. 6 (group-based caching)"),
    ("bench_staleness_decay", "beyond-paper: staleness-decayed aggregation"),
    ("bench_cache_policies", "paper contribution 3: all registered cache "
                             "policies × mobility models "
                             "-> BENCH_policies.json"),
    ("bench_transfer_budget", "beyond-paper: contact-duration-limited "
                              "transfers, accuracy-vs-budget frontier "
                              "-> BENCH_budget.json"),
    ("bench_fleet_scale", "§Perf: fused fleet engine vs legacy loop, "
                          "N × cache_size sweep -> BENCH_fleet.json"),
    ("bench_kernels", "Pallas kernel micro-benches"),
    ("bench_roofline", "roofline terms from the dry-run artifacts"),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    t0 = time.time()
    for mod_name, desc in BENCHES:
        print(f"# {mod_name}: {desc}", file=sys.stderr)
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod_name}_FAILED,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    print(f"# total wall: {time.time() - t0:.1f}s, failures: {failures}",
          file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
