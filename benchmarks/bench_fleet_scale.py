"""Fleet-scale benchmark on the Scenario API: grid sweep + sharded scaling.

Two parts, one ``BENCH_fleet.json`` artifact (schema ``sweep-v1`` via
``SweepResult.write_bench``):

  grid    — ``repro.api.sweep`` over N × cache_size with telemetry
            enabled, so every cell carries the standard telemetry columns
            (staleness, reach, admitted/epoch) next to accuracy and the
            sweep-level engine/retrace accounting;
  scaling — the sharded fleet engine (``shard_map`` over the ``agents``
            axis, block-sparse halo gossip) at a fixed fleet, swept over
            forced-host-device mesh sizes 1/2/4, timing compile-free
            dispatch throughput. Because halo mode computes each shard's
            contact/duration blocks against its (N/devices + 2·halo)-wide
            index window instead of all N columns, total contact work
            shrinks with the device count — the speedup is algorithmic,
            so it shows up even when forced host devices share one core.
            The fleet is deliberately contact-dominated (many mobility
            steps per epoch, one SGD step on a tiny model): the halo
            window shrinks contact work only, so the regime where
            sharding pays is the regime where contacts are the bill.
            A 10k-agent city-scale row runs on the 4-device mesh.

The artifact's ``extra.scaling`` rows feed ``tools/report.py``'s
epochs/s-vs-devices section.

Run:  PYTHONPATH=src python -m benchmarks.bench_fleet_scale
Env:  REPRO_BENCH_FAST=1 trims the sweep for smoke runs.
"""
from __future__ import annotations

import os

# the device-count sweep needs forced host devices before jax initializes
_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8").strip()

import time  # noqa: E402

import jax  # noqa: E402

from repro import api  # noqa: E402
from repro.configs.base import DFLConfig, MobilityConfig  # noqa: E402
from repro.fl.experiment import (ExperimentConfig, build_fleet,  # noqa: E402
                                 make_sharded_engine)
from repro.launch.mesh import make_fleet_mesh  # noqa: E402
from repro.models import cnn as cnn_lib  # noqa: E402

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

GRID_AGENTS = [16, 32] if FAST else [16, 32, 64]
GRID_CACHE = [5] if FAST else [5, 10]
TIMED_EPOCHS = 2 if FAST else 3

SCALING_N = 256 if FAST else 1024
SCALING_HALO = 16 if FAST else 32
# Steps per epoch for the device sweep. The per-epoch cost splits into
# window-independent work (local SGD, gossip/aggregation, collectives —
# ~1.6-1.8 s at N=1024 on this container) plus ~3.2 ms per step of
# contact work at the full 1024-column window; the halo window only
# shrinks the latter, so the sweep needs enough steps for contact work
# to dominate. 3600 steps ≈ 13 s/epoch at 1 device, ~85% contact work.
SCALING_SECONDS = 60.0 if FAST else 3600.0
SCALING_DEVICES = (1, 2, 4)

CITY_N = 0 if FAST else 10_000       # skipped in fast mode
CITY_HALO = 64
CITY_DEVICES = 4


def grid_base() -> api.Scenario:
    """Cache-traffic-dominated regime: 1 local step, small batch, so the
    per-epoch cost is the DTN exchange + aggregation, as at paper scale
    where K ≪ C·|model|."""
    exp = ExperimentConfig(
        algorithm="cached", distribution="noniid",
        dfl=DFLConfig(num_agents=16, cache_size=5, tau_max=10,
                      local_steps=1, batch_size=16, lr=0.1,
                      epoch_seconds=60.0),
        mobility=MobilityConfig(grid_w=4, grid_h=6),
        epochs=TIMED_EPOCHS, eval_every=TIMED_EPOCHS, seed=0,
        n_train=2000, n_test=200, image_hw=16, lr_plateau=False)
    return api.Scenario(experiment=exp, name="fleet_scale",
                        telemetry=True)


def scaling_cfg(N: int, halo: int, seconds: float) -> ExperimentConfig:
    """Contact-dominated fleet for the device sweep: long epochs (many
    mobility steps), one local SGD step on a tiny model, iid data (keeps
    the partitioner happy at a few samples per agent).

    Mobility is random waypoint, not the paper's Manhattan grid: the
    mobility advance is replicated per shard (every device repeats it so
    contact blocks see all N positions), so on serialized host devices
    its cost multiplies by the device count. Waypoint's leg sampling is
    ~0.07 ms/step at N=1024 vs ~0.5 ms for Manhattan's per-intersection
    turn draws — cheap enough that the sweep stays contact-dominated."""
    return ExperimentConfig(
        algorithm="cached", distribution="iid",
        dfl=DFLConfig(num_agents=N, cache_size=2, tau_max=10,
                      local_steps=1, batch_size=4, lr=0.1,
                      epoch_seconds=seconds, shard_halo=halo),
        mobility=MobilityConfig(model="random_waypoint",
                                area_w=4000.0, area_h=4000.0),
        epochs=TIMED_EPOCHS, eval_every=TIMED_EPOCHS, seed=0,
        n_train=2 * N, n_test=100, image_hw=8, lr_plateau=False)


def bench_sharded(cfg: ExperimentConfig, ndev: int) -> dict:
    """Compile-free epochs/sec of the sharded engine on an ndev mesh."""
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_sharded_engine(cfg, mesh=make_fleet_mesh(ndev),
                              loss_fn=loss_fn, mob_model=mob_model,
                              mob_cfg=mob_cfg, group_slots=group_slots,
                              chunk=cfg.epochs)
    key = jax.random.PRNGKey(cfg.seed + 2)
    lr = cfg.dfl.lr

    t0 = time.perf_counter()
    out = eng.run(state, mstate, key, lr, data, counts, cfg.epochs)
    state, mstate, key, _ = jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = eng.run(state, mstate, key, lr, data, counts, cfg.epochs)
    state, mstate, key, _ = jax.block_until_ready(out)
    dispatch_s = time.perf_counter() - t0

    N, halo = cfg.dfl.num_agents, cfg.dfl.shard_halo
    n_local = N // ndev
    window = N if (halo == 0 or n_local + 2 * halo >= N) \
        else n_local + 2 * halo
    return {
        "num_agents": N,
        "devices": ndev,
        "halo": halo,
        "window": window,
        "timed_epochs": cfg.epochs,
        "epochs_per_s": round(cfg.epochs / dispatch_s, 4),
        "dispatch_s": round(dispatch_s, 3),
        "compile_s": round(compile_s, 3),
        "traces": eng.traces,
        "retraces": eng.traces - 1,
    }


def main():
    # -- grid: N × cache_size through the sweep runner -----------------
    base = grid_base()
    axes = {"dfl.num_agents": GRID_AGENTS, "dfl.cache_size": GRID_CACHE}
    result = api.sweep(base, axes, verbose=True)

    # -- scaling: fixed fleet over mesh sizes --------------------------
    scaling = []
    cfg = scaling_cfg(SCALING_N, SCALING_HALO, SCALING_SECONDS)
    for ndev in SCALING_DEVICES:
        row = bench_sharded(cfg, ndev)
        if scaling:
            row["speedup_vs_1dev"] = round(
                row["epochs_per_s"] / scaling[0]["epochs_per_s"], 2)
        else:
            row["speedup_vs_1dev"] = 1.0
        scaling.append(row)
        print(f"scaling N={row['num_agents']} devices={ndev} "
              f"window={row['window']} {row['epochs_per_s']:.3f} ep/s "
              f"({row['speedup_vs_1dev']}x vs 1 dev, "
              f"retraces={row['retraces']})")

    if CITY_N:
        city = bench_sharded(
            scaling_cfg(CITY_N, CITY_HALO, 60.0), CITY_DEVICES)
        city["speedup_vs_1dev"] = None     # no 1-device baseline at 10k
        scaling.append(city)
        print(f"city    N={city['num_agents']} devices={city['devices']} "
              f"window={city['window']} {city['epochs_per_s']:.3f} ep/s "
              f"(retraces={city['retraces']})")

    doc = result.write_bench(
        "BENCH_fleet.json", name="fleet_scale", fast=FAST,
        extra={
            "backend": jax.default_backend(),
            "forced_host_devices": jax.device_count(),
            "scaling": scaling,
            "scaling_speedup_1_to_4": next(
                (r["speedup_vs_1dev"] for r in scaling
                 if r["devices"] == 4 and r["num_agents"] == SCALING_N),
                None),
        })
    print("wrote BENCH_fleet.json "
          f"({len(doc['cells'])} grid cells, {len(scaling)} scaling rows, "
          f"{doc['retraces']} grid retraces)")
    return doc


if __name__ == "__main__":
    main()
