"""Fleet-scale throughput sweep: fused scan engine vs legacy per-epoch loop.

Measures pure epoch throughput (no evals) for N ∈ {10, 25, 50, 100}
vehicles × cache sizes, in three driver modes:

  legacy      — the full pre-PR epoch path: 3+ jitted dispatches per epoch
                with host round-trips, gossip phase 2 materializing the
                [N, C+1, ...] concatenated stack, reference model impl
                (grouped-conv / select-and-scatter pool);
  host_select — the same host loop with this PR's epoch internals
                (allocation-light gossip gather, fast model impl) —
                isolates the scan driver's contribution vs `fused`;
  fused       — the scanned multi-epoch engine (one dispatch per chunk,
                lr/num_epochs traced, donated buffers off-CPU).

Also asserts the engine's compile discipline: exactly one trace per
(algorithm, shape), zero recompiles on LR or epoch-count changes.

Emits ``BENCH_fleet.json`` (epochs/sec per mode, speedups, compile counts,
peak-memory estimates) in the working directory.

Run:  PYTHONPATH=src python -m benchmarks.bench_fleet_scale
Env:  REPRO_BENCH_FAST=1 trims the sweep for smoke runs.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import resource
import time

import jax
import jax.numpy as jnp

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import (ExperimentConfig, build_fleet,
                                 make_engine, make_epoch_fn)
from repro.mobility.base import partners_from_contacts
from repro.models import cnn as cnn_lib
from repro.utils.tree import tree_bytes

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

SWEEP = [(10, 5), (25, 10), (50, 10), (100, 10)]
if FAST:
    SWEEP = [(10, 5), (50, 10)]
TIMED_EPOCHS = 3 if FAST else 6


def make_cfg(N: int, cache_size: int) -> ExperimentConfig:
    """Cache-traffic-dominated regime: 1 local step, small batch, so the
    per-epoch cost is the DTN exchange + aggregation, as at paper scale
    where K ≪ C·|model|."""
    return ExperimentConfig(
        algorithm="cached", distribution="noniid",
        dfl=DFLConfig(num_agents=N, cache_size=cache_size, tau_max=10,
                      local_steps=1, batch_size=16, lr=0.1,
                      epoch_seconds=60.0),
        mobility=MobilityConfig(grid_w=4, grid_h=6),
        epochs=TIMED_EPOCHS, eval_every=TIMED_EPOCHS, seed=0,
        n_train=2000, n_test=200, image_hw=16, lr_plateau=False)


def _loss_fn(model_cfg, impl: str = "fast"):
    return lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                        b["labels"], impl=impl)


def bench_legacy(cfg: ExperimentConfig, gather_mode: str,
                 impl: str = "fast"):
    """Epochs/sec of the historical host loop (one eval-free epoch at a
    time: sim dispatch → eager partner selection → epoch dispatch)."""
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    epoch_fn, counter = make_epoch_fn(cfg, loss_fn=_loss_fn(model_cfg, impl),
                                      group_slots=group_slots,
                                      gather_mode=gather_mode)
    sim = jax.jit(functools.partial(mob_model.simulate_epoch, cfg=mob_cfg,
                                    seconds=cfg.dfl.epoch_seconds))
    key = jax.random.PRNGKey(cfg.seed + 2)
    lr = cfg.dfl.lr

    def one_epoch(state, mstate, key):
        key, k1, k2 = jax.random.split(key, 3)
        mstate, met, dur = sim(mstate, k1)
        partners = partners_from_contacts(met, cfg.max_partners)
        state, _ = epoch_fn(state, partners, dur, data, counts, k2, lr)
        return state, mstate, key

    state, mstate, key = one_epoch(state, mstate, key)      # compile
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(cfg.epochs):
        state, mstate, key = one_epoch(state, mstate, key)
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    return cfg.epochs / dt, counter["traces"], state


def bench_fused(cfg: ExperimentConfig):
    """Epochs/sec of the scanned engine + compile-discipline checks."""
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    eng = make_engine(cfg, loss_fn=_loss_fn(model_cfg), mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots,
                      chunk=cfg.epochs)
    key = jax.random.PRNGKey(cfg.seed + 2)
    lr = cfg.dfl.lr

    out = eng.run(state, mstate, key, lr, data, counts, cfg.epochs)  # compile
    state, mstate, key, _ = jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = eng.run(state, mstate, key, lr, data, counts, cfg.epochs)
    state, mstate, key, _ = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    eps = cfg.epochs / dt

    # LR and epoch-count changes must not retrace the engine
    traces_before = eng.traces
    out = eng.run(state, mstate, key, lr * 0.5, data, counts,
                  max(cfg.epochs - 1, 1))
    state, mstate, key, _ = jax.block_until_ready(out)
    recompiles = eng.traces - traces_before
    return eps, eng.traces, recompiles, state


def main():
    rows = []
    for N, C in SWEEP:
        cfg = make_cfg(N, C)
        legacy_eps, legacy_traces, state = bench_legacy(
            cfg, "concat", impl="reference")          # full pre-PR path
        host_eps, _, _ = bench_legacy(cfg, "select", impl="fast")
        fused_eps, fused_traces, recompiles, _ = bench_fused(cfg)

        params_mb = tree_bytes(state.params) / 2**20
        cache_mb = tree_bytes(state.cache.models) / 2**20
        D = tree_bytes(state.params) // (4 * N)
        concat_temp_mb = N * (C + 1) * D * 4 / 2**20
        row = {
            "num_agents": N,
            "cache_size": C,
            "param_dim": int(D),
            "timed_epochs": cfg.epochs,
            "legacy_eps": round(legacy_eps, 3),
            "host_select_eps": round(host_eps, 3),
            "fused_eps": round(fused_eps, 3),
            "speedup_fused_vs_legacy": round(fused_eps / legacy_eps, 2),
            "speedup_scan_driver_only": round(fused_eps / host_eps, 2),
            "legacy_traces": legacy_traces,
            "fused_traces": fused_traces,
            "recompiles_on_lr_and_epoch_change": recompiles,
            "params_mb": round(params_mb, 2),
            "cache_mb": round(cache_mb, 2),
            "concat_temp_saved_mb": round(concat_temp_mb, 2),
            "ru_maxrss_mb": round(
                resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1),
        }
        rows.append(row)
        print(f"N={N:4d} C={C:3d}  legacy {legacy_eps:6.2f} ep/s  "
              f"host_select {host_eps:6.2f}  fused {fused_eps:6.2f}  "
              f"({row['speedup_fused_vs_legacy']}x total, "
              f"{row['speedup_scan_driver_only']}x driver)  "
              f"recompiles={recompiles}")

    report = {
        "bench": "fleet_scale",
        "backend": jax.default_backend(),
        "fast": FAST,
        "rows": rows,
    }
    with open("BENCH_fleet.json", "w") as f:
        json.dump(report, f, indent=2)
    print("wrote BENCH_fleet.json")
    return report


if __name__ == "__main__":
    main()
