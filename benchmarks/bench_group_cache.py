"""Paper Fig. 6: Group-Based cache update vs vanilla LRU vs DFL under
grouped mobility + grouped (non-overlapping) label distributions.

Claim: GB caching beats LRU (which over-samples same-area models) and DFL.
"""
import dataclasses

from benchmarks.common import BASE, emit, run


def main():
    lines = []
    accs = {}
    base_dfl = dataclasses.replace(BASE["dfl"], num_agents=12, cache_size=6)
    for name, alg, policy in (("gb", "cached", "group"),
                              ("lru", "cached", "lru"),
                              ("dfl", "dfl", "lru")):
        dfl = dataclasses.replace(base_dfl, policy=policy)
        hist = run(algorithm=alg, distribution="grouped", seed=5, dfl=dfl,
                   overlap=0, epochs=BASE["epochs"] + 4)
        accs[name] = hist["best_acc"]
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"fig6_nonoverlap_{name}", us,
                          f"best_acc={hist['best_acc']:.4f}"))
    lines.append(emit("fig6_claim_gb_ge_lru", 0.0,
                      f"holds={accs['gb'] >= accs['lru'] - 0.03} "
                      f"(gb={accs['gb']:.3f} lru={accs['lru']:.3f} "
                      f"dfl={accs['dfl']:.3f})"))
    return lines


if __name__ == "__main__":
    main()
