"""Paper Fig. 5: convergence vs mobility speed (same wall clock: v×s with
K/s local steps).

Claim: faster movement spreads models quicker -> faster convergence, even
with fewer local steps.
"""
import dataclasses

from benchmarks.common import BASE, emit, run
from repro.configs.base import MobilityConfig


def main():
    lines = []
    accs = {}
    # sparse grid: model spreading is the bottleneck, so speed matters
    for mult, k in ((1, 15), (3, 5)):
        dfl = dataclasses.replace(BASE["dfl"], local_steps=k,
                                  num_agents=12, epoch_seconds=30.0)
        mobility = MobilityConfig(grid_w=8, grid_h=16,
                                  speed=13.89 * mult)
        hist = run(algorithm="cached", distribution="noniid", seed=4,
                   dfl=dfl, mobility=mobility, epochs=BASE["epochs"] + 6,
                   max_partners=3)
        accs[mult] = hist
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"fig5_speed_x{mult}_K{k}", us,
                          f"best_acc={hist['best_acc']:.4f}"))
    holds = accs[3]["best_acc"] >= accs[1]["best_acc"] - 0.05
    lines.append(emit("fig5_claim_speed_helps", 0.0, f"holds={holds}"))
    return lines


if __name__ == "__main__":
    main()
