"""Paper Table 2: average number and age of cached models vs τ_max and
epoch time, with unlimited cache — a pure mobility/protocol statistic
(no training), measured exactly as the paper does.

Claims: #cached and age grow ~linearly with τ_max; shorter epochs fetch
fewer models per epoch.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import MobilityConfig
from repro.core import gossip, rounds as rounds_lib
from repro.mobility import manhattan as mob


def cache_stats(tau_max: int, epoch_seconds: float, epochs: int = 15,
                N: int = 20):
    """Run mobility + exchange only (1-scalar models), collect stats."""
    mcfg = MobilityConfig(grid_w=6, grid_h=9)
    params = {"w": jnp.arange(N, dtype=jnp.float32)[:, None]}
    state = rounds_lib.init_fleet(params_template := {"w": jnp.zeros((1,))},
                                  N, cache_size=N, samples=np.ones(N))
    cache = state.cache
    fleet_params = {"w": jnp.arange(N, dtype=jnp.float32)[:, None]}
    mstate = mob.init_mobility(jax.random.PRNGKey(0), N, mcfg)
    key = jax.random.PRNGKey(1)
    nums, ages = [], []
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    sim = jax.jit(lambda s, k: mob.simulate_epoch(s, k, mcfg, epoch_seconds))
    for t in range(epochs):
        key, k = jax.random.split(key)
        mstate, met, _dur = sim(mstate, k)
        partners = mob.partners_from_contacts(met, 8)
        cache = gossip.exchange(fleet_params, cache, partners, t, samples,
                                group, tau_max=tau_max, policy="lru")
        valid = np.asarray(cache.valid)
        age = np.asarray(t - cache.ts)
        nums.append(valid.sum(1).mean())
        if valid.sum():
            ages.append((age * valid).sum() / valid.sum())
    return float(np.mean(nums[5:])), float(np.mean(ages[5:]))


def main():
    lines = []
    t0 = time.time()
    results = {}
    for epoch_s in (30.0, 120.0):
        for tau in (1, 2, 5, 10):
            num, age = cache_stats(tau, epoch_s)
            results[(epoch_s, tau)] = (num, age)
            lines.append(emit(f"table2_ep{int(epoch_s)}s_tau{tau}",
                              (time.time() - t0) * 1e6,
                              f"avg_num={num:.2f};avg_age={age:.2f}"))
    # claims: num grows with tau; longer epoch time fetches more models
    grow = results[(30.0, 10)][0] > results[(30.0, 1)][0]
    age_grow = results[(30.0, 10)][1] > results[(30.0, 2)][1]
    more_contact = results[(120.0, 5)][0] > results[(30.0, 5)][0]
    lines.append(emit("table2_claims", 0.0,
                      f"num_grows_with_tau={grow};age_grows={age_grow};"
                      f"longer_epoch_more_models={more_contact}"))
    return lines


if __name__ == "__main__":
    main()
