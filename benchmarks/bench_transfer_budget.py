"""Accuracy-vs-bandwidth frontier for contact-duration-limited transfers.

The unbudgeted exchange moves an unbounded candidate set per contact —
physically impossible on a real vehicular link. This study sweeps the
per-link transfer budget (entries one contact may move) across mobility
models × cache policies and emits ``BENCH_budget.json``:

  * best/final accuracy per (budget, mobility, policy) — the
    accuracy-vs-budget frontier, expected monotone non-decreasing in the
    budget (communication-constrained DFL, arXiv:2107.12048 regime);
  * a duration-derived point (``link_entries_per_step``) where the cap
    comes from the measured per-pair contact durations instead of a flat
    knob;
  * the fused engine's compile discipline: the budget is a *traced*
    scalar, so sweeping it through one engine must report 0 retraces.

Run:  PYTHONPATH=src python -m benchmarks.bench_transfer_budget
Env:  REPRO_BENCH_FAST=1 trims mobilities and budgets.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BASE, FAST, emit, run
from repro.configs.base import MobilityConfig
from repro.fl.experiment import ExperimentConfig, build_fleet, make_engine
from repro.mobility import trace as trace_lib
from repro.models import cnn as cnn_lib

N_AGENTS = 12
BUDGETS = (0.0, 1.0, 2.0, 4.0, float("inf"))


def jsonable(budget: float):
    """inf -> "inf" so the artifact stays strict RFC-8259 JSON."""
    return "inf" if budget == float("inf") else budget
POLICIES = ("lru", "mobility_aware")
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_budget.json")


def make_trace_file() -> str:
    rng = np.random.default_rng(0)
    seq = rng.random((600, N_AGENTS, N_AGENTS)) < 0.08
    path = os.path.join(tempfile.mkdtemp(prefix="bench_budget_"),
                        "trace.npz")
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))
    return path


def mobilities(trace_path: str):
    mobs = {
        "manhattan": MobilityConfig(grid_w=8, grid_h=16),
        "random_waypoint": MobilityConfig(model="random_waypoint",
                                          area_w=1500.0, area_h=1500.0),
        "trace": MobilityConfig(model="trace", trace_path=trace_path,
                                trace_frames_per_epoch=30),
    }
    return {"manhattan": mobs["manhattan"]} if FAST else mobs


def budget_dfl(policy: str, budget: float, leps: float = 0.0):
    return dataclasses.replace(
        BASE["dfl"], policy=policy, num_agents=N_AGENTS, cache_size=6,
        epoch_seconds=30.0, tau_max=20, transfer_budget=budget,
        link_entries_per_step=leps)


def check_no_retrace_across_budgets() -> int:
    """One fused engine, many budgets: the cap is traced, 0 retraces."""
    cfg = ExperimentConfig(
        algorithm="cached", distribution="noniid", seed=8,
        dfl=budget_dfl("lru", 2.0),
        mobility=MobilityConfig(grid_w=6, grid_h=8),
        epochs=4, eval_every=2, n_train=600, n_test=100, image_hw=12,
        lr_plateau=False)
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots, chunk=2)
    key = jax.random.PRNGKey(0)
    for b in (0.0, 1.0, 3.0, 1e9):
        state, mstate, key, _ = eng.run(state, mstate, key, 0.1, data,
                                        counts, 2, jnp.float32(b))
    return eng.traces - 1


def main():
    lines = []
    results = {}
    trace_path = make_trace_file()
    budgets = BUDGETS[:3] + (float("inf"),) if FAST else BUDGETS
    for policy in POLICIES:
        for mob_name, mob in mobilities(trace_path).items():
            frontier = []
            for budget in budgets:
                hist = run(algorithm="cached", distribution="noniid",
                           seed=8, dfl=budget_dfl(policy, budget),
                           mobility=mob, epochs=BASE["epochs"],
                           max_partners=3)
                key_name = f"{policy}/{mob_name}/{jsonable(budget)}"
                results[key_name] = {
                    "policy": policy, "mobility": mob_name,
                    "transfer_budget": jsonable(budget),
                    "best_acc": hist["best_acc"],
                    "final_acc": hist["final_acc"],
                    "cache_num": (hist["cache_num"][-1]
                                  if hist["cache_num"] else None),
                    "traces": hist["epoch_traces"],
                }
                frontier.append(hist["best_acc"])
                lines.append(emit(
                    f"budget_{policy}_{mob_name}_{budget}", 0.0,
                    f"best_acc={hist['best_acc']:.4f}"))
            # monotone (non-decreasing within noise) frontier per series
            mono = all(b >= a - 0.03 for a, b in zip(frontier, frontier[1:]))
            results[f"{policy}/{mob_name}/monotone"] = {
                "frontier": frontier, "monotone": bool(mono)}
    # aggregate frontier: mean best accuracy per budget across every
    # (policy, mobility) series — the headline accuracy-vs-budget curve
    # (individual series carry per-point noise at this scale)
    agg = []
    for budget in budgets:
        pts = [r["best_acc"] for r in results.values()
               if isinstance(r, dict)
               and r.get("transfer_budget") == jsonable(budget)]
        agg.append(sum(pts) / max(len(pts), 1))
    results["frontier/mean_best_acc"] = {
        "budgets": [str(b) for b in budgets], "mean_best_acc": agg,
        "monotone": bool(all(b >= a - 0.005       # seed-level noise floor
                             for a, b in zip(agg, agg[1:])))}
    lines.append(emit("budget_frontier", 0.0,
                      ";".join(f"{b}={a:.4f}"
                               for b, a in zip(budgets, agg))))
    # duration-derived budget point: cap = measured steps x entries/step
    hist = run(algorithm="cached", distribution="noniid", seed=8,
               dfl=budget_dfl("lru", float("inf"), leps=0.1),
               mobility=MobilityConfig(grid_w=8, grid_h=16),
               epochs=BASE["epochs"], max_partners=3)
    results["lru/manhattan/duration_derived"] = {
        "link_entries_per_step": 0.1, "best_acc": hist["best_acc"],
        "traces": hist["epoch_traces"]}
    retraces = check_no_retrace_across_budgets()
    results["engine/retraces_across_budgets"] = retraces
    with open(OUT, "w") as f:
        json.dump({"fast": FAST, "results": results}, f, indent=1,
                  sort_keys=True)
    lines.append(emit("budget_retraces", 0.0,
                      f"retraces_across_budgets={retraces}"))
    return lines


if __name__ == "__main__":
    main()
