"""Accuracy-vs-bandwidth frontier for contact-duration-limited transfers.

The unbudgeted exchange moves an unbounded candidate set per contact —
physically impossible on a real vehicular link. This study is one
``api.sweep`` over the per-link transfer budget (entries one contact may
move) × mobility models × cache policies, emitting ``BENCH_budget.json``
through the shared ``write_bench`` schema:

  * best/final accuracy per (budget, mobility, policy) — the
    accuracy-vs-budget frontier, expected monotone non-decreasing in the
    budget (communication-constrained DFL, arXiv:2107.12048 regime);
  * a duration-derived point (``link_entries_per_step``) where the cap
    comes from the measured per-pair contact durations instead of a flat
    knob;
  * the fused engine's compile discipline, now enforced *by the sweep
    runner itself*: ``dfl.transfer_budget`` is a traced axis, so the
    sweep shares one engine per (policy, mobility) and
    ``SweepResult.retraces`` must be 0.

Run:  PYTHONPATH=src python -m benchmarks.bench_transfer_budget
Env:  REPRO_BENCH_FAST=1 trims mobilities and budgets.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np

from repro import api
from repro.configs.base import MobilityConfig
from repro.mobility import trace as trace_lib

from benchmarks.common import FAST, base_scenario, bench_out, emit

N_AGENTS = 12
BUDGETS = (0.0, 1.0, 2.0, 4.0, float("inf"))
POLICIES = ("lru", "mobility_aware")
OUT = bench_out("BENCH_budget.json")


def jsonable(budget: float):
    """inf -> "inf" so the artifact stays strict RFC-8259 JSON."""
    return "inf" if budget == float("inf") else budget


def make_trace_file() -> str:
    rng = np.random.default_rng(0)
    seq = rng.random((600, N_AGENTS, N_AGENTS)) < 0.08
    path = os.path.join(tempfile.mkdtemp(prefix="bench_budget_"),
                        "trace.npz")
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))
    return path


def mobilities(trace_path: str):
    mobs = {
        "manhattan": MobilityConfig(grid_w=8, grid_h=16),
        "random_waypoint": MobilityConfig(model="random_waypoint",
                                          area_w=1500.0, area_h=1500.0),
        "trace": MobilityConfig(model="trace", trace_path=trace_path,
                                trace_frames_per_epoch=30),
    }
    return {"manhattan": mobs["manhattan"]} if FAST else mobs


def main():
    lines = []
    trace_path = make_trace_file()
    budgets = BUDGETS[:3] + (float("inf"),) if FAST else BUDGETS
    base = base_scenario(seed=8, max_partners=3).with_overrides({
        "dfl.num_agents": N_AGENTS, "dfl.cache_size": 6,
        "dfl.epoch_seconds": 30.0, "dfl.tau_max": 20})
    # telemetry-enabled cells carry staleness/spread/budget-utilization
    # summary columns into the artifact (tools/report.py renders the
    # utilization frontier from them); bit-exact with a telemetry-off run
    base = dataclasses.replace(base, telemetry=True)
    mobs = mobilities(trace_path)
    sw = api.sweep(base, {"dfl.policy": list(POLICIES),
                          "mobility": list(mobs.values()),
                          "dfl.transfer_budget": list(budgets)})

    # per-series frontier: monotone (non-decreasing within noise) in budget
    extra = {"frontiers": {}}
    for policy in POLICIES:
        for mob_name, mob in mobs.items():
            series = [c for c in sw.select(**{"dfl.policy": policy})
                      if c.overrides["mobility"] == mob]
            series.sort(key=lambda c: c.overrides["dfl.transfer_budget"])
            frontier = [c.result.best_acc for c in series]
            mono = all(b >= a - 0.03
                       for a, b in zip(frontier, frontier[1:]))
            extra["frontiers"][f"{policy}/{mob_name}"] = {
                "budgets": [jsonable(b) for b in budgets],
                "frontier": frontier, "monotone": bool(mono)}
            for c in series:
                b = c.overrides["dfl.transfer_budget"]
                lines.append(emit(
                    f"budget_{policy}_{mob_name}_{b}", 0.0,
                    f"best_acc={c.result.best_acc:.4f}"))

    # aggregate frontier: mean best accuracy per budget across every
    # (policy, mobility) series — the headline accuracy-vs-budget curve
    # (individual series carry per-point noise at this scale)
    agg = []
    for budget in budgets:
        pts = [c.result.best_acc for c in sw.cells
               if c.overrides["dfl.transfer_budget"] == budget]
        agg.append(sum(pts) / max(len(pts), 1))
    extra["frontier_mean_best_acc"] = {
        "budgets": [str(b) for b in budgets], "mean_best_acc": agg,
        "monotone": bool(all(b >= a - 0.005       # seed-level noise floor
                             for a, b in zip(agg, agg[1:])))}
    lines.append(emit("budget_frontier", 0.0,
                      ";".join(f"{b}={a:.4f}"
                               for b, a in zip(budgets, agg))))

    # duration-derived budget point: cap = measured steps x entries/step
    dur = api.run(base.with_overrides({
        "dfl.link_entries_per_step": 0.1,
        "mobility": MobilityConfig(grid_w=8, grid_h=16)}))
    extra["duration_derived"] = {
        "link_entries_per_step": 0.1, "best_acc": dur.best_acc,
        "traces": dur.traces}

    # compile discipline through the sweep API: the budget axis is traced,
    # so every engine compiled exactly once
    extra["retraces_across_budgets"] = sw.retraces
    sw.write_bench(OUT, name="transfer_budget", fast=FAST, extra=extra)
    lines.append(emit("budget_retraces", 0.0,
                      f"retraces_across_budgets={sw.retraces}"))
    return lines


if __name__ == "__main__":
    main()
