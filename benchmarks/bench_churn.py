"""Churn-robustness study: cached vs dfl accuracy under agent churn.

The paper's DTN argument is that cached models keep spreading after
their origin drops out of contact. This study makes that measurable:
one ``api.sweep`` over algorithm × churn fraction × cache size on the
shared scaled-down fleet, with a staggered round-robin join/leave
schedule (``dfl.churn_period`` epochs per cycle, each agent out of
coverage for a ``dfl.churn_fraction`` share of it). Dead agents freeze
and stop meeting; under ``cached`` their models still ride carriers'
caches, under plain ``dfl`` they simply vanish from the gossip — so the
cached-over-dfl accuracy gap should widen with the churn rate, and a
bigger cache should buy extra robustness (more carrier slots per agent).

Emits ``BENCH_churn.json`` (schema ``sweep-v1``); the per-churn-level
per-algorithm frontier rides ``extra.churn_frontier`` and
``tools/report.py`` renders the same frontier from the cells (the
``dfl.churn_fraction`` axis triggers its accuracy-vs-churn section).
Engine discipline: churn knobs are trace-static (they change the epoch
step function), so every (algorithm, churn, cache) cell compiles its
own engine but ``retraces`` must still be 0 — churn adds no retraces.

Run:  PYTHONPATH=src python -m benchmarks.bench_churn
Env:  REPRO_BENCH_FAST=1 trims churn levels and cache sizes.
"""
from __future__ import annotations

from repro import api

from benchmarks.common import FAST, base_scenario, bench_out

CHURN_PERIOD = 4
CHURN_FRACTIONS = [0.0, 0.5] if FAST else [0.0, 0.25, 0.5]
CACHE_SIZES = [5] if FAST else [3, 8]
OUT = bench_out("BENCH_churn.json")


def main():
    base = base_scenario(seed=3).with_overrides(
        {"dfl.churn_period": CHURN_PERIOD})
    sw = api.sweep(base, {
        "algorithm": ["cached", "dfl"],
        "dfl.churn_fraction": CHURN_FRACTIONS,
        "dfl.cache_size": CACHE_SIZES,
    }, verbose=True)
    assert sw.retraces == 0, \
        f"churn knobs must add no retraces, got {sw.retraces}"

    # per-churn-level frontier: each algorithm's best accuracy, plus the
    # cached-over-dfl robustness gap
    frontier = []
    for frac in CHURN_FRACTIONS:
        row = {"churn_fraction": frac}
        for algo in ("cached", "dfl"):
            cells = sw.select(algorithm=algo, dfl_churn_fraction=frac)
            row[algo] = max(c.result.best_acc for c in cells)
        row["gap"] = round(row["cached"] - row["dfl"], 4)
        frontier.append(row)
        print(f"churn={frac}: cached={row['cached']:.4f} "
              f"dfl={row['dfl']:.4f} gap={row['gap']:+.4f}")

    doc = sw.write_bench(OUT, name="churn", fast=FAST, extra={
        "churn_period": CHURN_PERIOD,
        "churn_frontier": frontier,
        "gap_at_max_churn": frontier[-1]["gap"],
    })
    print(f"wrote BENCH_churn.json ({len(doc['cells'])} cells, "
          f"{doc['num_engines']} engines, {doc['retraces']} retraces)")
    return doc


if __name__ == "__main__":
    main()
