"""Kernel micro-benchmarks: the Cached-DFL aggregation reduction and the
decode-attention hot spot. On this CPU container Pallas runs interpret=True
(Python-level, correctness only), so wall-times are measured on the jnp
reference path and the kernel path is verified for agreement; derived
reports the modelled TPU HBM-bound time for the same shapes.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW


def timeit(fn, *args, iters=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def main():
    lines = []
    # cache_aggregate: C models × D params (a 100M-param model slice)
    for C, D in ((3, 1 << 22), (10, 1 << 22)):
        key = jax.random.PRNGKey(0)
        cache = jax.random.normal(key, (C, D), jnp.float32)
        w = jnp.ones((C,)) / C
        valid = jnp.ones((C,))
        f_ref = jax.jit(ref.cache_aggregate_ref)
        us = timeit(f_ref, cache, w, valid)
        # verify kernel agreement on a slice (interpret mode is slow)
        out_k = ops.cache_aggregate(cache[:, : 1 << 16], w, valid)
        out_r = ref.cache_aggregate_ref(cache[:, : 1 << 16], w, valid)
        ok = bool(np.allclose(out_k, out_r, rtol=1e-5, atol=1e-5))
        tpu_us = (C + 1) * D * 4 / HBM_BW * 1e6
        lines.append(emit(
            f"kernel_cache_aggregate_C{C}_D{D}", us,
            f"kernel_matches_ref={ok};modelled_tpu_us={tpu_us:.0f}"))

    # decode attention: 32k cache, GQA 8kv × 6 groups
    B, S, KV, G, hd = 4, 32768, 8, 6, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)
    length = jnp.asarray(S, jnp.int32)
    f_ref = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, length))
    us = timeit(f_ref, q, k, v, iters=3)
    out_k = ops.decode_attention(q[:1, :, :, :], k[:1, :2048], v[:1, :2048],
                                 jnp.asarray(2048, jnp.int32))
    out_r = ref.decode_attention_ref(q[:1], k[:1, :2048], v[:1, :2048],
                                     jnp.asarray(2048, jnp.int32))
    ok = bool(np.allclose(out_k, out_r, rtol=3e-2, atol=3e-2))
    tpu_us = 2 * B * S * KV * hd * 2 / HBM_BW * 1e6
    lines.append(emit(
        f"kernel_decode_attn_B{B}_S{S}", us,
        f"kernel_matches_ref={ok};modelled_tpu_us={tpu_us:.0f}"))
    return lines


if __name__ == "__main__":
    main()
