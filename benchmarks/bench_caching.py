"""Paper Fig. 2: Cached-DFL vs DFL (DeFedAvg) vs Centralized FL, non-iid.

Claim: Cached-DFL converges faster than DFL and approaches CFL.
"""
from benchmarks.common import emit, run


def main():
    lines = []
    accs = {}
    for alg in ("cached", "dfl", "cfl"):
        hist = run(algorithm=alg, distribution="noniid", seed=1)
        accs[alg] = hist["best_acc"]
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"fig2_noniid_{alg}", us,
                          f"best_acc={hist['best_acc']:.4f}"))
    ordered = accs["cached"] >= accs["dfl"] - 0.02
    lines.append(emit("fig2_claim_cached_ge_dfl", 0.0,
                      f"holds={ordered} ({accs['cached']:.3f} vs "
                      f"{accs['dfl']:.3f}; cfl={accs['cfl']:.3f})"))
    return lines


if __name__ == "__main__":
    main()
