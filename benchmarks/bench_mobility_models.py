"""Beyond-paper: Cached-DFL convergence across mobility regimes.

The paper's convergence argument hinges on mobility statistics (meeting
rate, inter-contact time), not on the Manhattan map itself. This
benchmark runs the same Cached-DFL fleet under every registered mobility
model — grid, random waypoint, Lévy walk, community/RPGM, and a synthetic
contact-trace replay — and reports best accuracy next to the measured
encounter statistics, making the mobility→convergence coupling visible.
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np

from benchmarks.common import BASE, emit, run
from repro.configs.base import MobilityConfig
from repro.mobility import registry, stats
from repro.mobility import trace as trace_lib

N_AGENTS = 10
EPOCH_S = 30.0

MODEL_CFGS = {
    "manhattan": MobilityConfig(model="manhattan", grid_w=4, grid_h=6),
    "random_waypoint": MobilityConfig(model="random_waypoint",
                                      area_w=800.0, area_h=800.0),
    "levy_walk": MobilityConfig(model="levy_walk", area_w=800.0,
                                area_h=800.0, levy_max_flight=800.0),
    "community": MobilityConfig(model="community", area_w=1000.0,
                                area_h=1000.0, num_bands=3,
                                community_radius=120.0),
}


def synthetic_trace(path: str, n: int = N_AGENTS, T: int = 240,
                    seed: int = 0) -> None:
    """Bursty schedule: random pairs meet for a few consecutive frames."""
    rng = np.random.default_rng(seed)
    seq = np.zeros((T, n, n), bool)
    for _ in range(6 * n):
        i, j = rng.choice(n, size=2, replace=False)
        t0 = rng.integers(0, T - 5)
        seq[t0:t0 + rng.integers(2, 6), i, j] = True
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))


def encounter_line(name: str, mcfg: MobilityConfig) -> str:
    model = registry.get_model(name)
    state = model.init(jax.random.PRNGKey(7), N_AGENTS, mcfg)
    _, seq = stats.collect_contacts(model, state, jax.random.PRNGKey(8),
                                    mcfg, n_steps=240)
    return stats.summarize(stats.encounter_stats(seq, mcfg.step_seconds))


def main():
    lines = []
    dfl = dataclasses.replace(BASE["dfl"], num_agents=N_AGENTS,
                              epoch_seconds=EPOCH_S)
    cfgs = dict(MODEL_CFGS)
    tmp = tempfile.mkdtemp(prefix="bench_trace_")
    trace_path = os.path.join(tmp, "trace.npz")
    synthetic_trace(trace_path)
    cfgs["trace"] = MobilityConfig(model="trace", trace_path=trace_path,
                                   trace_frames_per_epoch=30)
    for name, mcfg in cfgs.items():
        hist = run(algorithm="cached", distribution="noniid", seed=5,
                   dfl=dfl, mobility=mcfg, max_partners=3,
                   partner_sample="random")
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"mobility_{name}", us,
                          f"best_acc={hist['best_acc']:.4f} "
                          + encounter_line(name, mcfg)))
    return lines


if __name__ == "__main__":
    main()
