"""Beyond-paper: Cached-DFL convergence across mobility regimes.

The paper's convergence argument hinges on mobility statistics (meeting
rate, inter-contact time), not on the Manhattan map itself. This
benchmark runs the same Cached-DFL fleet under every registered mobility
model — grid, random waypoint, Lévy walk, community/RPGM, and a synthetic
contact-trace replay — as one ``api.sweep`` over the mobility axis, and
reports best accuracy next to the measured encounter statistics, making
the mobility→convergence coupling visible. Emits
``BENCH_mobility_models.json`` via the shared ``write_bench`` schema.

Run:  PYTHONPATH=src python -m benchmarks.bench_mobility_models
"""
import os
import tempfile

import jax
import numpy as np

from repro import api
from repro.configs.base import MobilityConfig
from repro.mobility import registry, stats
from repro.mobility import trace as trace_lib

from benchmarks.common import FAST, base_scenario, bench_out, emit

N_AGENTS = 10
EPOCH_S = 30.0

MODEL_CFGS = {
    "manhattan": MobilityConfig(model="manhattan", grid_w=4, grid_h=6),
    "random_waypoint": MobilityConfig(model="random_waypoint",
                                      area_w=800.0, area_h=800.0),
    "levy_walk": MobilityConfig(model="levy_walk", area_w=800.0,
                                area_h=800.0, levy_max_flight=800.0),
    "community": MobilityConfig(model="community", area_w=1000.0,
                                area_h=1000.0, num_bands=3,
                                community_radius=120.0),
}
OUT = bench_out("BENCH_mobility_models.json")


def synthetic_trace(path: str, n: int = N_AGENTS, T: int = 240,
                    seed: int = 0) -> None:
    """Bursty schedule: random pairs meet for a few consecutive frames."""
    rng = np.random.default_rng(seed)
    seq = np.zeros((T, n, n), bool)
    for _ in range(6 * n):
        i, j = rng.choice(n, size=2, replace=False)
        t0 = rng.integers(0, T - 5)
        seq[t0:t0 + rng.integers(2, 6), i, j] = True
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))


def encounter_line(name: str, mcfg: MobilityConfig) -> str:
    model = registry.get_model(name)
    state = model.init(jax.random.PRNGKey(7), N_AGENTS, mcfg)
    _, seq = stats.collect_contacts(model, state, jax.random.PRNGKey(8),
                                    mcfg, n_steps=240)
    return stats.summarize(stats.encounter_stats(seq, mcfg.step_seconds))


def main():
    lines = []
    cfgs = dict(MODEL_CFGS)
    tmp = tempfile.mkdtemp(prefix="bench_trace_")
    trace_path = os.path.join(tmp, "trace.npz")
    synthetic_trace(trace_path)
    cfgs["trace"] = MobilityConfig(model="trace", trace_path=trace_path,
                                   trace_frames_per_epoch=30)
    base = base_scenario(seed=5, max_partners=3,
                         partner_sample="random").with_overrides({
                             "dfl.num_agents": N_AGENTS,
                             "dfl.epoch_seconds": EPOCH_S})
    sw = api.sweep(base, {"mobility": list(cfgs.values())})
    encounters = {}
    for cell in sw.cells:
        name = cell.result.scenario.experiment.mobility.model
        us = (cell.result.wall_s / max(len(cell.result.epoch), 1)) * 1e6
        enc = encounter_line(name, cell.result.scenario.experiment.mobility)
        encounters[name] = enc
        lines.append(emit(f"mobility_{name}", us,
                          f"best_acc={cell.result.best_acc:.4f} {enc}"))
    sw.write_bench(OUT, name="mobility_models", fast=FAST,
                   extra={"encounter_stats": encounters})
    return lines


if __name__ == "__main__":
    main()
