"""Paper Fig. 3: accuracy vs cache size (1 / 3 / 10), non-iid, LRU.

Claim: larger caches help under non-iid data.
"""
import dataclasses

from benchmarks.common import BASE, emit, run


from repro.configs.base import MobilityConfig

# Sparse contact graph (large grid): the cache-size effect appears when
# an epoch's direct contacts cover only a fraction of the fleet.
SPARSE = MobilityConfig(grid_w=8, grid_h=16)


def main():
    lines = []
    accs = {}
    # the cache benefit emerges over longer horizons (paper Fig. 3 runs
    # 1000 epochs); we run 40 epochs x 2 seeds and compare mean best acc
    for size in (1, 10):
        bests = []
        for seed in (2, 7):
            dfl = dataclasses.replace(BASE["dfl"], cache_size=size,
                                      num_agents=12, epoch_seconds=30.0)
            hist = run(algorithm="cached", distribution="noniid", seed=seed,
                       dfl=dfl, mobility=SPARSE, epochs=40, max_partners=3)
            bests.append(hist["best_acc"])
            us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
            lines.append(emit(f"fig3_cache{size}_seed{seed}", us,
                              f"best_acc={hist['best_acc']:.4f}"))
        accs[size] = sum(bests) / len(bests)
    lines.append(emit("fig3_claim_larger_cache_helps", 0.0,
                      f"holds={accs[10] >= accs[1] - 0.02} "
                      f"(mean c1={accs[1]:.3f} c10={accs[10]:.3f})"))
    return lines


if __name__ == "__main__":
    main()
