"""Beyond-paper ablation: staleness-decayed aggregation weights
(α_j ∝ n_j·γ^age, after async-FL mixing) vs the paper's flat weights,
under a LARGE τ_max where stale models are plentiful.

Hypothesis: with τ_max=20 and sparse contacts, γ<1 recovers some of the
final-accuracy loss the paper observes for large τ_max (Fig. 4 zoom-ins)
while keeping the early-convergence benefit of a full cache.
"""
import dataclasses

from benchmarks.common import BASE, emit, run
from repro.configs.base import MobilityConfig

SPARSE = MobilityConfig(grid_w=8, grid_h=16)


def main():
    lines = []
    accs = {}
    for gamma in (1.0, 0.7):
        dfl = dataclasses.replace(BASE["dfl"], tau_max=20, num_agents=12,
                                  epoch_seconds=30.0,
                                  staleness_decay=gamma)
        hist = run(algorithm="cached", distribution="noniid", seed=6,
                   dfl=dfl, mobility=SPARSE, epochs=BASE["epochs"] + 10,
                   max_partners=3)
        accs[gamma] = hist["best_acc"]
        us = hist["wall_s"] / max(len(hist["epoch"]), 1) * 1e6
        lines.append(emit(f"ablation_decay_g{gamma}", us,
                          f"best_acc={hist['best_acc']:.4f}"))
    lines.append(emit("ablation_decay_summary", 0.0,
                      f"gamma0.7={accs[0.7]:.3f} vs flat={accs[1.0]:.3f}"))
    return lines


if __name__ == "__main__":
    main()
