"""Scenario smoke gate: every registered mobility model × {cached, dfl},
every registered cache policy × {manhattan, trace}, bandwidth-budget-
limited exchanges (flat and duration-derived caps), every registered
scenario preset (``repro.api.available_presets``) — each preset must
``resolve()`` at full size and smoke-run shrunken — and one
telemetry-enabled run per algorithm whose structured event stream must
validate against the JSONL schema (``repro.telemetry.events``).

Runs 2 tiny epochs of the full experiment loop per combination through
the Scenario API and fails (non-zero exit) on NaN accuracy, shape
errors, or exceptions — so a mobility/scenario/policy/budget/preset/
telemetry regression is caught in seconds without the full benchmark
suite.

The ``--serve`` smoke additionally round-trips two specs through the
streaming scenario service (``repro.serve.service``), validating the
result JSONL schema, wave batching and malformed-spec error handling.

    PYTHONPATH=src python tools/check_scenarios.py [--list] [--only SUBSTR]
    PYTHONPATH=src python tools/check_scenarios.py --telemetry
    PYTHONPATH=src python tools/check_scenarios.py --sharded
    PYTHONPATH=src python tools/check_scenarios.py --serve
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import tempfile
import time
import traceback
from typing import Callable, List, Optional, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --sharded cross-checks the shard_map engine on real multi-device
# layouts; forced host devices must enter XLA_FLAGS before jax (imported
# transitively by repro.api below) initializes its backend.
if "--sharded" in sys.argv[1:]:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=4").strip()

from repro import api  # noqa: E402
from repro.mobility import registry  # noqa: E402
from repro.mobility import trace as trace_lib  # noqa: E402
from repro.policies import registry as policy_registry  # noqa: E402

N_AGENTS = 6
ALGORITHMS = ("cached", "dfl")
POLICY_MOBILITIES = ("manhattan", "trace")
# transfer-budget-limited exchanges: (mobility, policy, budget knobs)
BUDGET_CONFIGS = (
    ("manhattan", "lru", {"dfl.transfer_budget": 0.0}),
    ("manhattan", "lru", {"dfl.transfer_budget": 2.0}),
    ("manhattan", "lru", {"dfl.link_entries_per_step": 0.3}),
    ("trace", "mobility_aware", {"dfl.transfer_budget": 1.0}),
    ("trace", "group", {"dfl.transfer_budget": 2.0,
                        "dfl.link_entries_per_step": 0.5}),
)

# the tiny-footprint overrides every smoke run shares
SMOKE = {
    "epochs": 2, "n_train": 300, "n_test": 60, "image_hw": 8,
    "lr_plateau": False, "partner_sample": "random",
    "early_stop_patience": 100,
    "dfl.num_agents": N_AGENTS, "dfl.cache_size": 3, "dfl.local_steps": 2,
    "dfl.batch_size": 16, "dfl.epoch_seconds": 10.0,
}


def tiny_mobility(name: str, trace_path: str) -> dict:
    if name == "trace":
        return {"mobility.model": name, "mobility.trace_path": trace_path,
                "mobility.trace_frames_per_epoch": 5}
    return {"mobility.model": name, "mobility.grid_w": 4,
            "mobility.grid_h": 6, "mobility.area_w": 400.0,
            "mobility.area_h": 400.0, "mobility.levy_max_flight": 400.0,
            "mobility.community_radius": 80.0}


def make_trace(path: str, n: int = N_AGENTS) -> None:
    rng = np.random.default_rng(0)
    seq = rng.random((20, n, n)) < 0.15
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))


def _run(scenario: api.Scenario) -> Optional[str]:
    result = api.run(scenario)
    epochs = scenario.experiment.epochs
    if len(result.acc) != epochs:
        return f"expected {epochs} eval points, got {len(result.acc)}"
    bad = [a for a in result.acc if not math.isfinite(a)]
    if bad:
        return f"non-finite accuracy: {result.acc}"
    return None


def check_mobility(name: str, algorithm: str, trace_path: str
                   ) -> Optional[str]:
    scenario = api.Scenario().with_overrides({
        **SMOKE, **tiny_mobility(name, trace_path),
        "algorithm": algorithm, "distribution": "noniid"})
    return _run(scenario)


def check_policy(policy: str, mob_name: str, trace_path: str,
                 budget_knobs: Optional[dict] = None) -> Optional[str]:
    """Smoke one registered cache policy through the cached algorithm."""
    grouped = policy_registry.get_policy(policy).needs_group_slots
    scenario = api.Scenario().with_overrides({
        **SMOKE, **tiny_mobility(mob_name, trace_path),
        "algorithm": "cached",
        "distribution": "grouped" if grouped else "noniid",
        "num_groups": 3, "dfl.policy": policy, **(budget_knobs or {})})
    return _run(scenario)


def check_telemetry(algorithm: str, out_dir: str) -> Optional[str]:
    """Telemetry smoke: a tiny telemetry-on run per algorithm; the fleet
    metrics must cover every epoch and the event stream must round-trip
    through JSONL and pass the ``repro-telemetry-v1`` schema gate."""
    from repro.telemetry import events as events_lib
    scenario = api.get_preset("paper-noniid").with_overrides({
        **SMOKE, "algorithm": algorithm})
    scenario = dataclasses.replace(scenario, telemetry=True)
    result = api.run(scenario)
    bad = [a for a in result.acc if not math.isfinite(a)]
    if bad:
        return f"non-finite accuracy: {result.acc}"
    telem = result.telemetry
    if telem is None:
        return "telemetry-enabled run returned no telemetry"
    fleet = telem.get("fleet") or {}
    if fleet.get("epochs") != scenario.experiment.epochs:
        return (f"fleet metrics cover {fleet.get('epochs')} epochs, "
                f"expected {scenario.experiment.epochs}")
    path = os.path.join(out_dir, f"events_{algorithm}.jsonl")
    events_lib.write_jsonl(path, telem["events"])
    problems = events_lib.validate_jsonl(path)
    if problems:
        return "; ".join(problems[:3])
    return None


def check_sharded(algorithm: str) -> Optional[str]:
    """Sharded-engine cross-check: the shard_map engine over every
    visible device (``mesh=0``; 4 forced host devices under ``--sharded``,
    the in-process single device in the default list) must reproduce the
    single-device fused trajectory and hold the 1-trace discipline."""
    import jax
    overrides = {
        **SMOKE, "algorithm": algorithm,
        # lowest-id partner draws are the sharded engine's contract;
        # 8 agents divide every forced-host-device mesh (1/2/4)
        "partner_sample": "lowest-id", "dfl.num_agents": 8,
        "mobility.grid_w": 4, "mobility.grid_h": 6,
    }
    base = api.Scenario().with_overrides(overrides)
    fused = api.run(base)
    sharded = api.run(dataclasses.replace(base, engine="sharded", mesh=0))
    if sharded.traces != 1:
        return f"sharded engine traced {sharded.traces}x, expected 1"
    bad = [a for a in sharded.acc if not math.isfinite(a)]
    if bad:
        return f"non-finite accuracy: {sharded.acc}"
    delta = max(abs(a - b) for a, b in zip(fused.acc, sharded.acc))
    if delta > 2e-3:
        return (f"sharded({jax.device_count()} devices) diverges from "
                f"fused: max|Δacc|={delta:.2e} "
                f"(fused {fused.acc} vs sharded {sharded.acc})")
    return None


def check_preset(name: str) -> Optional[str]:
    """Full-size resolve, then a shrunken smoke run of the preset."""
    scenario = api.get_preset(name)
    scenario.resolve()                       # paper-scale spec must validate
    smoke = dict(SMOKE)
    exp = scenario.experiment
    # keep invariants the preset's spec depends on: the trace fleet size
    # is pinned by the trace file; group policies need slots >= groups
    if exp.mobility.model == "trace":
        smoke.pop("dfl.num_agents")
        smoke.pop("dfl.cache_size")
    if policy_registry.get_policy(exp.dfl.policy).needs_group_slots:
        smoke["dfl.cache_size"] = max(3, exp.num_groups)
    return _run(scenario.with_overrides(smoke))


def check_serve() -> Optional[str]:
    """Scenario-service smoke: round-trip two preset specs (plus one
    malformed line) through the streaming queue; the JSONL result stream
    must validate, both runs must land in one wave on one engine with
    retraces pinned at 0, and the bad line must surface as a structured
    error without stalling the queue."""
    import io
    import json

    from repro.serve import service as service_lib
    from repro.telemetry import events as events_lib

    out = io.StringIO()
    svc = service_lib.ScenarioService(out=out)
    svc.submit_lines([
        json.dumps({"rid": "a", "preset": "paper-noniid",
                    "overrides": SMOKE}),
        json.dumps({"rid": "b", "preset": "paper-noniid",
                    "overrides": {**SMOKE, "dfl.lr": 0.05}}),
        json.dumps({"rid": "bad", "preset": "no-such-preset"}),
    ])
    summary = svc.drain()
    problems = service_lib.validate_service_jsonl(out.getvalue().splitlines())
    if problems:
        return "; ".join(problems[:3])
    if summary["runs_ok"] != 2 or summary["runs_failed"] != 1:
        return f"expected 2 ok + 1 failed, got {summary}"
    rows = {r["rid"]: r for r in svc.results if r["kind"] == "result"}
    if rows["a"]["wave"] != rows["b"]["wave"]:
        return ("same-engine specs split across waves "
                f"{rows['a']['wave']} vs {rows['b']['wave']}")
    if rows["bad"]["status"] != "error":
        return f"malformed spec not surfaced as error: {rows['bad']}"
    if summary["num_engines"] != 1 or summary["retraces"] != 0:
        return (f"expected 1 engine / 0 retraces, got "
                f"{summary['num_engines']} / {summary['retraces']}")
    ev_problems = events_lib.validate_events(svc.events.to_dicts())
    if ev_problems:
        return "; ".join(ev_problems[:3])
    return None


def check_analysis() -> Optional[str]:
    """Run the static-analysis gate (tools/analyze.py --json) and fail on
    any active (unsuppressed, unbaselined) finding."""
    import json
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = os.path.join(tempfile.mkdtemp(prefix="analysis_"),
                       "findings.json")
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "analyze.py"),
         "src", "--json", out, "-q"],
        cwd=root, capture_output=True, text=True, timeout=120)
    try:
        with open(out) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        return f"analyzer produced no findings JSON: {proc.stderr[-300:]}"
    active = doc["counts"]["active"]
    if proc.returncode or active:
        heads = [f"{f['path']}:{f['line']} {f['rule']}"
                 for f in doc["findings"] if f["rule"]][:5]
        return (f"{active} active finding(s): " + "; ".join(heads))
    return None


def build_checks(trace_path: str) -> List[Tuple[str, Callable[[], Optional[str]]]]:
    checks: List[Tuple[str, Callable[[], Optional[str]]]] = []
    checks.append(("analysis:static", check_analysis))
    for name in registry.available():
        for algorithm in ALGORITHMS:
            checks.append((f"mobility:{name}×{algorithm}",
                           lambda n=name, a=algorithm:
                           check_mobility(n, a, trace_path)))
    for policy in policy_registry.available():
        for mob_name in POLICY_MOBILITIES:
            checks.append((f"policy:{policy}×{mob_name}",
                           lambda p=policy, m=mob_name:
                           check_policy(p, m, trace_path)))
    for mob_name, policy, knobs in BUDGET_CONFIGS:
        label = ",".join(f"{k.split('.')[-1]}={v}" for k, v in knobs.items())
        checks.append((f"budget:{policy}×{mob_name}[{label}]",
                       lambda p=policy, m=mob_name, k=knobs:
                       check_policy(p, m, trace_path, budget_knobs=k)))
    for name in api.available_presets():
        checks.append((f"preset:{name}", lambda n=name: check_preset(n)))
    out_dir = os.path.dirname(trace_path)
    for algorithm in ("cached", "dfl", "cfl"):
        checks.append((f"telemetry:{algorithm}",
                       lambda a=algorithm: check_telemetry(a, out_dir)))
    for algorithm in ("cached", "dfl", "cfl"):
        checks.append((f"sharded:{algorithm}",
                       lambda a=algorithm: check_sharded(a)))
    checks.append(("serve:roundtrip", check_serve))
    return checks


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="list scenario ids without running them")
    ap.add_argument("--only", default="",
                    help="run only scenarios whose id contains SUBSTR")
    ap.add_argument("--telemetry", action="store_true",
                    help="run only the telemetry smoke checks (one "
                         "telemetry-on run per algorithm + JSONL schema "
                         "validation)")
    ap.add_argument("--sharded", action="store_true",
                    help="run only the sharded-engine cross-checks, under "
                         "4 forced host devices (one shard_map run per "
                         "algorithm, compared against the single-device "
                         "fused engine)")
    ap.add_argument("--analyze", action="store_true",
                    help="run only the static-analysis gate "
                         "(tools/analyze.py over src/, fail on active "
                         "findings)")
    ap.add_argument("--serve", action="store_true",
                    help="run only the scenario-service smoke (two specs "
                         "round-tripped through the streaming queue, JSONL "
                         "schema-validated, batching + error handling "
                         "pinned)")
    args = ap.parse_args(argv)

    tmp = tempfile.mkdtemp(prefix="check_scenarios_")
    trace_path = os.path.join(tmp, "trace.npz")
    make_trace(trace_path)
    checks = build_checks(trace_path)
    if args.telemetry:
        checks = [(cid, fn) for cid, fn in checks
                  if cid.startswith("telemetry:")]
    if args.sharded:
        checks = [(cid, fn) for cid, fn in checks
                  if cid.startswith("sharded:")]
    if args.analyze:
        checks = [(cid, fn) for cid, fn in checks
                  if cid.startswith("analysis:")]
    if args.serve:
        checks = [(cid, fn) for cid, fn in checks
                  if cid.startswith("serve:")]
    if args.only:
        checks = [(cid, fn) for cid, fn in checks if args.only in cid]
    if args.list:
        for cid, _ in checks:
            print(cid)
        return 0
    if not checks:
        print(f"no scenarios match --only {args.only!r}")
        return 1

    failures = 0
    for cid, fn in checks:
        t0 = time.time()
        try:
            err = fn()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            err = f"{type(e).__name__}: {e}"
        status = "PASS" if err is None else f"FAIL ({err})"
        failures += err is not None
        print(f"{cid:>44} {status} [{time.time() - t0:.1f}s]")
    print(f"{failures} failure(s) across {len(checks)} scenarios")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
