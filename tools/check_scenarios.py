"""Scenario smoke gate: every registered mobility model × {cached, dfl},
every registered cache policy × {manhattan, trace}, plus
bandwidth-budget-limited exchanges (flat and duration-derived caps).

Runs 2 tiny epochs of the full experiment loop per combination and fails
(non-zero exit) on NaN accuracy, shape errors, or exceptions — so a
mobility/scenario/policy/budget regression is caught in seconds without
the full benchmark suite.

    PYTHONPATH=src python tools/check_scenarios.py
"""
from __future__ import annotations

import math
import os
import sys
import tempfile
import time
import traceback

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import DFLConfig, MobilityConfig  # noqa: E402
from repro.fl.experiment import ExperimentConfig, run_experiment  # noqa: E402
from repro.mobility import registry  # noqa: E402
from repro.mobility import trace as trace_lib  # noqa: E402
from repro.policies import registry as policy_registry  # noqa: E402

N_AGENTS = 6
ALGORITHMS = ("cached", "dfl")
POLICY_MOBILITIES = ("manhattan", "trace")
# transfer-budget-limited exchanges: (mobility, policy, budget knobs)
BUDGET_CONFIGS = (
    ("manhattan", "lru", dict(transfer_budget=0.0)),
    ("manhattan", "lru", dict(transfer_budget=2.0)),
    ("manhattan", "lru", dict(link_entries_per_step=0.3)),
    ("trace", "mobility_aware", dict(transfer_budget=1.0)),
    ("trace", "group", dict(transfer_budget=2.0,
                            link_entries_per_step=0.5)),
)


def tiny_mobility(name: str, trace_path: str) -> MobilityConfig:
    if name == "trace":
        return MobilityConfig(model=name, trace_path=trace_path,
                              trace_frames_per_epoch=5)
    return MobilityConfig(model=name, grid_w=4, grid_h=6,
                          area_w=400.0, area_h=400.0,
                          levy_max_flight=400.0, community_radius=80.0)


def make_trace(path: str) -> None:
    rng = np.random.default_rng(0)
    seq = rng.random((20, N_AGENTS, N_AGENTS)) < 0.15
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))


def _run(cfg: ExperimentConfig) -> str | None:
    hist = run_experiment(cfg)
    if len(hist["acc"]) != cfg.epochs:
        return f"expected {cfg.epochs} eval points, got {len(hist['acc'])}"
    bad = [a for a in hist["acc"] if not math.isfinite(a)]
    if bad:
        return f"non-finite accuracy: {hist['acc']}"
    return None


def check(name: str, algorithm: str, trace_path: str) -> str | None:
    cfg = ExperimentConfig(
        algorithm=algorithm, distribution="noniid",
        dfl=DFLConfig(num_agents=N_AGENTS, cache_size=3, local_steps=2,
                      batch_size=16, epoch_seconds=10.0),
        mobility=tiny_mobility(name, trace_path),
        epochs=2, n_train=300, n_test=60, image_hw=8,
        lr_plateau=False, partner_sample="random")
    return _run(cfg)


def check_policy(policy: str, mob_name: str, trace_path: str,
                 budget_knobs: dict | None = None) -> str | None:
    """Smoke one registered cache policy through the cached algorithm."""
    grouped = policy_registry.get_policy(policy).needs_group_slots
    cfg = ExperimentConfig(
        algorithm="cached",
        distribution="grouped" if grouped else "noniid",
        num_groups=3,
        dfl=DFLConfig(num_agents=N_AGENTS, cache_size=3, local_steps=2,
                      batch_size=16, epoch_seconds=10.0, policy=policy,
                      **(budget_knobs or {})),
        mobility=tiny_mobility(mob_name, trace_path),
        epochs=2, n_train=300, n_test=60, image_hw=8,
        lr_plateau=False, partner_sample="random")
    return _run(cfg)


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="check_scenarios_")
    trace_path = os.path.join(tmp, "trace.npz")
    make_trace(trace_path)
    failures = total = 0
    for name in registry.available():
        for algorithm in ALGORITHMS:
            t0 = time.time()
            try:
                err = check(name, algorithm, trace_path)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                err = f"{type(e).__name__}: {e}"
            status = "PASS" if err is None else f"FAIL ({err})"
            failures += err is not None
            total += 1
            print(f"{name:>16} × {algorithm:<6} {status} "
                  f"[{time.time() - t0:.1f}s]")
    for policy in policy_registry.available():
        for mob_name in POLICY_MOBILITIES:
            t0 = time.time()
            try:
                err = check_policy(policy, mob_name, trace_path)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                err = f"{type(e).__name__}: {e}"
            status = "PASS" if err is None else f"FAIL ({err})"
            failures += err is not None
            total += 1
            print(f"{policy:>18} × {mob_name:<9} {status} "
                  f"[{time.time() - t0:.1f}s]")
    for mob_name, policy, knobs in BUDGET_CONFIGS:
        t0 = time.time()
        try:
            err = check_policy(policy, mob_name, trace_path,
                               budget_knobs=knobs)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            err = f"{type(e).__name__}: {e}"
        status = "PASS" if err is None else f"FAIL ({err})"
        failures += err is not None
        total += 1
        label = ",".join(f"{k}={v}" for k, v in knobs.items())
        print(f"{policy:>18} × {mob_name:<9} budget[{label}] {status} "
              f"[{time.time() - t0:.1f}s]")
    print(f"{failures} failure(s) across {total} scenarios")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
