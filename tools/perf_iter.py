import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (§Perf hillclimb): lower one (arch × shape) with a
named set of knob overrides, extract the extrapolated roofline metrics and
print before/after-comparable numbers.

    PYTHONPATH=src python tools/perf_iter.py --arch deepseek-67b \
        --shape train_4k --variant baseline
    PYTHONPATH=src python tools/perf_iter.py --arch deepseek-67b \
        --shape train_4k --variant mb4_bf16 --microbatches 4 \
        --param-dtype bfloat16

Writes experiments/perf/<arch>_<shape>_<variant>.json.
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import registry as cfg_registry
from repro.launch.dryrun import (_cost_metrics, build_lowering, make_rules)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (RooflineTerms, extrapolate, format_row,
                                   model_flops, summarize_memory)
from repro.configs.base import get_shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-dtype", default="")
    ap.add_argument("--compute-dtype", default="")
    ap.add_argument("--remat-policy", default="")
    ap.add_argument("--moe-token-shard", action="store_true")
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--pure-fsdp", action="store_true",
                    help="no TP: batch over both axes, weights FSDP-sharded")
    ap.add_argument("--cache-size", type=int, default=3)
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = cfg_registry.get_config(args.arch)
    over = {}
    if args.param_dtype:
        over["param_dtype"] = args.param_dtype
    if args.compute_dtype:
        over["compute_dtype"] = args.compute_dtype
    if args.remat_policy:
        over["remat_policy"] = args.remat_policy
    if args.moe_token_shard:
        over["moe_token_shard"] = True
    if args.moe_shard_map:
        over["moe_shard_map"] = True
    if args.kv_quant:
        over["kv_quant"] = True
    if over:
        cfg = dataclasses.replace(cfg, **over)

    mesh = make_production_mesh()
    rules = make_rules(cfg, mesh)
    if args.no_fsdp:
        rules = dataclasses.replace(rules, fsdp=False)
    if args.fsdp:
        rules = dataclasses.replace(rules, fsdp=True)
    if args.pure_fsdp:
        rules = dataclasses.replace(rules, pure_fsdp=True, fsdp=False)

    t0 = time.time()
    # full scan compile for memory analysis
    low, _ = build_lowering(cfg, args.shape, mesh, scan_layers=True,
                            cache_size=args.cache_size, rules=rules,
                            microbatches=args.microbatches,
                            kv_chunk=args.kv_chunk)
    mem = summarize_memory(low.compile().memory_analysis())
    # 2L/3L extrapolation for flops/bytes/collectives
    bases = {}
    for L in (2, 3):
        cfg_l = dataclasses.replace(
            cfg, n_layers=L, enc_layers=L if cfg.enc_dec else 0)
        low_l, _ = build_lowering(cfg_l, args.shape, mesh,
                                  scan_layers=False,
                                  cache_size=args.cache_size, rules=rules,
                                  microbatches=args.microbatches,
                                  kv_chunk=args.kv_chunk)
        bases[L] = _cost_metrics(low_l.compile())
    total = extrapolate(bases[2], bases[3], cfg.n_layers)
    terms = RooflineTerms(
        arch=args.arch, shape=args.shape, mesh=f"single/{args.variant}",
        chips=mesh.devices.size,
        hlo_flops=total["flops"], hlo_bytes=total["bytes"],
        coll_bytes=total["coll_bytes"],
        coll_breakdown={k[5:]: v for k, v in total.items()
                        if k.startswith("coll_")},
        model_flops=model_flops(cfg, get_shape(args.shape)),
        bytes_per_device=mem["total_bytes_per_device"] or 0)
    print(format_row(terms))
    print(f"  coll breakdown: "
          f"{ {k: f'{v/1e9:.1f}GB' for k, v in terms.coll_breakdown.items() if v} }")
    print(f"  wall: {time.time() - t0:.0f}s")

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}_{args.variant}.json")
    with open(path, "w") as f:
        json.dump({"variant": args.variant, "overrides": over,
                   "microbatches": args.microbatches,
                   "fsdp": rules.fsdp, "memory": mem,
                   "roofline": terms.to_dict()}, f, indent=1, default=str)
    print(f"  -> {path}")


if __name__ == "__main__":
    main()
