"""Render a run or benchmark artifact into a markdown dashboard.

Consumes either a ``RunResult`` JSON (``train.py --out run.json`` /
``RunResult.to_json``) or a sweep benchmark artifact (``BENCH_*.json``,
schema ``sweep-v1``) and emits a self-contained markdown report:

- run reports: accuracy trajectory with cache staleness at each eval
  point (the staleness-vs-accuracy table), the phase-time breakdown from
  the span telemetry, the on-device fleet metrics summary (staleness
  histogram, model spread, gossip traffic, budget utilization) and the
  structured event stream tail;
- bench reports: per-cell results with telemetry summary columns when
  the sweep ran telemetry-enabled, engine/retrace accounting, for sweeps
  with a ``dfl.transfer_budget`` axis the budget-utilization frontier
  (accuracy and realized utilization per budget level), for sweeps with
  a ``dfl.churn_fraction`` axis the accuracy-vs-churn frontier
  (``BENCH_churn.json`` — per-algorithm best accuracy per churn level,
  with the cached-over-dfl robustness gap), and — when the artifact
  carries ``extra.scaling`` (the fleet-scale bench) — the sharded-engine
  epochs/s-vs-devices scaling table;
- JSONL streams: a ``repro-fleet-serve-v1`` scenario-service result
  stream (``fleet_serve --out``) renders the wave/engine accounting and
  a per-run outcome table; a ``repro-telemetry-v1`` event log
  (``--events-out`` / ``--telemetry-out``) renders per-kind counts and
  the service queue-event trail.

Telemetry fields are optional throughout: artifacts written before the
telemetry subsystem (or with ``telemetry=False``) render with the
columns they have.

    PYTHONPATH=src python tools/report.py run.json [-o report.md]
    PYTHONPATH=src python tools/report.py BENCH_budget.json
    PYTHONPATH=src python tools/report.py serve-results.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Mapping, Optional, Sequence


def _fmt(v: Any, digits: int = 4) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.{digits}f}"
    if isinstance(v, Mapping):  # config-object override (e.g. mobility)
        for key in ("model", "name"):
            if key in v:
                return str(v[key])
        return "<config>"
    return str(v)


def _table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> List[str]:
    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(_fmt(c) for c in row) + " |")
    return lines


def is_bench(doc: Mapping[str, Any]) -> bool:
    return "cells" in doc and "axes" in doc


def is_analysis(doc: Mapping[str, Any]) -> bool:
    return doc.get("schema") == "repro-analysis-v1"


# ---------------------------------------------------------------------------
# analyzer-findings report
# ---------------------------------------------------------------------------

def render_analysis(doc: Mapping[str, Any]) -> str:
    """Markdown section for a ``tools/analyze.py --json`` artifact."""
    counts = doc.get("counts") or {}
    findings = doc.get("findings") or []
    out: List[str] = ["# Static-analysis report", ""]
    out.append(f"- {counts.get('active', 0)} active finding(s), "
               f"{counts.get('suppressed', 0)} suppressed, "
               f"{counts.get('baselined', 0)} baselined "
               f"(wall {_fmt(doc.get('wall_s'), 2)}s)")
    per_rule = counts.get("per_rule") or {}
    if per_rule:
        out.append("- active by rule: "
                   + ", ".join(f"{r}×{n}" for r, n in
                               sorted(per_rule.items())))
    out.append("")
    active = [f for f in findings
              if not (f.get("suppressed") or f.get("baselined"))]
    if active:
        out.append("## Findings")
        out.append("")
        rows = [[f.get("rule"), f"{f.get('path')}:{f.get('line')}",
                 f.get("message"), f.get("hint")] for f in active]
        out.extend(_table(["rule", "location", "message", "hint"], rows))
        out.append("")
    suppressed = [f for f in findings if f.get("suppressed")]
    if suppressed:
        out.append("## Suppressed (justified host boundaries etc.)")
        out.append("")
        rows = [[f.get("rule"), f"{f.get('path')}:{f.get('line')}",
                 f.get("reason") or "—"] for f in suppressed]
        out.extend(_table(["rule", "location", "justification"], rows))
        out.append("")
    if not findings:
        out.append("No findings — the tree is analyzer-clean.")
        out.append("")
    return "\n".join(out).rstrip() + "\n"


# ---------------------------------------------------------------------------
# run report
# ---------------------------------------------------------------------------

def render_run(doc: Mapping[str, Any]) -> str:
    scenario = doc.get("scenario") or {}
    exp = scenario.get("experiment") or {}
    metrics = doc.get("metrics") or {}
    telem = doc.get("telemetry")
    name = scenario.get("name") or exp.get("model", "run")

    out: List[str] = [f"# Run report: {name}", ""]
    out.append(f"- config hash: `{doc.get('config_hash', '?')}` "
               f"(engine `{doc.get('engine', '?')}`, algorithm "
               f"`{exp.get('algorithm', '?')}`)")
    out.append(f"- best acc **{_fmt(doc.get('best_acc'))}** at epoch "
               f"{doc.get('best_epoch', '?')}; final "
               f"{_fmt(doc.get('final_acc'))}")
    out.append(f"- wall {_fmt(doc.get('wall_s'), 2)}s, "
               f"{doc.get('traces', '?')} engine trace(s)")
    out.append("")

    # staleness-vs-accuracy trajectory
    epochs = metrics.get("epoch") or []
    if epochs:
        tel_eval = (telem or {}).get("eval") or {}
        headers = ["epoch", "acc", "lr"]
        cols: List[List[Any]] = [metrics.get("acc") or [],
                                 metrics.get("lr") or []]
        for label, series in (("cache_num", metrics.get("cache_num")),
                              ("cache_age", metrics.get("cache_age")),
                              ("acc_std", tel_eval.get("acc_std")),
                              ("acc_min", tel_eval.get("acc_min")),
                              ("acc_max", tel_eval.get("acc_max")),
                              ("contacts/epoch",
                               tel_eval.get("contacts_per_epoch"))):
            if series and len(series) == len(epochs):
                headers.append(label)
                cols.append(series)
        rows = [[ep] + [c[i] for c in cols] for i, ep in enumerate(epochs)]
        out.append("## Staleness vs accuracy")
        out.append("")
        out.extend(_table(headers, rows))
        out.append("")

    # phase-time breakdown
    phase_s = doc.get("phase_s") or {}
    if phase_s:
        total = sum(phase_s.values())
        out.append("## Phase times")
        out.append("")
        rows = [[name_, f"{secs:.3f}",
                 f"{100.0 * secs / total:.1f}%" if total else "—"]
                for name_, secs in sorted(phase_s.items(),
                                          key=lambda kv: -kv[1])]
        out.extend(_table(["phase", "seconds", "share"], rows))
        out.append("")

    # on-device fleet metrics
    fleet = (telem or {}).get("fleet")
    if fleet:
        out.append("## Fleet metrics")
        out.append("")
        out.append(f"- staleness: mean {_fmt(fleet.get('staleness_mean'), 2)} "
                   f"epochs, p95 {fleet.get('staleness_p95', '—')} "
                   f"({fleet.get('cache_entry_epochs', 0)} cache "
                   f"entry-epochs)")
        hist = fleet.get("staleness_hist") or []
        if hist:
            out.append(f"- staleness histogram (age 0..{len(hist) - 1}): "
                       f"{hist}")
        out.append(f"- model spread: mean {_fmt(fleet.get('spread_mean'), 2)}"
                   f" / min {_fmt(fleet.get('spread_min'), 0)} / max "
                   f"{_fmt(fleet.get('spread_max'), 0)} origins per agent "
                   f"(reach {_fmt(fleet.get('reach_fraction'), 3)})")
        out.append(f"- gossip traffic: offered {_fmt(fleet.get('offered'), 0)}"
                   f", admitted {_fmt(fleet.get('admitted'), 0)}, denied "
                   f"{_fmt(fleet.get('denied'), 0)} "
                   f"({_fmt(fleet.get('admitted_per_epoch'), 1)} "
                   f"admitted/epoch)")
        util = fleet.get("budget_utilization")
        if util is not None:
            out.append(f"- budget utilization: {_fmt(util, 3)} over "
                       f"{_fmt(fleet.get('capped_links'), 0)} capped links "
                       f"(capacity {_fmt(fleet.get('link_capacity'), 0)} "
                       f"entries)")
        out.append(f"- contacts: {_fmt(fleet.get('contacts'), 0)} total, "
                   f"{_fmt(fleet.get('contacts_per_epoch'), 2)} per epoch")
        out.append("")

    # event stream tail
    events = (telem or {}).get("events") or []
    if events:
        out.append("## Events")
        out.append("")
        out.append(f"{len(events)} events "
                   f"(schema `{(telem or {}).get('schema', '?')}`); last 5:")
        out.append("")
        out.append("```json")
        for ev in events[-5:]:
            out.append(json.dumps(ev, sort_keys=True))
        out.append("```")
        out.append("")
    return "\n".join(out).rstrip() + "\n"


# ---------------------------------------------------------------------------
# bench report
# ---------------------------------------------------------------------------

_TELEMETRY_COLUMNS = (("staleness_mean", "staleness"),
                      ("reach_fraction", "reach"),
                      ("admitted_per_epoch", "admitted/ep"),
                      ("budget_utilization", "budget util"))


def render_bench(doc: Mapping[str, Any]) -> str:
    cells = doc.get("cells") or []
    axes = doc.get("axes") or {}
    name = doc.get("bench") or "sweep"

    out: List[str] = [f"# Benchmark report: {name}", ""]
    out.append(f"- base config `{doc.get('base_config_hash', '?')}`, "
               f"schema `{doc.get('schema', '?')}`"
               + (f", fast={doc['fast']}" if "fast" in doc else ""))
    out.append(f"- {len(cells)} cells over axes "
               f"{{{', '.join(sorted(axes))}}}; "
               f"{doc.get('num_engines', '?')} engine(s), "
               f"{doc.get('retraces', '?')} retrace(s), wall "
               f"{_fmt(doc.get('wall_s'), 1)}s")
    out.append("")

    has_telem = any(c.get("telemetry") for c in cells)
    axis_names = sorted(axes)
    headers = axis_names + ["best_acc", "final_acc", "epochs", "wall_s"]
    if has_telem:
        headers += [label for _, label in _TELEMETRY_COLUMNS]
    rows = []
    for cell in cells:
        ov = cell.get("overrides") or {}
        row: List[Any] = [ov.get(a) for a in axis_names]
        row += [cell.get("best_acc"), cell.get("final_acc"),
                cell.get("epochs_run"), cell.get("wall_s")]
        if has_telem:
            tc = cell.get("telemetry") or {}
            row += [tc.get(key) for key, _ in _TELEMETRY_COLUMNS]
        rows.append(row)
    out.append("## Cells")
    out.append("")
    out.extend(_table(headers, rows))
    out.append("")

    churn = churn_frontier(cells)
    if churn:
        out.append("## Accuracy-vs-churn frontier")
        out.append("")
        out.append("Best accuracy per algorithm at each churn level "
                   "(fraction of every churn cycle an agent spends out "
                   "of coverage); the gap column is cached minus dfl — "
                   "the caching robustness margin under churn:")
        out.append("")
        algos = sorted({a for _, per_algo in churn for a in per_algo})
        headers = ["churn_fraction"] + algos
        if "cached" in algos and "dfl" in algos:
            headers.append("gap (cached - dfl)")
        rows = []
        for level, per_algo in churn:
            row: List[Any] = [level] + [per_algo.get(a) for a in algos]
            if "cached" in algos and "dfl" in algos:
                c, d = per_algo.get("cached"), per_algo.get("dfl")
                row.append(None if c is None or d is None else c - d)
            rows.append(row)
        out.extend(_table(headers, rows))
        out.append("")

    frontier = budget_frontier(cells)
    if frontier:
        out.append("## Budget-utilization frontier")
        out.append("")
        out.append("Best accuracy per transfer-budget level, across all "
                   "other axis values"
                   + (" (with realized budget utilization)"
                      if has_telem else "") + ":")
        out.append("")
        headers = ["transfer_budget", "best_acc", "cells"]
        if has_telem:
            headers.insert(2, "budget util (best cell)")
        rows = []
        for budget, info in frontier:
            row = [budget, info["best_acc"], info["cells"]]
            if has_telem:
                row.insert(2, info["budget_utilization"])
            rows.append(row)
        out.extend(_table(headers, rows))
        out.append("")

    scaling = (doc.get("extra") or {}).get("scaling") or []
    if scaling:
        out.append("## Sharded-engine scaling (epochs/s vs devices)")
        out.append("")
        out.append("Fixed fleet, compile-free dispatch throughput per "
                   "device-mesh size (block-sparse halo gossip: each shard "
                   "computes contacts against its window columns only):")
        out.append("")
        cols = [("devices", "devices"), ("num_agents", "N"),
                ("halo", "halo"), ("window", "window cols"),
                ("epochs_per_s", "epochs/s"),
                ("speedup_vs_1dev", "speedup vs 1 dev"),
                ("traces", "traces")]
        cols = [(k, label) for k, label in cols
                if any(k in r for r in scaling)]
        rows = [[r.get(k) for k, _ in cols] for r in scaling]
        out.extend(_table([label for _, label in cols], rows))
        out.append("")
    return "\n".join(out).rstrip() + "\n"


def budget_frontier(cells: Sequence[Mapping[str, Any]]
                    ) -> List[Any]:
    """Per transfer-budget level: the best cell's accuracy (+ realized
    utilization when telemetry columns are present). Empty when the sweep
    has no ``dfl.transfer_budget`` axis."""
    levels: Dict[Any, Dict[str, Any]] = {}
    order: List[Any] = []
    for cell in cells:
        ov = cell.get("overrides") or {}
        if "dfl.transfer_budget" not in ov:
            continue
        budget = ov["dfl.transfer_budget"]
        if budget not in levels:
            levels[budget] = {"best_acc": None, "budget_utilization": None,
                              "cells": 0}
            order.append(budget)
        info = levels[budget]
        info["cells"] += 1
        acc = cell.get("best_acc")
        if acc is not None and (info["best_acc"] is None
                                or acc > info["best_acc"]):
            info["best_acc"] = acc
            info["budget_utilization"] = (
                (cell.get("telemetry") or {}).get("budget_utilization"))

    def sort_key(b):
        try:
            return (0, float(b))
        except (TypeError, ValueError):
            return (1, str(b))

    return [(b, levels[b]) for b in sorted(order, key=sort_key)]


def churn_frontier(cells: Sequence[Mapping[str, Any]]
                   ) -> List[Any]:
    """Per churn level: each algorithm's best accuracy across all other
    axis values. Empty when the sweep has no ``dfl.churn_fraction``
    axis. Returns ``[(level, {algorithm: best_acc}), ...]`` sorted by
    churn level."""
    levels: Dict[Any, Dict[str, Any]] = {}
    for cell in cells:
        ov = cell.get("overrides") or {}
        if "dfl.churn_fraction" not in ov:
            continue
        level = ov["dfl.churn_fraction"]
        algo = str(ov.get("algorithm", "cached"))
        per_algo = levels.setdefault(level, {})
        acc = cell.get("best_acc")
        if acc is not None and (per_algo.get(algo) is None
                                or acc > per_algo[algo]):
            per_algo[algo] = acc
    return sorted(levels.items(), key=lambda kv: float(kv[0]))


# ---------------------------------------------------------------------------
# JSONL streams: service results + telemetry event logs
# ---------------------------------------------------------------------------

_SERVICE_SCHEMA = "repro-fleet-serve-v1"
_EVENTS_SCHEMA = "repro-telemetry-v1"
_QUEUE_KINDS = ("run_queued", "run_batched", "run_failed")


def is_service_stream(rows: Sequence[Mapping[str, Any]]) -> bool:
    return bool(rows) and rows[0].get("schema") == _SERVICE_SCHEMA


def is_event_stream(rows: Sequence[Mapping[str, Any]]) -> bool:
    return bool(rows) and all(
        isinstance(r.get("kind"), str) and "data" in r for r in rows)


def render_service(rows: Sequence[Mapping[str, Any]]) -> str:
    """Markdown for a scenario-service result stream (fleet_serve)."""
    results = [r for r in rows if r.get("kind") == "result"]
    summary = next((r for r in rows if r.get("kind") == "summary"), {})
    out: List[str] = ["# Scenario-service report", ""]
    out.append(f"- schema `{_SERVICE_SCHEMA}`: "
               f"{summary.get('runs_ok', '?')} ok / "
               f"{summary.get('runs_failed', '?')} failed over "
               f"{summary.get('waves', '?')} wave(s)")
    out.append(f"- {summary.get('num_engines', '?')} compiled engine(s), "
               f"{summary.get('retraces', '?')} retrace(s) — same-key "
               "specs share one executable")
    out.append("")
    if results:
        out.append("## Runs")
        out.append("")
        rows_md = []
        for r in results:
            res = r.get("result") or {}
            rows_md.append([
                r.get("rid"), r.get("wave"), r.get("status"),
                r.get("attempts"), res.get("best_acc"),
                res.get("final_acc"), res.get("traces"),
                res.get("wall_s") if r.get("status") == "ok"
                else r.get("error")])
        out.extend(_table(["rid", "wave", "status", "attempts", "best_acc",
                           "final_acc", "traces", "wall_s / error"],
                          rows_md))
        out.append("")
    return "\n".join(out).rstrip() + "\n"


def render_events(rows: Sequence[Mapping[str, Any]]) -> str:
    """Markdown for a telemetry event-log JSONL (run or service)."""
    counts: Dict[str, int] = {}
    for r in rows:
        counts[str(r.get("kind"))] = counts.get(str(r.get("kind")), 0) + 1
    out: List[str] = ["# Event-log report", ""]
    out.append(f"- {len(rows)} events (schema `{_EVENTS_SCHEMA}`): "
               + ", ".join(f"{k}×{n}" for k, n in sorted(counts.items())))
    out.append("")
    queue = [r for r in rows if r.get("kind") in _QUEUE_KINDS]
    if queue:
        out.append("## Service queue events")
        out.append("")
        rows_md = [[r.get("t"), r.get("kind"),
                    (r.get("data") or {}).get("rid"),
                    (r.get("data") or {}).get("wave"),
                    (r.get("data") or {}).get("error")] for r in queue]
        out.extend(_table(["t", "kind", "rid", "wave", "error"], rows_md))
        out.append("")
    tail = [r for r in rows if r.get("kind") not in _QUEUE_KINDS][-5:]
    if tail:
        out.append("## Tail")
        out.append("")
        out.append("```json")
        for ev in tail:
            out.append(json.dumps(ev, sort_keys=True))
        out.append("```")
        out.append("")
    return "\n".join(out).rstrip() + "\n"


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def render(doc: Mapping[str, Any]) -> str:
    if is_analysis(doc):
        return render_analysis(doc)
    return render_bench(doc) if is_bench(doc) else render_run(doc)


def render_jsonl(rows: Sequence[Mapping[str, Any]]) -> str:
    if is_service_stream(rows):
        return render_service(rows)
    if is_event_stream(rows):
        return render_events(rows)
    raise ValueError("unrecognized JSONL stream: neither a "
                     f"{_SERVICE_SCHEMA} result stream nor a "
                     f"{_EVENTS_SCHEMA} event log")


def load_artifact(path: str):
    """A (kind, payload) pair: ("doc", dict) for a JSON artifact,
    ("jsonl", rows) for a JSON Lines stream."""
    with open(path) as f:
        text = f.read()
    try:
        return "doc", json.loads(text)
    except json.JSONDecodeError:
        rows = [json.loads(line) for line in text.splitlines()
                if line.strip()]
        return "jsonl", rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact",
                    help="RunResult JSON, BENCH_*.json, or a JSONL "
                         "stream (service results / event log)")
    ap.add_argument("-o", "--out", default="",
                    help="write markdown here (default: stdout)")
    args = ap.parse_args(argv)
    kind, payload = load_artifact(args.artifact)
    md = render(payload) if kind == "doc" else render_jsonl(payload)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"report -> {args.out}")
    else:
        sys.stdout.write(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
