"""Build the EXPERIMENTS.md §Roofline markdown table from the dry-run
artifacts in experiments/dryrun.

    PYTHONPATH=src python tools/make_roofline_table.py [--mesh single]
"""
import argparse
import glob
import json
import os


def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x * 1e3:8.2f}ms"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir,
                                              f"*_{args.mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        rows.append(r)

    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                   "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], shape_order.get(r["shape"], 9)))

    if args.markdown:
        print("| arch | shape | compute | memory | collective | bottleneck"
              " | useful FLOPs | GiB/dev | note |")
        print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            if args.markdown:
                print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                      f" — | SKIP (full attention) |")
            else:
                print(f"{r['arch']:<20} {r['shape']:<12} SKIP")
            continue
        if r["status"] != "ok" or "roofline" not in r:
            print(f"{r['arch']:<20} {r['shape']:<12} {r['status']}")
            continue
        t = r["roofline"]
        gib = t["bytes_per_device"] / 2**30
        note = "over-HBM" if gib > 16 else ""
        if args.markdown:
            print(f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} |"
                  f" {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} |"
                  f" {t['bottleneck']} | {t['useful_flops_ratio']:.0%} |"
                  f" {gib:.1f} | {note} |")
        else:
            print(f"{r['arch']:<20} {r['shape']:<12} "
                  f"comp={fmt_s(t['compute_s'])} mem={fmt_s(t['memory_s'])} "
                  f"coll={fmt_s(t['collective_s'])} -> "
                  f"{t['bottleneck']:<10} useful={t['useful_flops_ratio']:.0%}"
                  f" dev={gib:6.1f}GiB {note}")


if __name__ == "__main__":
    main()
