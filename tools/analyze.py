#!/usr/bin/env python
"""Static-analysis gate: AST linter + eval_shape contract verifier.

    python tools/analyze.py src/                 # lint + contracts
    python tools/analyze.py src/ --json out.json # machine-readable
    python tools/analyze.py src/ --rules RPR001,RPR004
    python tools/analyze.py src/ --no-contracts  # AST only (no jax)
    python tools/analyze.py src/ --baseline analysis-baseline.json
    python tools/analyze.py src/ --write-baseline analysis-baseline.json

Exit code 1 when any *active* (unsuppressed, unbaselined) finding
remains — the tier-1 gate in ``tests/test_analysis.py`` runs exactly
this and asserts zero. Rule catalog: ``docs/ANALYSIS.md``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import findings as findings_lib  # noqa: E402
from repro.analysis import linter  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro static analysis (linter + contract verifier)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule ids to run (RPR001..005 "
                         "lint, RPR101..105 contracts); default: all")
    ap.add_argument("--json", dest="json_path", default="",
                    help="write the findings document to this path "
                         "('-' = stdout)")
    ap.add_argument("--baseline", default="",
                    help="baseline JSON of accepted fingerprints; "
                         "matching findings don't fail the gate")
    ap.add_argument("--write-baseline", default="",
                    help="record current active findings as the baseline "
                         "and exit 0")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the eval_shape contract verifier "
                         "(pure-AST run, never imports jax)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-finding text output")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    select = {r.strip().upper() for r in args.rules.split(",")
              if r.strip()} or None
    paths = args.paths or ["src"]

    lint_rules = None if select is None else \
        {r for r in select if r in linter.RULES}
    contract_rules = None if select is None else \
        {r for r in select if r.startswith("RPR1")}
    run_lint = select is None or bool(lint_rules)
    run_contracts = not args.no_contracts and (
        select is None or bool(contract_rules))

    findings = []
    if run_lint:
        findings.extend(linter.lint_paths(paths, select=lint_rules))
    if run_contracts:
        from repro.analysis import contracts
        findings.extend(contracts.verify_all(select=contract_rules,
                                             root=os.getcwd()))

    if args.baseline:
        findings_lib.apply_baseline(
            findings, findings_lib.load_baseline(args.baseline))
    if args.write_baseline:
        findings_lib.write_baseline(args.write_baseline, findings)
        print(f"wrote baseline with "
              f"{sum(f.active for f in findings)} fingerprint(s) to "
              f"{args.write_baseline}")
        return 0

    wall = time.perf_counter() - t0
    doc = findings_lib.to_document(findings, wall_s=wall)
    if args.json_path == "-":
        json.dump(doc, sys.stdout, indent=1)
        sys.stdout.write("\n")
    elif args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump(doc, fh, indent=1)
            fh.write("\n")

    active = [f for f in findings if f.active]
    if not args.quiet:
        for f in findings:
            if f.active:
                print(f.format())
        counts = doc["counts"]
        print(f"analyze: {counts['active']} finding(s) "
              f"({counts['suppressed']} suppressed, "
              f"{counts['baselined']} baselined) in {wall:.2f}s")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
