"""The paper's own models (Tables 4-6): forward shapes, gradient steps,
and learnability on synthetic data for all three."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import (FASHION_CNN, MINI_RESNET, MNIST_CNN,
                                        PAPER_CONFIGS)
from repro.data.synthetic import make_image_dataset
from repro.models import cnn


@pytest.mark.parametrize("cfg", [MNIST_CNN, FASHION_CNN, MINI_RESNET],
                         ids=lambda c: c.name)
def test_forward_shapes_and_grad(cfg, key):
    params = cnn.init_params(cfg, key)
    x = jax.random.normal(key, (4, cfg.image_hw, cfg.image_hw,
                                cfg.in_channels))
    y = jnp.asarray([0, 1, 2, 3])
    logits = cnn.forward(params, cfg, x)
    assert logits.shape == (4, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    grads = jax.grad(cnn.loss_fn)(params, cfg, x, y)
    norms = [float(jnp.linalg.norm(g.reshape(-1)))
             for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(norms)) and sum(norms) > 0


@pytest.mark.parametrize("cfg,hw,ch", [(MNIST_CNN, 16, 1),
                                       (MINI_RESNET, 16, 3)],
                         ids=["mnist-cnn", "mini-resnet"])
def test_learns_synthetic_data(cfg, hw, ch, key):
    import dataclasses
    cfg = dataclasses.replace(cfg, image_hw=hw, in_channels=ch)
    tx, ty, ex, ey = make_image_dataset(3, n_train=800, n_test=200, hw=hw,
                                        channels=ch)
    params = cnn.init_params(cfg, key)
    tx, ty = jnp.asarray(tx), jnp.asarray(ty)

    @jax.jit
    def step(p, k):
        idx = jax.random.randint(k, (64,), 0, tx.shape[0])
        loss, g = jax.value_and_grad(cnn.loss_fn)(p, cfg, tx[idx], ty[idx])
        p = jax.tree_util.tree_map(lambda w, gg: w - 0.1 * gg, p, g)
        return p, loss

    k = key
    for _ in range(60):
        k, sub = jax.random.split(k)
        params, loss = step(params, sub)
    acc = float(cnn.accuracy(params, cfg, jnp.asarray(ex), jnp.asarray(ey)))
    assert acc > 0.5, acc


def test_registry_has_paper_models():
    assert set(PAPER_CONFIGS) == {"paper-mnist-cnn", "paper-fashion-cnn",
                                  "paper-mini-resnet"}
    for cfg in PAPER_CONFIGS.values():
        assert cfg.source
