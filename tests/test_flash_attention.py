"""Flash prefill attention kernel: shape/dtype/window sweeps vs the
pure-jnp chunked-attention oracle (which is itself validated against the
decode path and dense softmax elsewhere)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models.attention import chunked_attention


@pytest.mark.parametrize(
    "B,S,KV,G,hd,causal,win",
    [(1, 300, 2, 2, 64, True, 0),      # unaligned S
     (2, 512, 1, 4, 128, True, 0),     # MQA-ish
     (1, 400, 2, 1, 64, True, 128),    # sliding window
     (1, 256, 2, 2, 64, False, 0),     # bidirectional (encoder)
     (1, 130, 1, 3, 32, True, 0)])     # tiny odd shapes
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_oracle(B, S, KV, G, hd, causal, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              block_q=128, block_k=128)
    ref = chunked_attention(q, k, v, causal=causal, window=win, kv_chunk=96)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref, dtype=np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 320, 2, 2, 64))
    k = jax.random.normal(ks[1], (1, 320, 2, 64))
    v = jax.random.normal(ks[2], (1, 320, 2, 64))
    outs = [np.asarray(ops.flash_attention(q, k, v, block_q=bq, block_k=bk))
            for bq, bk in ((64, 64), (128, 64), (320, 320))]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_dense():
    """Oracle sanity: chunked jnp attention == dense softmax attention."""
    B, S, KV, G, hd = 1, 96, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = chunked_attention(q, k, v, causal=True, kv_chunk=32)
    s = jnp.einsum("bskgh,btkh->bkgst", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    dense = jnp.einsum("bkgst,btkh->bskgh", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
