"""Scenario API: lossless serialization round-trips, dotted-path
overrides (unknown keys raise, every config field reachable — including
via the CLI ``--set`` surface), consolidated resolve() validation, the
named Fleet struct, and the _area_labels remainder fix."""
import dataclasses
import json
import typing

import pytest

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.scenario import (ExperimentConfig, Scenario, _area_labels,
                               valid_override_paths)
from repro.mobility import registry as mob_registry
from repro.policies import registry as policy_registry


# ---------------------------------------------------------------------------
# serialization round trips
# ---------------------------------------------------------------------------

def test_roundtrip_default():
    s = Scenario()
    assert Scenario.from_json(s.to_json()) == s
    assert Scenario.from_dict(s.to_dict()) == s


@pytest.mark.parametrize("mobility", mob_registry.available())
@pytest.mark.parametrize("policy", policy_registry.available())
def test_roundtrip_every_mobility_policy_combo(mobility, policy):
    """Acceptance: lossless JSON round trip for every registered
    mobility model × cache policy combination."""
    s = Scenario(name=f"{mobility}-{policy}").with_overrides({
        "mobility.model": mobility,
        "dfl.policy": policy,
        "mobility.levy_alpha": 1.25,
        "mobility.trace_path": "/tmp/t.npz",
        "dfl.policy_params": (("gamma", 0.9),),
        "distribution": "grouped",
        "engine": "legacy",
    })
    s2 = Scenario.from_json(s.to_json())
    assert s2 == s
    assert s2.content_hash() == s.content_hash()


def test_roundtrip_nonfinite_floats():
    s = Scenario().with_overrides({"dfl.transfer_budget": float("inf")})
    j = s.to_json()
    json.loads(j)                        # strict JSON, no Infinity literal
    assert "Infinity" not in j
    s2 = Scenario.from_json(j)
    assert s2.experiment.dfl.transfer_budget == float("inf")
    assert s2 == s


def test_from_dict_unknown_key_raises_naming_fields():
    with pytest.raises(ValueError, match="experiment"):
        Scenario.from_dict({"bogus": 1})
    with pytest.raises(ValueError, match="cache_size"):
        Scenario.from_dict({"experiment": {"dfl": {"cach_size": 3}}})


def test_content_hash_changes_with_config():
    a = Scenario()
    b = a.with_overrides({"dfl.cache_size": 7})
    assert a.content_hash() != b.content_hash()
    assert a.content_hash() == Scenario().content_hash()


def test_content_hash_ignores_presentation_fields():
    """The provenance hash covers what the run computes — a named
    preset, a verbose CLI run and an anonymous spec of the same
    experiment hash identically."""
    a = Scenario()
    assert a.content_hash() == Scenario(name="x", verbose=True,
                                        record_cache_stats=True
                                        ).content_hash()
    assert a.content_hash() != Scenario(engine="legacy").content_hash()


def test_coercion_errors_name_the_path():
    with pytest.raises(ValueError, match="epochs"):
        Scenario().with_overrides({"epochs": "abc"})
    with pytest.raises(ValueError, match="dfl.lr"):
        Scenario().with_overrides({"dfl.lr": "1..2"})


# ---------------------------------------------------------------------------
# dotted-path overrides
# ---------------------------------------------------------------------------

def test_with_overrides_nested_and_toplevel():
    s = Scenario().with_overrides({
        "dfl.policy": "mobility_aware",
        "mobility.levy_alpha": 1.2,
        "epochs": 7,
        "engine": "legacy",
        "experiment.dfl.cache_size": 4,
    })
    assert s.experiment.dfl.policy == "mobility_aware"
    assert s.experiment.mobility.levy_alpha == 1.2
    assert s.experiment.epochs == 7
    assert s.engine == "legacy"
    assert s.experiment.dfl.cache_size == 4


def test_with_overrides_whole_subconfig():
    mob = MobilityConfig(model="community", community_radius=99.0)
    s = Scenario().with_overrides({"mobility": mob, "dfl.cache_size": 3})
    assert s.experiment.mobility == mob
    assert s.experiment.dfl.cache_size == 3


def test_with_overrides_unknown_key_raises_naming_valid():
    with pytest.raises(ValueError, match="dfl.cache_size"):
        Scenario().with_overrides({"dfl.nope": 1})
    with pytest.raises(ValueError, match="valid paths"):
        Scenario().with_overrides({"totally_bogus": 1})
    with pytest.raises(ValueError, match="valid paths"):
        Scenario().with_overrides({"epochs.nested": 1})


def test_with_overrides_does_not_mutate_base():
    base = Scenario()
    base.with_overrides({"dfl.cache_size": 99, "epochs": 1})
    assert base.experiment.dfl.cache_size == DFLConfig().cache_size
    assert base.experiment.epochs == ExperimentConfig().epochs


def _string_value(hint, default):
    """A non-default CLI-style string for a field of type ``hint``."""
    if hint is bool:
        return "false" if default else "true", (not default)
    if hint is int:
        return str(default + 1), default + 1
    if hint is float:
        new = 2.5 if default in (float("inf"), 0.0) else default + 0.5
        return repr(new), new
    if hint is str:
        return default + "x", default + "x"
    return None


@pytest.mark.parametrize("group,cls", [("dfl", DFLConfig),
                                       ("mobility", MobilityConfig)])
def test_every_config_field_reachable_via_string_override(group, cls):
    """Satellite: no unreachable knobs — every DFLConfig/MobilityConfig
    field accepts a string value, as the CLI --set flag supplies it."""
    hints = typing.get_type_hints(cls)
    for f in dataclasses.fields(cls):
        path = f"{group}.{f.name}"
        if f.name == "policy_params":
            s = Scenario().with_overrides({path: "gamma=0.9,w_ts=2"})
            assert getattr(s.experiment, group).policy_params == (
                ("gamma", 0.9), ("w_ts", 2.0))
            continue
        default = getattr(cls(), f.name)
        sval, expect = _string_value(hints[f.name], default)
        s = Scenario().with_overrides({path: sval})
        assert getattr(getattr(s.experiment, group), f.name) == expect, path


def test_valid_override_paths_cover_all_fields():
    paths = set(valid_override_paths())
    for f in dataclasses.fields(DFLConfig):
        assert f"dfl.{f.name}" in paths
    for f in dataclasses.fields(MobilityConfig):
        assert f"mobility.{f.name}" in paths
    for f in dataclasses.fields(ExperimentConfig):
        assert f.name in paths
    assert "engine" in paths


def test_coercion_rejects_garbage():
    with pytest.raises(ValueError, match="int"):
        Scenario().with_overrides({"epochs": "many"})
    with pytest.raises(ValueError, match="bool"):
        Scenario().with_overrides({"lr_plateau": "maybe"})
    with pytest.raises(ValueError, match="NAME=VALUE"):
        Scenario().with_overrides({"dfl.policy_params": "garbage"})


# ---------------------------------------------------------------------------
# CLI surface (--set / generated flags / presets)
# ---------------------------------------------------------------------------

def _cli_scenario(argv):
    from repro.launch.train import build_parser, scenario_from_args
    ap, dest_to_path = build_parser()
    return scenario_from_args(ap.parse_args(argv), dest_to_path)


def test_cli_set_reaches_every_dfl_and_mobility_field():
    """Satellite: the CLI exposes the full config surface — no more
    unreachable knobs like levy_alpha or max_partners."""
    hints = {**{f"dfl.{f.name}": typing.get_type_hints(DFLConfig)[f.name]
                for f in dataclasses.fields(DFLConfig)},
             **{f"mobility.{f.name}":
                typing.get_type_hints(MobilityConfig)[f.name]
                for f in dataclasses.fields(MobilityConfig)}}
    argv, expects = [], {}
    for path, hint in hints.items():
        if path.endswith("policy_params"):
            continue
        group, leaf = path.split(".")
        default = getattr({"dfl": DFLConfig(), "mobility":
                           MobilityConfig()}[group], leaf)
        sval, expect = _string_value(hint, default)
        argv += ["--set", f"{path}={sval}"]
        expects[path] = expect
    s = _cli_scenario(argv)
    for path, expect in expects.items():
        group, leaf = path.split(".")
        assert getattr(getattr(s.experiment, group), leaf) == expect, path


def test_cli_generated_flags_and_aliases():
    s = _cli_scenario(["--mobility-levy-alpha", "1.75",
                       "--agents", "9", "--dfl-cache-size", "4",
                       "--max-partners", "2", "--policy", "fifo"])
    assert s.experiment.mobility.levy_alpha == 1.75
    assert s.experiment.dfl.num_agents == 9
    assert s.experiment.dfl.cache_size == 4
    assert s.experiment.max_partners == 2
    assert s.experiment.dfl.policy == "fifo"


def test_cli_defaults_match_historical_launcher():
    s = _cli_scenario([])
    assert s.experiment.dfl.num_agents == 20
    assert s.experiment.epochs == 30


def test_cli_preset_and_scenario_file(tmp_path):
    s = _cli_scenario(["--preset", "grouped-overlap", "--set", "epochs=3"])
    assert s.experiment.distribution == "grouped"
    assert s.experiment.dfl.policy == "group"
    assert s.experiment.epochs == 3
    spec = tmp_path / "spec.json"
    spec.write_text(api.get_preset("budget-limited").to_json())
    s2 = _cli_scenario(["--scenario", str(spec), "--agents", "7"])
    assert s2.experiment.dfl.transfer_budget == 2.0
    assert s2.experiment.dfl.num_agents == 7


# ---------------------------------------------------------------------------
# resolve(): consolidated validation
# ---------------------------------------------------------------------------

def test_resolve_rejects_bad_enums():
    with pytest.raises(ValueError, match="algorithm"):
        Scenario().with_overrides({"algorithm": "sgd"}).resolve()
    with pytest.raises(ValueError, match="distribution"):
        Scenario().with_overrides({"distribution": "uniform"}).resolve()
    with pytest.raises(ValueError, match="engines"):
        Scenario(engine="warp").resolve()
    with pytest.raises(ValueError, match="registered models"):
        Scenario().with_overrides({"model": "resnet-152"}).resolve()
    with pytest.raises(KeyError, match="mobility model"):
        Scenario().with_overrides({"mobility.model": "teleport"}).resolve()


def test_resolve_rejects_budget_on_noncached():
    bad = Scenario().with_overrides({"algorithm": "dfl",
                                     "dfl.transfer_budget": 2.0})
    with pytest.raises(ValueError, match="transfer_budget"):
        bad.resolve()


def test_resolve_rejects_group_policy_without_groups():
    bad = Scenario().with_overrides({"dfl.policy": "group",
                                     "distribution": "noniid"})
    with pytest.raises(ValueError, match="grouped"):
        bad.resolve()


def test_resolve_threads_num_bands():
    s = Scenario().with_overrides({"distribution": "grouped",
                                   "num_groups": 5,
                                   "dfl.cache_size": 10})
    rs = s.resolve()
    assert rs.mobility.num_bands == 5
    assert s.experiment.mobility.num_bands == 3     # spec untouched


def test_resolve_applies_image_hw():
    rs = Scenario().with_overrides({"image_hw": 12}).resolve()
    assert rs.model_cfg.image_hw == 12


# ---------------------------------------------------------------------------
# Fleet struct
# ---------------------------------------------------------------------------

def test_fleet_named_fields_and_tuple_unpack():
    s = Scenario().with_overrides({
        "dfl.num_agents": 5, "dfl.cache_size": 2, "n_train": 200,
        "n_test": 40, "image_hw": 8})
    fleet = s.resolve().build_fleet()
    (model_cfg, state, data, counts, test_batch, mstate,
     group_slots, mob_model, mob_cfg) = fleet          # legacy 9-tuple
    assert fleet.model_cfg is model_cfg
    assert fleet.mobility is mob_cfg
    assert fleet.group_slots is None
    assert fleet.num_agents == 5
    assert data["images"].shape[0] == 5
    assert callable(fleet.loss_fn()) and callable(fleet.acc_fn())


# ---------------------------------------------------------------------------
# _area_labels remainder fix (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_groups", [1, 2, 3, 4, 5, 6, 7, 10])
def test_area_labels_cover_every_class(num_groups):
    """4 groups × 10 classes used to drop classes 8 and 9 entirely."""
    labels = _area_labels(num_groups, overlap=0)
    assert len(labels) == num_groups
    covered = set().union(*[set(l) for l in labels])
    assert covered == set(range(10)), labels
    if num_groups <= 10:
        assert all(l for l in labels)              # no empty group


def test_area_labels_overlap_borrows_neighbors():
    labels = _area_labels(4, overlap=1)
    covered = set().union(*[set(l) for l in labels])
    assert covered == set(range(10))
    # each later group borrows its left neighbor's first class
    assert 2 in labels[1]                           # group1 starts at 3


def test_area_labels_paper_default_unchanged():
    assert _area_labels(3, overlap=0) == [[0, 1, 2, 3], [4, 5, 6],
                                          [7, 8, 9]]
