"""Roofline machinery unit tests: HLO collective parsing, extrapolation,
staleness-decayed aggregation weights."""
import jax.numpy as jnp
import numpy as np

from repro.core import cache as C
from repro.core.aggregate import aggregate, aggregation_weights
from repro.launch.roofline import (RooflineTerms, collective_bytes,
                                   extrapolate, model_flops, _tensor_bytes)
from repro.configs import registry as R
from repro.configs.base import get_shape


HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[8,8]{1,0} all-reduce(%y), channel_id=1
  %tuple = (f32[4,4]{1,0}, f32[2]{0}) all-reduce(%a, %b), channel_id=2
  %cp = u32[128]{0} collective-permute(%z), source_target_pairs=...
  %noise = f32[99]{0} add(%p, %q)
  %a2a = bf16[32,32]{1,0} all-to-all(%w), dimensions={0}
"""


def test_tensor_bytes():
    assert _tensor_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert _tensor_bytes("(f32[4,4], f32[2])") == 16 * 4 + 8


def test_collective_bytes_parses_ops():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 2 * (8 * 8 * 4 + 16 * 4 + 2 * 4)  # 2x rule
    assert out["collective-permute"] == 128 * 4
    assert out["all-to-all"] == 32 * 32 * 2


def test_extrapolation_linear():
    b2 = {"flops": 100.0, "bytes": 10.0}
    b3 = {"flops": 150.0, "bytes": 14.0}
    out = extrapolate(b2, b3, 10)
    assert out["flops"] == 100 + 8 * 50
    assert out["bytes"] == 10 + 8 * 4


def test_bottleneck_classification():
    t = RooflineTerms(arch="a", shape="s", mesh="m", chips=256,
                      hlo_flops=197e12, hlo_bytes=819e9 * 2,
                      coll_bytes=50e9 * 0.5, coll_breakdown={},
                      model_flops=197e12 * 256 * 0.5,
                      bytes_per_device=1.0)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert abs(t.collective_s - 0.5) < 1e-9
    assert t.bottleneck == "memory"
    assert abs(t.useful_flops_ratio - 0.5) < 1e-9


def test_model_flops_kinds():
    cfg = R.get_config("internlm2-1.8b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    assert tr == 6.0 * n * 4096 * 256
    assert pf == 2.0 * n * 32768 * 32
    assert dc == 2.0 * n * 128


def test_moe_active_flops_smaller():
    moe = R.get_config("mixtral-8x7b")
    assert moe.active_param_count() < 0.4 * moe.param_count()


def test_staleness_decay_weights():
    ages = jnp.asarray([0, 2, 4], jnp.int32)
    w_self, w = aggregation_weights(
        1.0, jnp.ones((3,)), jnp.ones((3,)), ages=ages, staleness_decay=0.5)
    # raw: self 1, cache [1, .25, .0625] -> normalized ratios preserved
    np.testing.assert_allclose(float(w[0] / w[1]), 4.0, rtol=1e-5)
    np.testing.assert_allclose(float(w[0] / w[2]), 16.0, rtol=1e-5)
    # γ=1 recovers the paper's flat weights
    _, w_flat = aggregation_weights(
        1.0, jnp.ones((3,)), jnp.ones((3,)), ages=ages, staleness_decay=1.0)
    assert np.allclose(np.asarray(w_flat), w_flat[0])


def test_aggregate_with_decay_prefers_fresh():
    params = {"w": jnp.zeros((2,))}
    cache = C.init_cache(params, 2)
    cache = C.insert(cache, {"w": jnp.full((2,), 10.0)}, t=0, origin=1,
                     samples=1.0, group=0, tau_max=100)
    cache = C.insert(cache, {"w": jnp.full((2,), 20.0)}, t=9, origin=2,
                     samples=1.0, group=0, tau_max=100)
    flat = aggregate(params, 1.0, cache, t=10, include_self=False)
    decayed = aggregate(params, 1.0, cache, t=10, staleness_decay=0.5,
                        include_self=False)
    # flat: (10+20)/2 = 15; decayed leans toward the fresh model (20)
    np.testing.assert_allclose(float(flat["w"][0]), 15.0, rtol=1e-5)
    assert float(decayed["w"][0]) > 19.0
