"""Protocol conformance for every registered mobility model.

Any model reachable through the registry must satisfy the contract the
fleet loop assumes: symmetric bool contact matrix with a False diagonal,
jit-able simulate_epoch, determinism under a fixed seed, finite
positions, and band restriction (where the model supports bands).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MobilityConfig
from repro.mobility import registry
from repro.mobility import trace as trace_lib
from repro.mobility.base import make_bands, partners_from_contacts

N = 12


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    rng = np.random.default_rng(3)
    seq = rng.random((40, N, N)) < 0.2
    path = tmp_path_factory.mktemp("traces") / "t.npz"
    trace_lib.save_trace(str(path), seq)
    return str(path)


def small_cfg(name: str, trace_path: str) -> MobilityConfig:
    return MobilityConfig(model=name, grid_w=5, grid_h=6,
                          area_w=500.0, area_h=600.0,
                          levy_max_flight=500.0, community_radius=100.0,
                          trace_path=trace_path if name == "trace" else "",
                          trace_frames_per_epoch=10)


def all_models():
    return registry.available()


@pytest.mark.parametrize("name", all_models())
def test_epoch_contract(name, trace_path):
    cfg = small_cfg(name, trace_path)
    model = registry.get_model(name)
    state = model.init(jax.random.PRNGKey(0), N, cfg)
    sim = jax.jit(lambda s, k: model.simulate_epoch(s, k, cfg, 30.0))
    state2, met, dur = sim(state, jax.random.PRNGKey(1))
    met = np.asarray(met)
    assert met.shape == (N, N) and met.dtype == bool
    assert (met == met.T).all()
    assert not met.diagonal().any()
    dur = np.asarray(dur)
    assert dur.shape == (N, N) and dur.dtype == np.int32
    assert (dur == dur.T).all() and not dur.diagonal().any()
    assert ((dur > 0) == met).all()          # in contact somewhere <=> dur>0
    assert dur.max() <= 30                   # bounded by steps in the epoch
    pos = np.asarray(model.positions(state2, cfg))
    assert pos.shape == (N, 2) and np.isfinite(pos).all()


@pytest.mark.parametrize("name", all_models())
def test_epoch_deterministic(name, trace_path):
    cfg = small_cfg(name, trace_path)
    model = registry.get_model(name)
    out = []
    for _ in range(2):
        state = model.init(jax.random.PRNGKey(4), N, cfg)
        _, met, dur = model.simulate_epoch(state, jax.random.PRNGKey(5), cfg,
                                           20.0)
        out.append((np.asarray(met), np.asarray(dur)))
    assert (out[0][0] == out[1][0]).all()
    assert (out[0][1] == out[1][1]).all()


@pytest.mark.parametrize("name", all_models())
def test_step_keeps_contacts_well_formed(name, trace_path):
    cfg = small_cfg(name, trace_path)
    model = registry.get_model(name)
    state = model.init(jax.random.PRNGKey(6), N, cfg)
    key = jax.random.PRNGKey(7)
    for _ in range(5):
        key, k = jax.random.split(key)
        state = model.step(state, k, cfg)
    met = np.asarray(model.contacts_now(state, cfg))
    assert (met == met.T).all() and not met.diagonal().any()


@pytest.mark.parametrize("name", ["random_waypoint", "levy_walk"])
def test_plane_band_restriction(name, trace_path):
    """Banded agents stay inside their horizontal slice of the area."""
    cfg = dataclasses.replace(small_cfg(name, trace_path), num_bands=2)
    model = registry.get_model(name)
    band, _ = make_bands(N, 2, free_per_band=1)
    state = model.init(jax.random.PRNGKey(8), N, cfg, band=jnp.asarray(band))
    key = jax.random.PRNGKey(9)
    for _ in range(60):
        key, k = jax.random.split(key)
        state = model.step(state, k, cfg)
    y = np.asarray(model.positions(state, cfg))[:, 1]
    h = cfg.area_h / 2
    for i, b in enumerate(np.asarray(band)):
        if b >= 0:
            assert b * h - 1e-3 <= y[i] <= (b + 1) * h + 1e-3, (i, b, y[i])


def test_manhattan_band_count_threads_through():
    """≠3 groups restrict correctly now that num_bands is threaded."""
    cfg = MobilityConfig(grid_w=4, grid_h=10, num_bands=5)
    model = registry.get_model("manhattan")
    band = jnp.arange(N, dtype=jnp.int32) % 5
    state = model.init(jax.random.PRNGKey(10), N, cfg, band=band)
    key = jax.random.PRNGKey(11)
    for _ in range(80):
        key, k = jax.random.split(key)
        state = model.step(state, k, cfg)
    y = np.asarray(state.node[:, 1])
    h = cfg.grid_h // 5
    for i, b in enumerate(np.asarray(band)):
        assert b * h <= y[i] <= (b + 1) * h + 1, (i, b, y[i])


def test_trace_replay_matches_schedule(trace_path):
    seq, _ = trace_lib.load_trace(trace_path)
    cfg = MobilityConfig(model="trace", trace_path=trace_path,
                         trace_frames_per_epoch=10)
    model = registry.get_model("trace")
    state = model.init(jax.random.PRNGKey(0), N, cfg)
    _, met1, dur1 = model.simulate_epoch(state, None, cfg, 0.0)
    sym = seq[:10] | seq[:10].transpose(0, 2, 1)
    sym = sym & ~np.eye(N, dtype=bool)[None]
    expect = sym.any(0)
    assert (np.asarray(met1) == expect).all()
    # duration = frames-in-contact, straight off the schedule
    assert (np.asarray(dur1) == sym.sum(0)).all()


def test_trace_edge_list_rejects_bad_indices():
    with pytest.raises(ValueError):
        trace_lib.contacts_from_edges(np.array([-1]), np.array([0]),
                                      np.array([1]), 5, 4)
    with pytest.raises(ValueError):
        trace_lib.contacts_from_edges(np.array([5]), np.array([0]),
                                      np.array([1]), 5, 4)


def test_trace_agent_mismatch_raises(trace_path):
    cfg = MobilityConfig(model="trace", trace_path=trace_path)
    with pytest.raises(ValueError):
        registry.get_model("trace").init(jax.random.PRNGKey(0), N + 1, cfg)


def test_partners_random_sampling_fair():
    """Random sampling must only return true contacts and vary selection."""
    met = jnp.ones((8, 8), bool) & ~jnp.eye(8, dtype=bool)
    seen = set()
    for s in range(10):
        p = np.asarray(partners_from_contacts(
            met, 2, sample="random", key=jax.random.PRNGKey(s)))
        assert (p >= 0).all()           # fully connected: no padding
        assert (p != np.arange(8)[:, None]).all()
        seen.add(tuple(p[0]))
    assert len(seen) > 1                # lowest-id would always pick (1, 2)


def test_partners_random_requires_key():
    met = jnp.zeros((3, 3), bool)
    with pytest.raises(ValueError):
        partners_from_contacts(met, 2, sample="random")
