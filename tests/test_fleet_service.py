"""Streaming scenario service: spec parsing, wave batching over shared
engines, bounded retry + malformed-spec survival, and the result-stream
JSONL schema."""
import io
import json

import pytest

from repro import api
from repro.serve import service as service_lib
from repro.telemetry import events as events_lib

SMOKE = {
    "epochs": 2, "n_train": 300, "n_test": 60, "image_hw": 8,
    "lr_plateau": False, "early_stop_patience": 100,
    "dfl.num_agents": 6, "dfl.cache_size": 3, "dfl.local_steps": 2,
    "dfl.batch_size": 16, "dfl.epoch_seconds": 10.0,
}


class _FakeResult:
    def to_dict(self):
        return {"config_hash": "deadbeef", "best_acc": 0.9,
                "final_acc": 0.8, "traces": 1, "wall_s": 0.01,
                "metrics": {"epoch": [1], "acc": [0.8]}}


class _FakeEngine:
    traces = 1


def _fake_run_fn(log=None):
    def run_fn(scenario, engines):
        engines.setdefault(api.engine_cache_key(scenario), _FakeEngine())
        if log is not None:
            log.append(scenario)
        return _FakeResult()
    return run_fn


def _service(**kw):
    out = io.StringIO()
    kw.setdefault("run_fn", _fake_run_fn())
    return service_lib.ScenarioService(out=out, **kw), out


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------

def test_parse_spec_shapes():
    preset = api.get_preset("paper-noniid")
    # bare Scenario dict
    assert service_lib.parse_spec(preset.to_dict()) == preset
    # preset wrapper with overrides
    s = service_lib.parse_spec({"preset": "paper-noniid",
                                "overrides": {"epochs": 7}})
    assert s.experiment.epochs == 7
    # nested scenario wrapper
    s = service_lib.parse_spec({"scenario": preset.to_dict(),
                                "overrides": {"dfl.lr": 0.05}})
    assert s.experiment.dfl.lr == 0.05
    with pytest.raises(ValueError, match="spec needs"):
        service_lib.parse_spec({"nonsense": 1})


# ---------------------------------------------------------------------------
# queue behavior (injected run_fn — no real training)
# ---------------------------------------------------------------------------

def test_same_key_specs_batch_into_one_wave_one_engine():
    svc, out = _service(max_wave=8)
    for rid in ("a", "b", "c"):
        # lr and epochs are traced knobs: all three share one engine key
        svc.submit({"rid": rid, "preset": "paper-noniid",
                    "overrides": {"dfl.lr": 0.1 if rid == "a" else 0.05,
                                  "epochs": 3}})
    summary = svc.drain()
    assert summary["runs_ok"] == 3 and summary["runs_failed"] == 0
    assert summary["waves"] == 1
    assert summary["num_engines"] == 1 and summary["retraces"] == 0
    waves = [r["wave"] for r in svc.results if r["kind"] == "result"]
    assert waves == [0, 0, 0]


def test_distinct_keys_split_waves_and_engines():
    svc, out = _service()
    svc.submit({"rid": "a", "preset": "paper-noniid"})
    # cache_size changes the trace shape -> a different engine key
    svc.submit({"rid": "b", "preset": "paper-noniid",
                "overrides": {"dfl.cache_size": 5}})
    svc.submit({"rid": "c", "preset": "paper-noniid"})
    summary = svc.drain()
    assert summary["runs_ok"] == 3
    assert summary["num_engines"] == 2
    rows = {r["rid"]: r["wave"] for r in svc.results
            if r["kind"] == "result"}
    # a and c share the first wave despite b queued between them
    assert rows["a"] == rows["c"] != rows["b"]


def test_max_wave_splits_but_reuses_engine():
    svc, out = _service(max_wave=2)
    for i in range(5):
        svc.submit({"rid": f"r{i}", "preset": "paper-noniid"})
    summary = svc.drain()
    assert summary["waves"] == 3
    assert summary["num_engines"] == 1 and summary["retraces"] == 0


def test_malformed_specs_surface_errors_and_queue_drains():
    svc, out = _service()
    svc.submit_lines([
        json.dumps({"rid": "good", "preset": "paper-noniid"}),
        "this is not json",
        json.dumps({"rid": "bad-preset", "preset": "no-such-preset"}),
        json.dumps({"rid": "bad-override", "preset": "paper-noniid",
                    "overrides": {"dfl.churn_fraction": 2.0}}),
        json.dumps({"rid": "good2", "preset": "paper-noniid"}),
    ])
    summary = svc.drain()
    assert summary["runs_ok"] == 2 and summary["runs_failed"] == 3
    rows = {r["rid"]: r for r in svc.results if r["kind"] == "result"}
    assert rows["bad-preset"]["status"] == "error"
    assert "no-such-preset" in rows["bad-preset"]["error"]
    assert rows["bad-override"]["status"] == "error"
    assert rows["good2"]["status"] == "ok"
    # the service event stream stays schema-valid: one session hash,
    # run_failed events carry rid + error
    assert events_lib.validate_events(svc.events.to_dicts()) == []
    failed = [e for e in svc.events.to_dicts() if e["kind"] == "run_failed"]
    assert {e["data"]["rid"] for e in failed} >= {"bad-preset",
                                                  "bad-override"}


def test_bounded_retry_then_success_and_exhaustion():
    attempts = {}

    def run_fn(scenario, engines):
        # epochs is a traced knob: distinguishes the runs without
        # splitting their engine key
        k = scenario.experiment.epochs
        attempts[k] = attempts.get(k, 0) + 1
        if k == 12 or attempts[k] == 1:
            raise RuntimeError(f"run {k} blew up")
        return _FakeResult()

    svc, out = _service(run_fn=run_fn, retries=1)
    svc.submit({"rid": "f", "preset": "paper-noniid",
                "overrides": {"epochs": 11}})    # fails once, then ok
    svc.submit({"rid": "b", "preset": "paper-noniid",
                "overrides": {"epochs": 12}})    # fails every attempt
    summary = svc.drain()
    rows = {r["rid"]: r for r in svc.results if r["kind"] == "result"}
    assert rows["f"]["status"] == "ok" and rows["f"]["attempts"] == 2
    assert rows["b"]["status"] == "error" and rows["b"]["attempts"] == 2
    assert "blew up" in rows["b"]["error"]
    assert summary["runs_ok"] == 1 and summary["runs_failed"] == 1


def test_jsonl_stream_validates_and_flags_corruption():
    svc, out = _service()
    svc.submit({"rid": "a", "preset": "paper-noniid"})
    svc.submit_lines(["broken line"])
    svc.drain()
    lines = out.getvalue().splitlines()
    assert service_lib.validate_service_jsonl(lines) == []
    # parsed-object form validates too
    assert service_lib.validate_service_jsonl(svc.results) == []
    # corruption is caught: summary counts disagreeing with the stream
    tampered = [json.loads(l) for l in lines]
    tampered[-1]["runs_ok"] = 99
    assert any("disagree" in p
               for p in service_lib.validate_service_jsonl(tampered))
    # missing summary is caught
    assert any("summary" in p
               for p in service_lib.validate_service_jsonl(lines[:-1]))
    # wrong schema tag is caught
    bad = [dict(r, schema="other") for r in tampered]
    assert any("schema" in p for p in service_lib.validate_service_jsonl(bad))


# ---------------------------------------------------------------------------
# real runs through the service (shared compiled engine)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_service_real_runs_share_one_compiled_engine():
    out = io.StringIO()
    svc = service_lib.ScenarioService(out=out)
    svc.submit({"rid": "a", "preset": "paper-noniid",
                "overrides": SMOKE})
    svc.submit({"rid": "b", "preset": "paper-noniid",
                "overrides": {**SMOKE, "dfl.lr": 0.05, "epochs": 3}})
    summary = svc.drain()
    assert summary["runs_ok"] == 2 and summary["runs_failed"] == 0
    # one wave, one live engine, zero retraces: the second spec reused
    # the first spec's compiled executable
    assert summary["waves"] == 1
    assert summary["num_engines"] == 1 and summary["retraces"] == 0
    assert service_lib.validate_service_jsonl(out.getvalue().splitlines()) \
        == []
    rows = {r["rid"]: r for r in svc.results if r["kind"] == "result"}
    assert rows["a"]["result"]["traces"] == 1    # first run compiles
    assert rows["b"]["result"]["traces"] == 0    # second reuses it
    assert len(rows["b"]["result"]["acc"]) == 3
