"""Sharding rules: every produced PartitionSpec must divide its dim for
every assigned architecture (the dry-run's correctness precondition)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import specs as specs_lib
from repro.sharding.rules import ShardingRules, param_specs

AXES = {"model": 16, "data": 16, "pod": 2}


def check_divisible(shapes, specs):
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for x, spec in zip(flat_s, flat_p):
        for dim, axis in zip(x.shape, spec):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for n in names:
                size *= AXES[n]
            assert dim % size == 0, (x.shape, spec)


@pytest.mark.parametrize("arch", R.ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    cfg = R.get_config(arch)
    shapes = specs_lib.param_shapes(cfg)
    rules = ShardingRules(model_size=16, data_size=16, fsdp=fsdp)
    specs = param_specs(cfg, shapes, rules)
    check_divisible(shapes, specs)


@pytest.mark.parametrize("arch", R.ARCH_IDS)
def test_some_params_are_sharded(arch):
    """The rules must actually shard the big tensors (no all-replicated)."""
    cfg = R.get_config(arch)
    shapes = specs_lib.param_shapes(cfg)
    rules = ShardingRules(model_size=16, data_size=16, fsdp=False)
    specs = param_specs(cfg, shapes, rules)
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    sharded_elems = sum(
        int(__import__("numpy").prod(x.shape))
        for x, s in zip(flat_s, flat_p) if any(a is not None for a in s))
    total = sum(int(__import__("numpy").prod(x.shape)) for x in flat_s)
    assert sharded_elems / total > 0.9, (
        f"{arch}: only {sharded_elems/total:.0%} of params sharded")
