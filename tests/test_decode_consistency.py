"""Serving correctness: token-by-token decode must reproduce the full
teacher-forced forward for every family (incl. SWA ring buffers and
enc-dec cross attention), with and without the Pallas kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import encdec as E
from repro.models import registry as M
from repro.models import transformer as T


def f32(arch, **kw):
    return dataclasses.replace(R.get_smoke_config(arch),
                               compute_dtype="float32", **kw)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen2-7b",
                                  "deepseek-67b", "internlm2-20b",
                                  "mamba2-780m", "hymba-1.5b",
                                  "phi-3-vision-4.2b"])
def test_decode_matches_forward(arch, key):
    cfg = f32(arch, moe_capacity_factor=4.0)
    p = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    img = (jax.random.normal(key, (2, cfg.image_tokens, cfg.d_model))
           if cfg.family == "vlm" else None)
    full, _ = T.forward(p, cfg, toks, img)
    lp, state = T.prefill(p, cfg, toks[:, :8], img,
                          max_len=16 + cfg.image_tokens)
    np.testing.assert_allclose(np.asarray(lp[:, : lp.shape[1]]),
                               np.asarray(full[:, : lp.shape[1]]),
                               rtol=2e-3, atol=2e-3)
    outs = []
    for t in range(8, 12):
        lg, state = T.decode_step(p, cfg, state, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -4:]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch,window", [("mixtral-8x7b", 8),
                                         ("hymba-1.5b", 8)])
def test_swa_ring_buffer_decode(arch, window, key):
    cfg = f32(arch, moe_capacity_factor=4.0, sliding_window=window)
    p = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab)
    full, _ = T.forward(p, cfg, toks)
    _, state = T.prefill(p, cfg, toks[:, :16], max_len=32)
    assert state.k is None or state.k.shape[2] == window  # ring alloc
    outs = []
    for t in range(16, 24):
        lg, state = T.decode_step(p, cfg, state, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 16:24]),
                               rtol=2e-2, atol=2e-2)


def test_encdec_decode_matches_teacher_forced(key):
    cfg = f32("whisper-small")
    p = M.init_params(cfg, key)
    frames = jax.random.normal(key, (2, cfg.enc_context, cfg.d_model))
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab)
    enc = E.encode(p, cfg, frames)
    tf_logits = E.decode_train(p, cfg, toks, enc)
    state = E.init_serve_state(p, cfg, enc, 2, 16)
    outs = []
    for t in range(10):
        lg, state = E.decode_step(p, cfg, state, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(tf_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "whisper-small"])
def test_kernel_path_matches_jnp_path(arch, key):
    """decode with the Pallas kernel == decode with the jnp reference."""
    cfg = f32(arch)
    p = M.init_params(cfg, key)
    B = 2
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, cfg.enc_context, cfg.d_model))
        enc = E.encode(p, cfg, frames)
        s0 = E.init_serve_state(p, cfg, enc, B, 8)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
        l_ref, _ = E.decode_step(p, cfg, s0, tok, use_kernel=False)
        l_ker, _ = E.decode_step(p, cfg, s0, tok, use_kernel=True)
    else:
        toks = jax.random.randint(key, (B, 6), 0, cfg.vocab)
        _, s0 = T.prefill(p, cfg, toks, max_len=12)
        tok = toks[:, -1:]
        l_ref, _ = T.decode_step(p, cfg, s0, tok, use_kernel=False)
        l_ker, _ = T.decode_step(p, cfg, s0, tok, use_kernel=True)
    np.testing.assert_allclose(np.asarray(l_ker), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
