"""LocalUpdate: K-step proximal SGD correctness."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_update import local_update, proximal_penalty


def quad_loss(params, batch):
    # f(x) = 0.5 ||x - target||^2 per sample
    return 0.5 * jnp.mean(jnp.sum(
        (params["x"][None, :] - batch["t"]) ** 2, axis=-1))


def test_sgd_moves_toward_target():
    params = {"x": jnp.zeros((3,))}
    data = {"t": jnp.broadcast_to(jnp.asarray([1.0, 2.0, 3.0]), (10, 3))}
    out, losses = local_update(params, data, jnp.asarray(10),
                               jax.random.PRNGKey(0), loss_fn=quad_loss,
                               steps=50, batch_size=4, lr=0.2, rho=0.0)
    np.testing.assert_allclose(np.asarray(out["x"]), [1, 2, 3], atol=1e-3)
    assert float(losses[-1]) < float(losses[0])


def test_proximal_term_anchors():
    """With huge ρ the update cannot move away from the anchor."""
    params = {"x": jnp.zeros((3,))}
    data = {"t": jnp.broadcast_to(jnp.asarray([10.0, 10.0, 10.0]), (8, 3))}
    free, _ = local_update(params, data, jnp.asarray(8),
                           jax.random.PRNGKey(0), loss_fn=quad_loss,
                           steps=20, batch_size=4, lr=0.01, rho=0.0)
    anchored, _ = local_update(params, data, jnp.asarray(8),
                               jax.random.PRNGKey(0), loss_fn=quad_loss,
                               steps=20, batch_size=4, lr=0.01, rho=50.0)
    assert float(jnp.linalg.norm(anchored["x"])) < \
        0.2 * float(jnp.linalg.norm(free["x"]))


def test_proximal_penalty_value():
    a = {"w": jnp.ones((2, 2)), "b": jnp.zeros((3,))}
    b = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((3,))}
    assert float(proximal_penalty(a, b)) == 4.0


def test_count_masks_sampling():
    """Samples must come only from the first `count` rows."""
    params = {"x": jnp.zeros((1,))}
    data = {"t": jnp.concatenate([jnp.ones((5, 1)),
                                  jnp.full((5, 1), 1e6)])}
    out, _ = local_update(params, data, jnp.asarray(5),
                          jax.random.PRNGKey(1), loss_fn=quad_loss,
                          steps=30, batch_size=4, lr=0.3, rho=0.0)
    np.testing.assert_allclose(np.asarray(out["x"]), 1.0, atol=1e-2)
