"""Serving scheduler: wave batching must reproduce per-request greedy
decoding exactly (same tokens as serving each request alone)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import registry as M
from repro.serve.scheduler import BatchScheduler, Request


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(R.get_smoke_config("internlm2-1.8b"),
                              compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(params, cfg, prompt, n_tokens):
    logits, state = M.prefill(params, cfg,
                              {"tokens": jnp.asarray(prompt[None])},
                              max_len=len(prompt) + n_tokens)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    for _ in range(n_tokens - 1):
        logits, state = M.decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


@pytest.mark.slow
def test_wave_matches_single_request(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(3)]
    sched = BatchScheduler(params, cfg, slots=3, max_len=24)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = sched.run()
    assert set(done) == {0, 1, 2}
    for i, p in enumerate(prompts):
        ref = greedy_reference(params, cfg, p, 6)
        assert done[i].tokens_out == ref, (i, done[i].tokens_out, ref)


def test_length_bucketing_multiple_waves(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    sched = BatchScheduler(params, cfg, slots=2, max_len=24)
    reqs = ([Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(
        np.int32), max_new_tokens=4) for i in range(3)]     # len-6 bucket
        + [Request(rid=10, prompt=rng.integers(0, cfg.vocab, 9).astype(
            np.int32), max_new_tokens=4)])                  # len-9 bucket
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert set(done) == {0, 1, 2, 10}
    for r in reqs:
        assert len(done[r.rid].tokens_out) == 4


def test_eos_stops_request(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    ref = greedy_reference(params, cfg, prompt, 8)
    eos = ref[2]  # force an early stop at position 2
    sched = BatchScheduler(params, cfg, slots=1, max_len=24)
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=8,
                         eos_id=eos))
    done = sched.run()
    assert done[0].tokens_out == ref[:3]
