"""Runner API: typed RunResult vs the run_experiment shim, the terminal-
epoch eval-cadence fix, and the compile-aware sweep (traced axes reuse
one fused engine — no retraces)."""
import json

import numpy as np
import pytest

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import ExperimentConfig, run_experiment

TINY = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=10.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=2, eval_every=2, n_train=300, n_test=60, image_hw=8,
    lr_plateau=False,
)


def tiny_scenario(**kw):
    merged = {**TINY, **kw}
    return api.Scenario(experiment=ExperimentConfig(**merged),
                        record_cache_stats=True)


# ---------------------------------------------------------------------------
# RunResult vs the legacy shim
# ---------------------------------------------------------------------------

def test_run_matches_run_experiment_shim():
    scenario = tiny_scenario()
    result = api.run(scenario)
    hist = run_experiment(scenario.experiment, record_cache_stats=True)
    assert result.acc == hist["acc"]
    assert result.epoch == hist["epoch"]
    assert result.cache_num == hist["cache_num"]
    assert result.traces == hist["epoch_traces"] == 1
    assert result.best_acc == hist["best_acc"]
    assert result.final_acc == hist["final_acc"]


def test_run_result_typed_fields_and_json():
    result = api.run(tiny_scenario())
    assert result.engine == "fused"
    assert result.config_hash == tiny_scenario().content_hash()
    assert result.best_epoch in result.epoch
    doc = json.loads(result.to_json())
    assert doc["config_hash"] == result.config_hash
    assert doc["metrics"]["acc"] == result.acc
    # history() is the exact legacy dict shape
    hist = result.history()
    assert set(hist) == {"epoch", "acc", "lr", "cache_num", "cache_age",
                         "epoch_traces", "engine", "best_acc", "final_acc",
                         "wall_s"}


def test_run_legacy_engine():
    result = api.run(tiny_scenario().with_overrides({"engine": "legacy"}))
    assert result.engine == "legacy"
    assert len(result.acc) == 1 and np.isfinite(result.acc).all()


# ---------------------------------------------------------------------------
# eval cadence: the terminal epoch is always evaluated (satellite)
# ---------------------------------------------------------------------------

def test_fused_evaluates_terminal_partial_chunk():
    """epochs not a multiple of eval_every: the tail epochs used to run
    but never land in the history."""
    result = api.run(tiny_scenario(epochs=5, eval_every=2))
    assert result.epoch == [2, 4, 5]
    assert result.final_acc == result.acc[-1]


def test_legacy_evaluates_terminal_partial_chunk():
    result = api.run(tiny_scenario(epochs=3, eval_every=2).with_overrides(
        {"engine": "legacy"}))
    assert result.epoch == [2, 3]


@pytest.mark.slow
def test_fused_and_legacy_history_lengths_pinned():
    """Regression: epochs=10, eval_every=3 — fused == legacy histories,
    both including the terminal epoch."""
    fused = run_experiment(ExperimentConfig(**{**TINY, "epochs": 10,
                                               "eval_every": 3}))
    legacy = run_experiment(ExperimentConfig(**{**TINY, "epochs": 10,
                                                "eval_every": 3}),
                            engine="legacy")
    assert fused["epoch"] == legacy["epoch"] == [3, 6, 9, 10]
    assert len(fused["acc"]) == len(legacy["acc"]) == 4
    np.testing.assert_allclose(fused["acc"], legacy["acc"], atol=2e-3)


# ---------------------------------------------------------------------------
# sweep: compile-aware grids (acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_budget_lr_grid_single_engine_single_trace():
    """Acceptance: sweeping transfer_budget × lr reuses ONE fused engine
    with exactly one trace — the engine's no-retrace guarantee holds
    through the new API."""
    sw = api.sweep(tiny_scenario(),
                   {"dfl.transfer_budget": [0.0, 2.0, float("inf")],
                    "dfl.lr": [0.1, 0.05]})
    assert len(sw.cells) == 6
    assert sw.num_engines == 1
    assert list(sw.engine_traces.values()) == [1]
    assert sw.retraces == 0
    for cell in sw.cells:
        assert np.isfinite(cell.result.acc).all()


def test_sweep_static_axis_splits_engines():
    sw = api.sweep(tiny_scenario(), {"dfl.policy": ["lru", "fifo"],
                                     "dfl.lr": [0.1, 0.05]})
    assert len(sw.cells) == 4
    assert sw.num_engines == 2               # one per trace-static combo
    assert sw.retraces == 0


def test_sweep_adjust_and_select():
    sw = api.sweep(tiny_scenario(), {"dfl.lr": [0.1, 0.05]},
                   adjust=lambda ov: {"seed": 3})
    assert all(c.overrides["seed"] == 3 for c in sw.cells)
    assert all(c.result.scenario.experiment.seed == 3 for c in sw.cells)
    assert len(sw.select(dfl_lr=0.1)) == 1
    # underscore shorthand also works for fields whose names contain '_'
    sw2 = api.sweep(tiny_scenario(),
                    {"dfl.transfer_budget": [0.0, 2.0]})
    assert len(sw2.select(dfl_transfer_budget=2.0)) == 1


def test_sweep_write_bench_schema(tmp_path):
    sw = api.sweep(tiny_scenario(), {"dfl.lr": [0.1]})
    out = tmp_path / "BENCH_test.json"
    doc = sw.write_bench(str(out), name="unit", fast=True,
                         extra={"budget": float("inf")})
    on_disk = json.loads(out.read_text())
    assert on_disk == doc
    assert on_disk["bench"] == "unit"
    assert on_disk["schema"] == "sweep-v1"
    assert on_disk["retraces"] == 0
    assert on_disk["extra"]["budget"] == "inf"    # strict JSON
    cell = on_disk["cells"][0]
    assert {"overrides", "config_hash", "best_acc", "final_acc",
            "traces"} <= set(cell)
