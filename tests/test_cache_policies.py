"""Registry-driven cache-policy subsystem.

Three layers of coverage:
  * bit-exactness — the ported lru/fifo/random/group policies reproduce the
    pre-refactor selection code (kept verbatim in
    ``legacy_policy_reference.py``) bit-for-bit through the fleet exchange;
  * conformance — invariants every registered policy must satisfy
    (capacity, origin dedup keeps the freshest copy, blanked empty slots,
    candidate-permutation invariance for deterministic policies);
  * the new policies' semantics (mobility_aware, staleness_weighted,
    priority) and the policy-aware single-insert path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import gossip
from repro.core import rounds as rounds_lib
from repro.core.cache import CacheMeta
from repro.policies import base as policy_base
from repro.policies import registry as policy_registry

from legacy_policy_reference import legacy_exchange

PORTED = ("lru", "fifo", "random", "group")


def fleet_params(N):
    return {"w": jnp.arange(N, dtype=jnp.float32)[:, None]
            * jnp.ones((N, 4))}


def empty_fleet_cache(N, cap):
    c = cache_lib.init_cache({"w": jnp.zeros((4,))}, cap)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape).copy(), c)


def random_partners(key, N, max_partners=2):
    from repro.mobility.base import partners_from_contacts
    met = jax.random.bernoulli(key, 0.4, (N, N))
    met = met & met.T & ~jnp.eye(N, dtype=bool)
    return partners_from_contacts(met, max_partners)


def exchange_kwargs(pol, N, cap):
    kw = {}
    if pol.needs_group_slots:
        kw["group_slots"] = jnp.asarray([cap - cap // 2, cap // 2],
                                        jnp.int32)
    if pol.needs_rng:
        kw["rng"] = jax.random.PRNGKey(11)
    if pol.needs_encounters:
        kw["encounters"] = jnp.ones((N, N), jnp.float32)
    return kw


# ---------------------------------------------------------------------------
# bit-exactness vs the pre-refactor dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", PORTED)
def test_ported_policy_bitexact_vs_prerefactor(policy):
    """Metadata AND model trajectories must match the pre-refactor code
    bit-for-bit over multi-epoch random contact sequences."""
    N, cap = 6, 3
    params = fleet_params(N)
    samples = jnp.ones((N,)) * 2.0
    group = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    group_slots = jnp.asarray([2, 1], jnp.int32)
    new_cache = empty_fleet_cache(N, cap)
    old_cache = empty_fleet_cache(N, cap)
    key = jax.random.PRNGKey(42)
    for t in range(6):
        key, kc, kr = jax.random.split(key, 3)
        partners = random_partners(kc, N)
        kw = dict(tau_max=4, policy=policy, group_slots=group_slots,
                  rng=kr)
        new_cache = gossip.exchange(params, new_cache, partners, t, samples,
                                    group, **kw)
        old_cache = legacy_exchange(params, old_cache, partners, t, samples,
                                    group, **kw)
        for a, b in zip(jax.tree_util.tree_leaves(new_cache),
                        jax.tree_util.tree_leaves(old_cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_select_shims_match_prerefactor():
    """The legacy ``cache.select_*`` API shims stay bit-exact too."""
    import legacy_policy_reference as legacy
    rng = np.random.default_rng(0)
    M, cap = 9, 4
    origin = jnp.asarray(rng.integers(-1, 6, M), jnp.int32)
    ts = jnp.asarray(rng.integers(0, 10, M), jnp.int32)
    samples = jnp.asarray(rng.random(M), jnp.float32)
    group = jnp.asarray(rng.integers(0, 2, M), jnp.int32)
    arrival = jnp.asarray(rng.integers(0, 10, M), jnp.int32)
    slots = jnp.asarray([2, 2], jnp.int32)
    key = jax.random.PRNGKey(5)
    pairs = [
        (cache_lib.select_lru(origin, ts, samples, group, arrival, cap),
         legacy.select_lru(origin, ts, samples, group, arrival, cap)),
        (cache_lib.select_fifo(origin, ts, samples, group, arrival, cap),
         legacy.select_fifo(origin, ts, samples, group, arrival, cap)),
        (cache_lib.select_random(origin, ts, samples, group, arrival, cap,
                                 key),
         legacy.select_random(origin, ts, samples, group, arrival, cap,
                              key)),
        (cache_lib.select_group(origin, ts, samples, group, arrival, cap,
                                slots),
         legacy.select_group(origin, ts, samples, group, arrival, cap,
                             slots)),
    ]
    for (sel_new, meta_new), (sel_old, meta_old) in pairs:
        np.testing.assert_array_equal(np.asarray(sel_new),
                                      np.asarray(sel_old))
        for k in meta_old:
            np.testing.assert_array_equal(np.asarray(meta_new[k]),
                                          np.asarray(meta_old[k]))


# ---------------------------------------------------------------------------
# conformance suite: invariants shared by every registered policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", policy_registry.available())
def test_policy_conformance_through_exchange(name):
    """Capacity respected, ≤1 entry per origin, empty slots blanked across
    ALL metadata fields — after arbitrary contact sequences."""
    pol = policy_registry.get_policy(name)
    N, cap = 6, 3
    params = fleet_params(N)
    samples = jnp.ones((N,))
    group = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    cache = empty_fleet_cache(N, cap)
    kw = exchange_kwargs(pol, N, cap)
    key = jax.random.PRNGKey(3)
    for t in range(4):
        key, kc = jax.random.split(key)
        partners = random_partners(kc, N)
        cache = gossip.exchange(params, cache, partners, t, samples, group,
                                tau_max=3, policy=pol, **kw)
        valid = np.asarray(cache.valid)
        origin = np.asarray(cache.origin)
        assert valid.sum(axis=1).max() <= cap
        for i in range(N):
            kept = origin[i][valid[i]]
            assert len(set(kept.tolist())) == len(kept)      # origin dedup
            assert ((t - np.asarray(cache.ts)[i][valid[i]]) < 3).all()
        # empty slots: origin == -1 across every metadata field
        empty = ~valid
        assert (np.asarray(cache.ts)[empty] == -1).all()
        assert (np.asarray(cache.origin)[empty] == -1).all()
        assert (np.asarray(cache.samples)[empty] == 0.0).all()
        assert (np.asarray(cache.group)[empty] == -1).all()
        assert (np.asarray(cache.arrival)[empty] == -1).all()


def _random_meta(rng, M):
    return CacheMeta(
        ts=jnp.asarray(rng.integers(0, 8, M), jnp.int32),
        origin=jnp.asarray(rng.integers(-1, 5, M), jnp.int32),
        samples=jnp.asarray(rng.random(M), jnp.float32),
        group=jnp.asarray(rng.integers(0, 2, M), jnp.int32),
        arrival=jnp.asarray(rng.integers(0, 8, M), jnp.int32))


def _ctx(pol, M, cap=3, params=None):
    return policy_base.PolicyContext(
        t=jnp.asarray(8, jnp.int32), capacity=cap,
        rng=jax.random.PRNGKey(0) if pol.needs_rng else None,
        group_slots=jnp.asarray([2, 1], jnp.int32),
        encounters=jnp.asarray([0.13, 1.41, 2.72, 3.14, 0.57], jnp.float32),
        params=params or {})


@pytest.mark.parametrize("name", policy_registry.available())
def test_policy_dedup_keeps_freshest(name):
    """Duplicate origins: only the max-ts copy may survive retention."""
    pol = policy_registry.get_policy(name)
    meta = CacheMeta(
        ts=jnp.asarray([2, 6, 4, 1], jnp.int32),
        origin=jnp.asarray([3, 3, 3, 1], jnp.int32),
        samples=jnp.ones((4,), jnp.float32),
        group=jnp.zeros((4,), jnp.int32),
        arrival=jnp.asarray([5, 0, 3, 1], jnp.int32))
    _, out = policy_base.retain(meta, pol, _ctx(pol, 4, cap=4))
    out_origin = np.asarray(out.origin)
    out_ts = np.asarray(out.ts)
    kept3 = out_ts[out_origin == 3]
    assert len(kept3) <= 1
    if len(kept3):
        assert kept3[0] == 6                  # the freshest copy of origin 3


@pytest.mark.parametrize(
    "name", [n for n in policy_registry.available()
             if policy_registry.get_policy(n).deterministic])
def test_deterministic_policy_permutation_invariant(name):
    """Deterministic policies retain the same origin set regardless of
    candidate ordering (distinct sort keys — ties legitimately break by
    candidate index, which is order-dependent by design)."""
    pol = policy_registry.get_policy(name)
    rng = np.random.default_rng(7)
    for trial in range(5):
        meta = _random_meta(rng, 10)
        # tie-free: distinct ts and arrival per candidate
        meta = dataclasses.replace(
            meta,
            ts=jnp.asarray(rng.permutation(10), jnp.int32),
            arrival=jnp.asarray(rng.permutation(10), jnp.int32))
        perm = rng.permutation(10)
        meta_p = CacheMeta(ts=meta.ts[perm], origin=meta.origin[perm],
                           samples=meta.samples[perm],
                           group=meta.group[perm],
                           arrival=meta.arrival[perm])
        _, a = policy_base.retain(meta, pol, _ctx(pol, 10))
        _, b = policy_base.retain(meta_p, pol, _ctx(pol, 10))
        oa = sorted(np.asarray(a.origin)[np.asarray(a.origin) >= 0].tolist())
        ob = sorted(np.asarray(b.origin)[np.asarray(b.origin) >= 0].tolist())
        assert oa == ob, (trial, oa, ob)


# ---------------------------------------------------------------------------
# new policies: semantics
# ---------------------------------------------------------------------------

def test_mobility_aware_evicts_frequently_met_origins():
    """Equal freshness: the origin this agent meets all the time is evicted
    before the rarely-met one."""
    pol = policy_registry.get_policy("mobility_aware")
    meta = CacheMeta(ts=jnp.asarray([5, 5], jnp.int32),
                     origin=jnp.asarray([0, 1], jnp.int32),
                     samples=jnp.ones((2,), jnp.float32),
                     group=jnp.zeros((2,), jnp.int32),
                     arrival=jnp.asarray([5, 5], jnp.int32))
    enc = jnp.asarray([9.0, 0.0], jnp.float32)   # meets origin 0 constantly
    ctx = policy_base.PolicyContext(t=jnp.asarray(3, jnp.int32), capacity=1,
                                    encounters=enc)
    _, out = policy_base.retain(meta, pol, ctx)
    assert int(out.origin[0]) == 1               # rare origin protected


def test_mobility_aware_requires_encounters():
    pol = policy_registry.get_policy("mobility_aware")
    meta = _random_meta(np.random.default_rng(0), 4)
    ctx = policy_base.PolicyContext(t=jnp.asarray(1, jnp.int32), capacity=2)
    with pytest.raises(ValueError, match="encounter"):
        policy_base.retain(meta, pol, ctx)


def test_priority_policy_reduces_to_fifo():
    """w_ts=0, w_arrival=1 must reproduce FIFO's retained set."""
    fifo = policy_registry.get_policy("fifo")
    prio = policy_registry.get_policy("priority")
    rng = np.random.default_rng(1)
    meta = _random_meta(rng, 8)
    # distinct arrivals so the int/float sort keys induce the same order
    meta = dataclasses.replace(
        meta, arrival=jnp.asarray(rng.permutation(8), jnp.int32))
    _, a = policy_base.retain(meta, fifo, _ctx(fifo, 8))
    _, b = policy_base.retain(
        meta, prio, _ctx(prio, 8, params={"w_ts": 0.0, "w_arrival": 1.0}))
    np.testing.assert_array_equal(np.asarray(a.origin), np.asarray(b.origin))


def test_staleness_weighted_decay_resolution():
    pol = policy_registry.get_policy("staleness_weighted")
    lru = policy_registry.get_policy("lru")
    assert policy_base.effective_staleness_decay(pol) == pytest.approx(0.9)
    assert policy_base.effective_staleness_decay(pol, 0.5) == pytest.approx(0.5)
    assert policy_base.effective_staleness_decay(
        pol, 1.0, {"gamma": 0.7}) == pytest.approx(0.7)
    assert policy_base.effective_staleness_decay(lru) == pytest.approx(1.0)


def test_aggregate_flat_paths_apply_staleness_decay():
    """The flat/kernel aggregation paths honor the γ^age weight decay."""
    from repro.core.aggregate import (aggregate_flat,
                                      aggregate_flat_gathered,
                                      aggregation_weights)
    key = jax.random.PRNGKey(0)
    C, D = 4, 64
    cache = jax.random.normal(key, (C, D), jnp.float32)
    params = jax.random.normal(jax.random.PRNGKey(1), (D,))
    samples = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    valid = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    ages = jnp.asarray([0, 3, 1, 5], jnp.int32)
    out = aggregate_flat(params, cache, 2.0, samples, valid,
                         use_kernel=False, ages=ages, staleness_decay=0.8)
    w_self, w_cache = aggregation_weights(2.0, samples, valid, True,
                                          ages=ages, staleness_decay=0.8)
    ref = w_self * params + jnp.sum(w_cache[:, None] * valid[:, None]
                                    * cache, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    fused = aggregate_flat_gathered(
        params, cache, jnp.arange(C, dtype=jnp.int32), 2.0, samples, valid,
        use_kernel=False, ages=ages, staleness_decay=0.8)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
    # γ=1 recovers the undecayed paper weighting
    plain = aggregate_flat(params, cache, 2.0, samples, valid,
                           use_kernel=False)
    assert not np.allclose(np.asarray(out), np.asarray(plain))


# ---------------------------------------------------------------------------
# policy-aware single-insert path (pod-scale)
# ---------------------------------------------------------------------------

def _cache_with(ts, origin, arrival, cap):
    c = cache_lib.init_cache({"w": jnp.zeros((4,))}, cap)
    n = len(ts)
    return dataclasses.replace(
        c,
        ts=c.ts.at[:n].set(jnp.asarray(ts, jnp.int32)),
        origin=c.origin.at[:n].set(jnp.asarray(origin, jnp.int32)),
        samples=c.samples.at[:n].set(1.0),
        group=c.group.at[:n].set(0),
        arrival=c.arrival.at[:n].set(jnp.asarray(arrival, jnp.int32)))


def test_insert_honors_configured_policy():
    """Regression (pre-refactor ``insert`` hardcoded select_lru): with
    policy="fifo" the single-insert path must retain by arrival, matching
    the fleet path's fifo semantics."""
    # origin 1: fresh model received long ago; origin 2: stale model
    # received recently — lru and fifo must disagree
    base = _cache_with(ts=[9, 1], origin=[1, 2], arrival=[0, 5], cap=2)
    new_model = {"w": jnp.full((4,), 7.0)}
    lru = cache_lib.insert(base, new_model, t=6, origin=3, samples=1.0,
                           group=0, tau_max=100)
    fifo = cache_lib.insert(base, new_model, t=6, origin=3, samples=1.0,
                            group=0, tau_max=100, policy="fifo")
    lru_kept = sorted(np.asarray(lru.origin)[np.asarray(lru.valid)].tolist())
    fifo_kept = sorted(
        np.asarray(fifo.origin)[np.asarray(fifo.valid)].tolist())
    assert lru_kept == [1, 3]     # freshest-trained: ts 9 and 6
    assert fifo_kept == [2, 3]    # most recently received: arrival 5 and 6
    # the retained models' weights follow the metadata
    idx3 = int(np.argwhere(np.asarray(fifo.origin) == 3)[0, 0])
    assert float(fifo.models["w"][idx3, 0]) == 7.0


def test_insert_random_policy_requires_rng():
    base = _cache_with(ts=[1], origin=[1], arrival=[1], cap=2)
    with pytest.raises(ValueError, match="PRNG"):
        cache_lib.insert(base, {"w": jnp.zeros((4,))}, t=2, origin=2,
                         samples=1.0, group=0, tau_max=10, policy="random")
    out = cache_lib.insert(base, {"w": jnp.zeros((4,))}, t=2, origin=2,
                           samples=1.0, group=0, tau_max=10,
                           policy="random", rng=jax.random.PRNGKey(0))
    assert int(jnp.sum(out.valid)) == 2


# ---------------------------------------------------------------------------
# config-resolution validation (fl/experiment)
# ---------------------------------------------------------------------------

def test_group_policy_config_validation_names_fields():
    from repro.configs.base import DFLConfig
    from repro.fl.experiment import ExperimentConfig, resolve_policy_setup
    bad_dist = ExperimentConfig(
        algorithm="cached", distribution="noniid",
        dfl=DFLConfig(policy="group"))
    with pytest.raises(ValueError, match=r"distribution='grouped'"):
        resolve_policy_setup(bad_dist)
    bad_slots = ExperimentConfig(
        algorithm="cached", distribution="grouped", num_groups=5,
        dfl=DFLConfig(policy="group", cache_size=3))
    with pytest.raises(ValueError,
                       match=r"DFLConfig\.cache_size=3.*num_groups=5"):
        resolve_policy_setup(bad_slots)
    with pytest.raises(KeyError, match="registered"):
        resolve_policy_setup(ExperimentConfig(
            dfl=DFLConfig(policy="nonesuch")))
    ok = ExperimentConfig(algorithm="cached", distribution="grouped",
                          num_groups=3, dfl=DFLConfig(policy="group",
                                                      cache_size=6))
    pol, params = resolve_policy_setup(ok)
    assert pol.name == "group" and params == {}
    # knob typos are rejected at config resolution, not silently ignored
    typo = ExperimentConfig(
        algorithm="cached",
        dfl=DFLConfig(policy="mobility_aware",
                      policy_params=(("mobility_biass", 8.0),)))
    with pytest.raises(ValueError, match=r"mobility_biass"):
        resolve_policy_setup(typo)
    # "gamma" is accepted by every policy (aggregation decay)
    pol, params = resolve_policy_setup(ExperimentConfig(
        dfl=DFLConfig(policy="lru", policy_params=(("gamma", 0.95),))))
    assert params == {"gamma": 0.95}


# ---------------------------------------------------------------------------
# new policies under the fused engine: one trace per (algorithm, policy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,params", [
    ("mobility_aware", ()),
    ("staleness_weighted", (("gamma", 0.85),)),
    ("priority", (("w_ts", 1.0), ("w_samples", 0.1))),
])
def test_new_policies_run_fused_single_trace(policy, params):
    from repro.configs.base import DFLConfig, MobilityConfig
    from repro.fl.experiment import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(
        algorithm="cached", distribution="noniid",
        dfl=DFLConfig(num_agents=6, cache_size=3, local_steps=2,
                      batch_size=16, epoch_seconds=20.0, policy=policy,
                      policy_params=params),
        mobility=MobilityConfig(grid_w=4, grid_h=6),
        epochs=2, eval_every=2, n_train=300, n_test=60, image_hw=8,
        lr_plateau=False)
    hist = run_experiment(cfg, engine="fused")
    assert hist["epoch_traces"] == 1
    assert np.isfinite(hist["acc"]).all()


def test_encounter_counts_accumulate_through_engine():
    """FleetState.encounters is threaded through the fused engine and grows
    with realized exchanges."""
    from repro.configs.base import DFLConfig, MobilityConfig
    from repro.fl.experiment import (ExperimentConfig, build_fleet,
                                     make_engine)
    from repro.models import cnn as cnn_lib
    cfg = ExperimentConfig(
        algorithm="cached", distribution="noniid",
        dfl=DFLConfig(num_agents=6, cache_size=3, local_steps=2,
                      batch_size=16, epoch_seconds=30.0,
                      policy="mobility_aware"),
        mobility=MobilityConfig(grid_w=4, grid_h=6),
        epochs=2, eval_every=2, n_train=300, n_test=60, image_hw=8,
        lr_plateau=False)
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    warm = np.asarray(state.encounters)
    assert warm.shape == (6, 6) and (warm >= 0).all()
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots, chunk=2)
    state, mstate, _, _ = eng.run(state, mstate, jax.random.PRNGKey(0),
                                  0.1, data, counts, 2)
    after = np.asarray(state.encounters)
    assert after.sum() >= warm.sum()
    assert int(state.t) == 2
