"""Per-assigned-architecture smoke tests (assignment deliverable f):
reduced variant (≤2 layers, d_model ≤ 512, ≤4 experts), one forward/train
step on CPU, asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.launch import steps as steps_lib
from repro.models import registry as M

ARCHS = list(R.ARCH_IDS)


def make_batch(cfg, key, B=2, S=24):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.image_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_context, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_reduced(arch):
    cfg = R.get_smoke_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.moe_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dimensions(arch):
    """The full config carries the exact published dimensions."""
    cfg = R.get_config(arch)
    assert cfg.source, "config must cite its source"
    n = cfg.param_count()
    expected = {
        "phi-3-vision-4.2b": 4.2e9, "grok-1-314b": 314e9,
        "internlm2-1.8b": 1.8e9, "qwen2-7b": 7e9, "mamba2-780m": 780e6,
        "mixtral-8x7b": 47e9, "hymba-1.5b": 1.5e9, "deepseek-67b": 67e9,
        "internlm2-20b": 20e9, "whisper-small": 244e6,
    }[arch]
    assert 0.55 * expected < n < 1.8 * expected, (
        f"{arch}: analytic {n / 1e9:.2f}B vs published {expected / 1e9:.2f}B")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = R.get_smoke_config(arch)
    params = M.init_params(cfg, key)
    batch = make_batch(cfg, key)
    loss = M.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    new_params, loss2 = steps_lib.local_sgd_step(params, batch, cfg, lr=0.1)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN in params"
    # params actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(params),
                               jax.tree_util.tree_leaves(new_params)))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_dfl_round_on_arch(arch, key):
    """The paper's technique composes with every assigned arch."""
    cfg = R.get_smoke_config(arch)
    params = M.init_params(cfg, key)
    cache = steps_lib.init_pod_cache(cfg, params, cache_size=2)
    step = steps_lib.make_train_step(cfg, lr=0.05)
    batch = make_batch(cfg, key)
    new_params, cache, loss = step(params, cache, batch,
                                   jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b",
                                  "mamba2-780m", "hymba-1.5b"])
def test_loss_decreases_on_tiny_data(arch, key):
    """A few SGD steps on one repeated batch must reduce the loss."""
    cfg = R.get_smoke_config(arch)
    params = M.init_params(cfg, key)
    batch = make_batch(cfg, key, B=2, S=16)
    losses = []
    for _ in range(8):
        params, loss = steps_lib.local_sgd_step(params, batch, cfg, lr=0.1)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
