"""Fused fleet-epoch engine: equivalence with the legacy per-epoch loop,
compile discipline (traced lr / epoch count), and the allocation-light
gossip gather rewrite."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DFLConfig, MobilityConfig
from repro.core import cache as cache_lib
from repro.core import gossip
from repro.core import rounds as rounds_lib
from repro.fl.experiment import (ExperimentConfig, build_fleet, make_engine,
                                 make_epoch_fn, run_experiment)
from repro.mobility.base import partners_from_contacts
from repro.models import cnn as cnn_lib

FAST = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=30.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=4,
    eval_every=2,
    n_train=400,
    n_test=100,
    image_hw=12,
    lr_plateau=False,
)

MOBILITIES = {
    "manhattan": MobilityConfig(grid_w=4, grid_h=6),
    "random_waypoint": MobilityConfig(model="random_waypoint",
                                      area_w=300.0, area_h=300.0),
}


def _cfg(algorithm="cached", mobility="manhattan", distribution="noniid",
         **kw):
    merged = {**FAST, "mobility": MOBILITIES[mobility], **kw}
    return ExperimentConfig(algorithm=algorithm, distribution=distribution,
                            **merged)


# ---------------------------------------------------------------------------
# gossip phase-2 gather rewrite: bit-exact vs the concat reference
# ---------------------------------------------------------------------------

def test_gather_select_matches_concat_bitexact():
    N, cap = 6, 3
    params = {"w": jnp.arange(N, dtype=jnp.float32)[:, None]
              * jnp.ones((N, 5)),
              "b": jnp.arange(N, dtype=jnp.float32)}
    c = cache_lib.init_cache({"w": jnp.zeros((5,)), "b": jnp.zeros(())}, cap)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape).copy(), c)
    samples = jnp.ones((N,)) * 3
    group = jnp.zeros((N,), jnp.int32)
    key = jax.random.PRNGKey(0)
    for t in range(5):
        key, k = jax.random.split(key)
        met = jax.random.bernoulli(k, 0.4, (N, N))
        met = met & met.T & ~jnp.eye(N, dtype=bool)
        partners = partners_from_contacts(met, 2)
        sel = gossip.exchange(params, cache, partners, t, samples, group,
                              tau_max=4, policy="lru", gather_mode="select")
        ref = gossip.exchange(params, cache, partners, t, samples, group,
                              tau_max=4, policy="lru", gather_mode="concat")
        for a, b in zip(jax.tree_util.tree_leaves(sel),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        cache = sel


def test_gather_winners_own_model_rows():
    """Slot C must resolve to the agent's own fresh model, clamped gather
    must never read out of bounds."""
    N, C = 3, 2
    cache_models = {"w": jnp.arange(N * C * 4, dtype=jnp.float32
                                    ).reshape(N, C, 4)}
    params = {"w": 100.0 + jnp.arange(N * 4, dtype=jnp.float32
                                      ).reshape(N, 4)}
    gather_a = jnp.asarray([[1, 2], [0, 0], [2, 1]], jnp.int32)
    gather_s = jnp.asarray([[C, 0], [1, C], [C, C]], jnp.int32)
    out = gossip.gather_winners(cache_models, params, gather_a, gather_s)
    ref = gossip.gather_winners(cache_models, params, gather_a, gather_s,
                                mode="concat")
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(ref["w"]))
    # own-model row check: agent 0 slot 0 pulled params[1]
    np.testing.assert_array_equal(np.asarray(out["w"][0, 0]),
                                  np.asarray(params["w"][1]))


# ---------------------------------------------------------------------------
# fused engine vs legacy loop: same seed -> same trajectory
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["cached", "dfl", "cfl"])
@pytest.mark.parametrize("mobility", ["manhattan", "random_waypoint"])
def test_fused_matches_legacy_trajectory(algorithm, mobility):
    cfg = _cfg(algorithm, mobility)
    fused = run_experiment(cfg, engine="fused", record_cache_stats=True)
    legacy = run_experiment(cfg, engine="legacy", record_cache_stats=True)
    assert fused["epoch"] == legacy["epoch"]
    np.testing.assert_allclose(fused["acc"], legacy["acc"], atol=2e-3)
    np.testing.assert_allclose(fused["cache_num"], legacy["cache_num"],
                               atol=1e-5)
    np.testing.assert_allclose(fused["cache_age"], legacy["cache_age"],
                               atol=1e-4)
    assert fused["epoch_traces"] == 1
    assert legacy["epoch_traces"] == 1


@pytest.mark.slow
def test_fused_grouped_policy_and_random_partners():
    """Engine covers the group cache policy and the random partner-sample
    key discipline."""
    cfg = _cfg("cached", distribution="grouped", partner_sample="random",
               dfl=dataclasses.replace(FAST["dfl"], policy="group",
                                       cache_size=6))
    cfg = dataclasses.replace(cfg, num_groups=3)
    fused = run_experiment(cfg, engine="fused")
    legacy = run_experiment(cfg, engine="legacy")
    np.testing.assert_allclose(fused["acc"], legacy["acc"], atol=2e-3)


# ---------------------------------------------------------------------------
# compile discipline
# ---------------------------------------------------------------------------

def test_legacy_lr_change_does_not_retrace():
    cfg = _cfg("cached")
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    epoch_fn, counter = make_epoch_fn(cfg, loss_fn=loss_fn,
                                      group_slots=group_slots)
    key = jax.random.PRNGKey(3)
    _, k1, k2 = jax.random.split(key, 3)
    mstate, met, dur = mob_model.simulate_epoch(mstate, k1, cfg=mob_cfg,
                                                seconds=cfg.dfl.epoch_seconds)
    partners = partners_from_contacts(met, cfg.max_partners)
    state, _ = epoch_fn(state, partners, dur, data, counts, k2, 0.1)
    assert counter["traces"] == 1
    state, _ = epoch_fn(state, partners, dur, data, counts, k2, 0.05)
    state, _ = epoch_fn(state, partners, dur, data, counts, k2, 0.025)
    assert counter["traces"] == 1          # ReduceLROnPlateau never retraces


def test_engine_lr_and_epoch_count_do_not_retrace():
    cfg = _cfg("cached")
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots, chunk=3)
    key = jax.random.PRNGKey(3)
    state, mstate, key, losses = eng.run(state, mstate, key, 0.1, data,
                                         counts, 3)
    assert eng.traces == 1
    assert np.isfinite(np.asarray(losses)).all()
    state, mstate, key, losses = eng.run(state, mstate, key, 0.05, data,
                                         counts, 2)
    assert eng.traces == 1                 # lr + epoch count both traced
    losses = np.asarray(losses)
    assert np.isfinite(losses[:2]).all() and np.isnan(losses[2])


def test_engine_donated_matches_undonated():
    """donate=True must not change results (in-place cache update)."""
    cfg = _cfg("cached", epochs=3, eval_every=3)
    outs = []
    for donate in (False, True):
        (model_cfg, state, data, counts, _tb, mstate,
         group_slots, mob_model, mob_cfg) = build_fleet(cfg)
        loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                               b["labels"])
        eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                          mob_cfg=mob_cfg, group_slots=group_slots,
                          chunk=3, donate=donate)
        with warnings.catch_warnings():
            # CPU XLA can't alias buffers; donation falls back with a warning
            warnings.simplefilter("ignore")
            state, mstate, key, _ = eng.run(state, mstate,
                                            jax.random.PRNGKey(7), 0.1,
                                            data, counts, 3)
        outs.append(state)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# on-device eval + fused gather/aggregate kernel
# ---------------------------------------------------------------------------

def test_fleet_eval_matches_host_stats():
    cfg = _cfg("cached", epochs=2, eval_every=2)
    (model_cfg, state, data, counts, test_batch, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    acc_fn = lambda p, b: cnn_lib.accuracy(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots, chunk=2)
    state, mstate, key, _ = eng.run(state, mstate, jax.random.PRNGKey(5),
                                    0.1, data, counts, 2)
    acc, cache_num, cache_age = rounds_lib.fleet_eval(state, acc_fn,
                                                      test_batch)
    ref_acc, _ = rounds_lib.fleet_accuracy(state, acc_fn, test_batch)
    valid = np.asarray(state.cache.valid)
    ages = np.asarray(state.t - state.cache.ts)
    assert float(acc) == pytest.approx(float(ref_acc))
    assert float(cache_num) == pytest.approx(float(valid.sum(1).mean()))
    assert float(cache_age) == pytest.approx(
        float((ages * valid).sum() / max(valid.sum(), 1)), abs=1e-5)


def test_gather_cache_aggregate_kernel():
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    key = jax.random.PRNGKey(0)
    for M, D, C in ((7, 256, 3), (13, 517, 5)):     # 517: padding path
        src = jax.random.normal(key, (M, D), jnp.float32)
        idx = jax.random.randint(jax.random.PRNGKey(1), (C,), 0, M)
        w = jax.random.uniform(jax.random.PRNGKey(2), (C,))
        out = kops.gather_cache_aggregate(src, idx, w)
        ref = kref.gather_cache_aggregate_ref(src, idx, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_aggregate_flat_gathered_matches_two_step():
    from repro.core.aggregate import aggregate_flat, aggregate_flat_gathered
    key = jax.random.PRNGKey(0)
    M, D, C = 11, 300, 4
    src = jax.random.normal(key, (M, D), jnp.float32)
    idx = jnp.asarray([3, 9, 0, 7], jnp.int32)
    params = jax.random.normal(jax.random.PRNGKey(1), (D,))
    samples = jnp.asarray([2.0, 4.0, 1.0, 3.0])
    valid = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    fused = aggregate_flat_gathered(params, src, idx, 5.0, samples, valid)
    two_step = aggregate_flat(params, src[idx], 5.0, samples, valid)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_step),
                               rtol=1e-5, atol=1e-5)
    no_kernel = aggregate_flat_gathered(params, src, idx, 5.0, samples,
                                        valid, use_kernel=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(no_kernel),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model fast-impl vs reference-impl oracle
# ---------------------------------------------------------------------------

def test_cnn_fast_impl_matches_reference():
    from repro.configs.paper_models import PAPER_CONFIGS
    for name in ("paper-mnist-cnn", "paper-fashion-cnn"):
        model_cfg = dataclasses.replace(PAPER_CONFIGS[name], image_hw=16)
        params = cnn_lib.init_params(model_cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 1))
        fast = cnn_lib.forward(params, model_cfg, x, impl="fast")
        ref = cnn_lib.forward(params, model_cfg, x, impl="reference")
        np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
