"""Verbatim pre-refactor cache-policy code (PR 2 state of ``core/cache.py``
+ ``core/gossip.py``), kept as the bit-exactness oracle for the ported
lru/fifo/random/group policies in the registry-driven subsystem.

Not a test module — imported by ``test_cache_policies.py``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.cache import NEG

# --- pre-refactor core/cache.py selection functions (verbatim) -------------


def _dedup_mask(origin, ts, pref):
    M = origin.shape[0]
    same = origin[None, :] == origin[:, None]          # [i, j]
    newer = ts[None, :] > ts[:, None]
    tie = ts[None, :] == ts[:, None]
    pref_j = (pref[None, :] > pref[:, None]) | (
        (pref[None, :] == pref[:, None])
        & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None]))
    beaten = same & (newer | (tie & pref_j))
    return (origin >= 0) & ~jnp.any(beaten, axis=1)


def select_lru(origin, ts, samples, group, arrival, capacity, rank_key=None):
    pref = jnp.zeros_like(ts) if rank_key is None else rank_key
    valid = _dedup_mask(origin, ts, pref)
    key = jnp.where(valid, ts, jnp.int32(-2**30))
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = valid[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def select_group(origin, ts, samples, group, arrival, capacity, group_slots):
    num_groups = group_slots.shape[0]
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    M = origin.shape[0]
    same_g = (group[None, :] == group[:, None])
    better = same_g & valid[None, :] & (
        (ts[None, :] > ts[:, None])
        | ((ts[None, :] == ts[:, None])
           & (jnp.arange(M)[None, :] < jnp.arange(M)[:, None])))
    rank = jnp.sum(better, axis=1)
    slots = jnp.where((group >= 0) & (group < num_groups),
                      group_slots[jnp.clip(group, 0, num_groups - 1)], 0)
    keep = valid & (rank < slots)
    key = jnp.where(keep, ts, jnp.int32(-2**30))
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = keep[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def _retain(retain_key, valid, origin, ts, samples, group, arrival,
            capacity):
    key = jnp.where(valid, retain_key, jnp.int32(-2**30))
    order = jnp.argsort(-key, stable=True)
    sel = order[:capacity]
    sel_valid = valid[sel]
    return sel, {
        "ts": jnp.where(sel_valid, ts[sel], NEG),
        "origin": jnp.where(sel_valid, origin[sel], NEG),
        "samples": jnp.where(sel_valid, samples[sel], 0.0),
        "group": jnp.where(sel_valid, group[sel], NEG),
        "arrival": jnp.where(sel_valid, arrival[sel], NEG),
    }


def select_fifo(origin, ts, samples, group, arrival, capacity):
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    return _retain(arrival, valid, origin, ts, samples, group, arrival,
                   capacity)


def select_random(origin, ts, samples, group, arrival, capacity, key):
    valid = _dedup_mask(origin, ts, jnp.zeros_like(ts))
    rnd = jax.random.randint(key, origin.shape, 0, 2**30)
    return _retain(rnd, valid, origin, ts, samples, group, arrival, capacity)


# --- pre-refactor gossip.exchange policy dispatch (verbatim) ---------------


def legacy_exchange(params, cache, partners, t, own_samples, own_group, *,
                    tau_max, policy="lru", group_slots=None, rng=None,
                    gather_mode="select"):
    N, C = cache.ts.shape
    # current _candidates sources candidates from an ExchangePool; the
    # identity pool reproduces the pre-refactor dense semantics exactly
    pool = gossip.identity_pool(params, cache, own_samples, own_group)
    ts, origin, samples, group, arrival, src_a, src_s = gossip._candidates(
        cache, t, partners, tau_max, pool)

    if policy == "lru":
        sel_fn = functools.partial(select_lru, capacity=C)
        sel, meta = jax.vmap(sel_fn)(origin, ts, samples, group, arrival)
    elif policy == "group":
        if group_slots is None:
            raise ValueError("group policy requires group_slots")
        sel_fn = lambda o, t_, s, g, a, gs: select_group(
            o, t_, s, g, a, capacity=C, group_slots=gs)
        sel, meta = jax.vmap(sel_fn, in_axes=(0, 0, 0, 0, 0, None))(
            origin, ts, samples, group, arrival, group_slots)
    elif policy == "fifo":
        sel_fn = functools.partial(select_fifo, capacity=C)
        sel, meta = jax.vmap(sel_fn)(origin, ts, samples, group, arrival)
    elif policy == "random":
        if rng is None:
            raise ValueError("random policy requires rng")
        keys = jax.random.split(rng, N)
        sel_fn = lambda o, t_, s, g, a, k: select_random(
            o, t_, s, g, a, C, k)
        sel, meta = jax.vmap(sel_fn)(origin, ts, samples, group, arrival,
                                     keys)
    else:
        raise ValueError(f"unknown cache policy {policy!r}")

    gather_a = jnp.take_along_axis(src_a, sel, axis=1)
    gather_s = jnp.take_along_axis(src_s, sel, axis=1)
    models = gossip.gather_winners(cache.models, params, gather_a, gather_s,
                                   mode=gather_mode)
    return dataclasses.replace(cache, models=models, **meta)
