"""Federated partitioner tests (paper §4.1 settings)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.fl import partition as P


def labels(n=1000, classes=10, seed=0):
    return np.random.default_rng(seed).integers(0, classes, n)


def test_iid_covers_all():
    y = labels()
    idx, counts = P.iid_partition(np.random.default_rng(0), y, 10)
    used = np.concatenate([idx[i, :counts[i]] for i in range(10)])
    assert len(np.unique(used)) == len(y)


def test_shards_noniid_label_concentration():
    y = labels(2000)
    idx, counts = P.shards_noniid_partition(np.random.default_rng(0), y, 20)
    # uneven counts: some agents have ~4x the shards of others
    assert counts.max() >= 3 * counts.min()
    # each agent sees few distinct labels (exreme non-iid)
    distinct = [len(np.unique(y[idx[i, :counts[i]]])) for i in range(20)]
    assert np.median(distinct) <= 4


def test_dirichlet_partition_nonempty():
    y = labels()
    idx, counts = P.dirichlet_partition(np.random.default_rng(0), y, 15,
                                        pi=0.5)
    assert (counts >= 1).all()
    used = np.concatenate([idx[i, :counts[i]] for i in range(15)])
    assert len(used) >= len(y) * 0.95


def test_grouped_partition_label_areas():
    y = labels(3000)
    groups = np.repeat(np.arange(3), 4)
    area_labels = [[0, 1, 2, 3], [4, 5, 6], [7, 8, 9]]
    idx, counts = P.grouped_label_partition(np.random.default_rng(0), y, 12,
                                            groups, area_labels)
    for i in range(12):
        seen = set(np.unique(y[idx[i, :counts[i]]]).tolist())
        assert seen <= set(area_labels[groups[i]])


@settings(max_examples=10, deadline=None)
@given(n_agents=st.integers(2, 30), seed=st.integers(0, 20))
def test_partitions_within_bounds(n_agents, seed):
    y = labels(500, seed=seed)
    for fn in (P.iid_partition, P.shards_noniid_partition,
               P.dirichlet_partition):
        idx, counts = fn(np.random.default_rng(seed), y, n_agents)
        assert idx.shape[0] == n_agents
        assert (counts <= idx.shape[1]).all()
        assert (idx < len(y)).all() and (idx >= 0).all()
