"""Unit + property tests for the Cached-DFL model cache (Alg. 2 & 3)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cache as C


def toy_params(val=0.0):
    return {"w": jnp.full((3, 2), val), "b": jnp.full((4,), val)}


def test_init_cache_empty():
    c = C.init_cache(toy_params(), 5)
    assert c.capacity == 5
    assert not bool(jnp.any(c.valid))
    assert c.models["w"].shape == (5, 3, 2)


def test_insert_and_refresh():
    c = C.init_cache(toy_params(), 3)
    c = C.insert(c, toy_params(1.0), t=0, origin=7, samples=10.0, group=0,
                 tau_max=10)
    assert int(jnp.sum(c.valid)) == 1
    assert int(c.origin[0]) == 7 and int(c.ts[0]) == 0
    assert float(c.models["w"][0, 0, 0]) == 1.0
    # refresh with a NEWER model from the same origin: still one entry
    c = C.insert(c, toy_params(2.0), t=3, origin=7, samples=10.0, group=0,
                 tau_max=10)
    assert int(jnp.sum(c.valid)) == 1
    assert int(c.ts[0]) == 3
    assert float(c.models["w"][0, 0, 0]) == 2.0
    # an OLDER model from the same origin must NOT replace the fresh one
    c = C.insert(c, toy_params(9.0), t=1, origin=7, samples=10.0, group=0,
                 tau_max=10)
    assert int(jnp.sum(c.valid)) == 1
    assert int(c.ts[0]) == 3


def test_staleness_eviction():
    c = C.init_cache(toy_params(), 4)
    c = C.insert(c, toy_params(1.0), t=0, origin=1, samples=1.0, group=0,
                 tau_max=100)
    c = C.insert(c, toy_params(2.0), t=5, origin=2, samples=1.0, group=0,
                 tau_max=100)
    # t - ts >= tau_max evicts: 10-0=10 >= 10 -> origin1 out; 10-5=5 stays
    c2 = C.evict_stale(c, t=10, tau_max=10)
    assert int(jnp.sum(c2.valid)) == 1
    # with a larger tolerance both survive
    assert int(jnp.sum(C.evict_stale(c, t=10, tau_max=11).valid)) == 2


def test_lru_retains_newest():
    c = C.init_cache(toy_params(), 2)
    for i, t in enumerate([3, 1, 7, 5]):
        c = C.insert(c, toy_params(float(t)), t=t, origin=10 + i,
                     samples=1.0, group=0, tau_max=100)
    ts = sorted(np.asarray(c.ts).tolist(), reverse=True)
    assert ts == [7, 5]


@settings(max_examples=30, deadline=None)
@given(
    n_ops=st.integers(1, 12),
    capacity=st.integers(1, 5),
    tau_max=st.integers(1, 8),
    data=st.data(),
)
def test_cache_invariants(n_ops, capacity, tau_max, data):
    """Property: after any op sequence — size ≤ capacity, no stale entries,
    at most one entry per origin, and entries are the freshest seen."""
    c = C.init_cache(toy_params(), capacity)
    best_seen = {}
    t = 0
    for _ in range(n_ops):
        t += data.draw(st.integers(0, 3))
        origin = data.draw(st.integers(0, 6))
        c = C.insert(c, toy_params(float(t)), t=t, origin=origin,
                     samples=1.0, group=0, tau_max=tau_max)
        best_seen[origin] = max(best_seen.get(origin, -1), t)

    valid = np.asarray(c.valid)
    origins = np.asarray(c.origin)[valid]
    ts = np.asarray(c.ts)[valid]
    assert valid.sum() <= capacity
    assert len(set(origins.tolist())) == len(origins)  # dedup by origin
    for o, tau in zip(origins, ts):
        assert t - tau < tau_max          # no stale survivors
        assert tau <= best_seen[o]        # never newer than seen


def test_group_select_respects_slots():
    # 6 candidates in 2 groups; 2 slots each
    origin = jnp.arange(6, dtype=jnp.int32)
    ts = jnp.asarray([5, 4, 3, 9, 8, 7], jnp.int32)
    group = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    samples = jnp.ones((6,))
    arrival = ts
    sel, meta = C.select_group(origin, ts, samples, group, arrival,
                               capacity=4,
                               group_slots=jnp.asarray([2, 2], jnp.int32))
    kept = np.asarray(meta["origin"])
    kept = kept[kept >= 0]
    # group0 keeps ts 5,4 (origins 0,1); group1 keeps ts 9,8 (origins 3,4)
    assert sorted(kept.tolist()) == [0, 1, 3, 4]


def test_fifo_vs_lru_difference():
    """FIFO keeps most recently RECEIVED; LRU keeps freshest TRAINED."""
    origin = jnp.asarray([1, 2], jnp.int32)
    ts = jnp.asarray([9, 1], jnp.int32)        # model 1 fresher
    arrival = jnp.asarray([0, 5], jnp.int32)   # model 2 received later
    samples = jnp.ones((2,))
    group = jnp.zeros((2,), jnp.int32)
    _, meta_lru = C.select_lru(origin, ts, samples, group, arrival, 1)
    _, meta_fifo = C.select_fifo(origin, ts, samples, group, arrival, 1)
    assert int(meta_lru["origin"][0]) == 1
    assert int(meta_fifo["origin"][0]) == 2
