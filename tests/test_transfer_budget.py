"""Contact-duration-limited transfers: the per-link bandwidth budget on
``gossip.exchange`` plus the correctness fixes it depends on
(duplicate-partner dedup, explicit policy-context epoch)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DFLConfig, MobilityConfig
from repro.core import cache as cache_lib
from repro.core import gossip
from repro.fl.experiment import (ExperimentConfig, build_fleet, make_engine,
                                 run_experiment)
from repro.models import cnn as cnn_lib
from repro.policies import registry as policy_registry
from repro.policies.base import CachePolicy


def fleet_params(N):
    return {"w": jnp.arange(N, dtype=jnp.float32)[:, None]
            * jnp.ones((N, 4))}


def empty_fleet_cache(N, cap):
    c = cache_lib.init_cache({"w": jnp.zeros((4,))}, cap)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape).copy(), c)


def populated_fleet(N, cap, epochs=3, tau_max=100, seed=0):
    """Run a few unbudgeted exchanges so caches hold non-trivial state."""
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    samples = jnp.ones((N,)) * 2.0
    group = jnp.arange(N, dtype=jnp.int32) % 2
    key = jax.random.PRNGKey(seed)
    from repro.mobility.base import partners_from_contacts
    for t in range(epochs):
        key, k = jax.random.split(key)
        met = jax.random.bernoulli(k, 0.5, (N, N))
        met = met & met.T & ~jnp.eye(N, dtype=bool)
        partners = partners_from_contacts(met, 2)
        cache = gossip.exchange(params, cache, partners, t, samples, group,
                                tau_max=tau_max, policy="lru")
    return params, cache, samples, group


def assert_caches_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# budget semantics on a single exchange
# ---------------------------------------------------------------------------

def test_budget_zero_is_no_exchange():
    """budget=0: caches only age/evict, exactly as if nobody met anyone."""
    N, cap = 5, 3
    params, cache, samples, group = populated_fleet(N, cap)
    partners = jnp.asarray([[1, 2], [0, -1], [0, 3], [2, 4], [3, -1]],
                           jnp.int32)
    none = jnp.full_like(partners, -1)
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru", transfer_budget=0.0)
    ref = gossip.exchange(params, cache, none, 5, samples, group,
                          tau_max=100, policy="lru")
    assert_caches_equal(out, ref)


def test_budget_unlimited_bitexact_all_policies():
    """budget=inf must be bit-exact with the unbudgeted exchange for every
    registered policy (the admission mask degenerates to all-True)."""
    N, cap = 6, 3
    params, cache, samples, _ = populated_fleet(N, cap)
    group = jnp.arange(N, dtype=jnp.int32) % 3
    partners = jnp.asarray([[1, 2], [0, 3], [0, 5], [1, -1], [5, -1],
                            [2, 4]], jnp.int32)
    durations = jax.random.randint(jax.random.PRNGKey(9), (N, N), 0, 30)
    durations = (durations + durations.T).astype(jnp.int32)
    for name in policy_registry.available():
        pol = policy_registry.get_policy(name)
        kw = dict(tau_max=100, policy=name,
                  group_slots=jnp.asarray([1, 1, 1], jnp.int32),
                  rng=jax.random.PRNGKey(1),
                  encounters=jnp.ones((N, N), jnp.float32),
                  policy_params={"w_encounter": 0.5} if name == "priority"
                  else None)
        ref = gossip.exchange(params, cache, partners, 5, samples, group,
                              **kw)
        out = gossip.exchange(params, cache, partners, 5, samples, group,
                              transfer_budget=float("inf"),
                              durations=durations,
                              link_entries_per_step=1e6, **kw)
        assert_caches_equal(out, ref)


def test_admission_respects_policy_priority():
    """On a saturated link the policy's own priority picks the entries:
    under lru the partner's fresh model (ts=t) and freshest cache rows."""
    N, cap = 3, 3
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    # partner 1 holds copies of origin 2 (ts=4) and nothing else; give it a
    # second, staler entry from origin 0 (ts=1)
    cache = dataclasses.replace(
        cache,
        ts=cache.ts.at[1, 0].set(4).at[1, 1].set(1),
        origin=cache.origin.at[1, 0].set(2).at[1, 1].set(0),
        samples=cache.samples.at[1, 0].set(1.0).at[1, 1].set(1.0),
        group=cache.group.at[1, 0].set(0).at[1, 1].set(0),
        arrival=cache.arrival.at[1, 0].set(4).at[1, 1].set(1))
    partners = jnp.asarray([[1], [-1], [-1]], jnp.int32)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru", transfer_budget=2.0)
    origins = set(np.asarray(out.origin[0]).tolist()) - {-1}
    # cap 2 admits the fresh model of agent 1 (ts=5) and origin 2 (ts=4);
    # origin 0 (ts=1) is cut
    assert origins == {1, 2}
    out3 = gossip.exchange(params, cache, partners, 5, samples, group,
                           tau_max=100, policy="lru", transfer_budget=3.0)
    assert set(np.asarray(out3.origin[0]).tolist()) - {-1} == {0, 1, 2}


def test_duration_derived_cap():
    """link_entries_per_step converts measured contact steps into the cap."""
    N, cap = 3, 3
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    cache = dataclasses.replace(
        cache,
        ts=cache.ts.at[1, 0].set(4),
        origin=cache.origin.at[1, 0].set(2),
        samples=cache.samples.at[1, 0].set(1.0),
        group=cache.group.at[1, 0].set(0),
        arrival=cache.arrival.at[1, 0].set(4))
    partners = jnp.asarray([[1], [-1], [-1]], jnp.int32)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    durations = jnp.zeros((N, N), jnp.int32).at[0, 1].set(10).at[1, 0].set(10)
    # 10 steps * 0.1 entries/step -> cap 1: only the fresh model crosses
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru", durations=durations,
                          link_entries_per_step=0.1)
    assert set(np.asarray(out.origin[0]).tolist()) - {-1} == {1}
    # 10 steps * 0.2 entries/step -> cap 2: the cached copy rides along
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru", durations=durations,
                          link_entries_per_step=0.2)
    assert set(np.asarray(out.origin[0]).tolist()) - {-1} == {1, 2}
    # a pair with zero measured contact time moves nothing
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru",
                          durations=jnp.zeros((N, N), jnp.int32),
                          link_entries_per_step=0.2)
    assert int(jnp.sum(out.valid[0])) == 0


def test_budget_not_wasted_on_unretainable_entries():
    """Regression: entries the policy's keep mask rejects (here a group
    with zero slots) must not consume the link budget — the admissible
    entry still crosses."""
    N, cap = 3, 2
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    # partner 1 (group 1, zero slots) carries a cached group-0 model
    cache = dataclasses.replace(
        cache,
        ts=cache.ts.at[1, 0].set(1),
        origin=cache.origin.at[1, 0].set(2),
        samples=cache.samples.at[1, 0].set(1.0),
        group=cache.group.at[1, 0].set(0),
        arrival=cache.arrival.at[1, 0].set(1))
    partners = jnp.asarray([[1], [-1], [-1]], jnp.int32)
    samples = jnp.ones((N,))
    group = jnp.asarray([0, 1, 0], jnp.int32)
    group_slots = jnp.asarray([2, 0], jnp.int32)
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="group",
                          group_slots=group_slots, transfer_budget=1.0)
    # partner's own fresh model is group-1 (keep=False, zero slots): it
    # must not burn the single budget slot; the group-0 entry gets it
    assert set(np.asarray(out.origin[0]).tolist()) - {-1} == {2}


def test_budget_on_non_cached_algorithm_rejected():
    """A budget knob on dfl/cfl would silently be a no-op — fail fast at
    config resolution instead, naming the fields."""
    from repro.fl.experiment import resolve_policy_setup
    for algo in ("dfl", "cfl"):
        cfg = ExperimentConfig(
            algorithm=algo, dfl=DFLConfig(transfer_budget=2.0))
        with pytest.raises(ValueError, match="transfer_budget"):
            resolve_policy_setup(cfg)
    # disabled knobs stay fine on every algorithm
    resolve_policy_setup(ExperimentConfig(algorithm="dfl"))


def test_negative_budget_means_unlimited():
    """Regression: a negative transfer_budget is the 'unlimited' sentinel;
    combined with a duration cap it must not flatten caps to -1."""
    dfl = DFLConfig(transfer_budget=-1.0, link_entries_per_step=0.5)
    assert dfl.resolved_transfer_budget is None
    assert dfl.transfer_budget_enabled          # duration cap still active
    assert DFLConfig(transfer_budget=-1.0).resolved_transfer_budget is None
    assert not DFLConfig(transfer_budget=-1.0).transfer_budget_enabled
    assert DFLConfig(transfer_budget=3.0).resolved_transfer_budget == 3.0
    assert DFLConfig().resolved_transfer_budget is None  # default inf


def test_stale_copy_on_idle_link_survives_saturated_link():
    """Regression: when the freshest copy of an origin is cut by its own
    link's cap, a staler copy riding another link with idle budget must
    still arrive (per-link dedup, no cross-link forfeit)."""
    N, cap = 4, 3
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    # partner 1 carries origin 3 @ ts=4, partner 2 carries origin 3 @ ts=2
    cache = dataclasses.replace(
        cache,
        ts=cache.ts.at[1, 0].set(4).at[2, 0].set(2),
        origin=cache.origin.at[1, 0].set(3).at[2, 0].set(3),
        samples=cache.samples.at[1, 0].set(1.0).at[2, 0].set(1.0),
        group=cache.group.at[1, 0].set(0).at[2, 0].set(0),
        arrival=cache.arrival.at[1, 0].set(4).at[2, 0].set(2))
    partners = jnp.asarray([[1, 2], [-1, -1], [-1, -1], [-1, -1]], jnp.int32)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    # measured durations -> link caps: 1 entry via partner 1, 2 via partner 2
    durations = jnp.zeros((N, N), jnp.int32)
    durations = durations.at[0, 1].set(10).at[1, 0].set(10)
    durations = durations.at[0, 2].set(20).at[2, 0].set(20)
    out = gossip.exchange(params, cache, partners, 5, samples, group,
                          tau_max=100, policy="lru", durations=durations,
                          link_entries_per_step=0.1)
    origins = set(np.asarray(out.origin[0]).tolist()) - {-1}
    # link 1 (cap 1) carries only partner 1's fresh model; origin 3 still
    # arrives as the ts=2 copy over link 2 (cap 2)
    assert origins == {1, 2, 3}
    idx3 = int(np.argwhere(np.asarray(out.origin[0]) == 3)[0, 0])
    assert int(out.ts[0, idx3]) == 2


def test_duplicate_partner_does_not_double_charge():
    """A repeated partner id in one row must behave exactly like a single
    occurrence — same candidates, one budget charge (bugfix)."""
    N, cap = 4, 3
    params, cache, samples, group = populated_fleet(N, cap)
    dup = jnp.asarray([[1, 1], [0, -1], [-1, -1], [-1, -1]], jnp.int32)
    single = jnp.asarray([[1, -1], [0, -1], [-1, -1], [-1, -1]], jnp.int32)
    for kw in (dict(), dict(transfer_budget=1.0), dict(transfer_budget=2.0)):
        out = gossip.exchange(params, cache, dup, 5, samples, group,
                              tau_max=100, policy="lru", **kw)
        ref = gossip.exchange(params, cache, single, 5, samples, group,
                              tau_max=100, policy="lru", **kw)
        assert_caches_equal(out, ref)


def test_count_encounters_dedups_partners():
    """Encounter counts use the same duplicate-partner mask the exchange
    does, so mobility-aware scores see the realized contacts one-for-one."""
    from repro.core import rounds as rounds_lib
    enc = jnp.zeros((3, 3), jnp.float32)
    partners = jnp.asarray([[1, 1], [0, -1], [-1, -1]], jnp.int32)
    out = np.asarray(rounds_lib.count_encounters(enc, partners))
    assert out[0, 1] == 1.0 and out[1, 0] == 1.0
    assert out.sum() == 2.0


def test_link_caps_combination():
    partners = jnp.asarray([[1, 2], [0, -1], [0, 1]], jnp.int32)
    durations = jnp.asarray([[0, 7, 2], [7, 0, 0], [2, 0, 0]], jnp.int32)
    caps = gossip.link_caps(partners, durations, None, 0.5)
    np.testing.assert_array_equal(np.asarray(caps),
                                  [[3.0, 1.0], [3.0, 3.0], [1.0, 0.0]])
    caps = gossip.link_caps(partners, durations, 2.0, 0.5)
    np.testing.assert_array_equal(np.asarray(caps),
                                  [[2.0, 1.0], [2.0, 2.0], [1.0, 0.0]])
    caps = gossip.link_caps(partners, None, 4.2, 0.0)
    np.testing.assert_array_equal(np.asarray(caps), np.full((3, 2), 4.0))
    # negative = unlimited sentinel, honored even for traced per-call caps
    # that bypass DFLConfig.resolved_transfer_budget
    caps = gossip.link_caps(partners, None, -1.0, 0.0)
    assert np.isinf(np.asarray(caps)).all()
    caps = gossip.link_caps(partners, durations, jnp.float32(-3.0), 0.5)
    np.testing.assert_array_equal(np.asarray(caps),
                                  [[3.0, 1.0], [3.0, 3.0], [1.0, 0.0]])
    with pytest.raises(ValueError):
        gossip.link_caps(partners, None, None, 0.5)


# ---------------------------------------------------------------------------
# engine threading
# ---------------------------------------------------------------------------

ENGINE_CFG = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=30.0,
                  transfer_budget=2.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=4, eval_every=2, n_train=400, n_test=100, image_hw=12,
    lr_plateau=False,
)


def test_budget_sweep_single_trace():
    """The fused engine compiles once per (algorithm, shape): sweeping the
    traced transfer budget must not retrace."""
    cfg = ExperimentConfig(algorithm="cached", distribution="noniid",
                           **ENGINE_CFG)
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_engine(cfg, loss_fn=loss_fn, mob_model=mob_model,
                      mob_cfg=mob_cfg, group_slots=group_slots, chunk=2)
    key = jax.random.PRNGKey(3)
    for budget in (0.0, 1.0, 3.0, float("inf")):
        state, mstate, key, losses = eng.run(
            state, mstate, key, 0.1, data, counts, 2, jnp.float32(budget))
        assert np.isfinite(np.asarray(losses)).all()
    assert eng.traces == 1


@pytest.mark.slow
def test_fused_matches_legacy_with_budget():
    """Both drivers thread durations + budget identically."""
    dfl = dataclasses.replace(ENGINE_CFG["dfl"], transfer_budget=1.0,
                              link_entries_per_step=0.5)
    cfg = ExperimentConfig(algorithm="cached", distribution="noniid",
                           **{**ENGINE_CFG, "dfl": dfl})
    fused = run_experiment(cfg, engine="fused", record_cache_stats=True)
    legacy = run_experiment(cfg, engine="legacy", record_cache_stats=True)
    np.testing.assert_allclose(fused["acc"], legacy["acc"], atol=2e-3)
    np.testing.assert_allclose(fused["cache_num"], legacy["cache_num"],
                               atol=1e-5)
    assert fused["epoch_traces"] == 1 and legacy["epoch_traces"] == 1


@pytest.mark.slow
def test_unbudgeted_run_unchanged_by_budget_inf():
    """A run with budget knobs disabled and one with an effectively
    unlimited cap produce the same trajectory end to end."""
    cfg = ExperimentConfig(algorithm="cached", distribution="noniid",
                           **{**ENGINE_CFG,
                              "dfl": dataclasses.replace(
                                  ENGINE_CFG["dfl"],
                                  transfer_budget=float("inf"))})
    assert not cfg.dfl.transfer_budget_enabled
    base = run_experiment(cfg, engine="fused")
    big = dataclasses.replace(ENGINE_CFG["dfl"], transfer_budget=1e9)
    cfg_b = ExperimentConfig(algorithm="cached", distribution="noniid",
                             **{**ENGINE_CFG, "dfl": big})
    assert cfg_b.dfl.transfer_budget_enabled
    budgeted = run_experiment(cfg_b, engine="fused")
    np.testing.assert_allclose(base["acc"], budgeted["acc"], atol=1e-6)


# ---------------------------------------------------------------------------
# legacy shim epoch clock (ctx.t) and the pod single-insert gate
# ---------------------------------------------------------------------------

def test_run_policy_threads_explicit_t():
    """_run_policy must hand the policy the real epoch, not the candidate
    max (which is -1 for an all-empty set)."""
    seen = {}

    def capture(meta, ctx, valid):
        seen["t"] = int(ctx.t)
        return meta.ts, valid

    policy_registry.register(CachePolicy("_capture_t", capture, paper=False))
    try:
        empty = jnp.full((4,), -1, jnp.int32)
        zeros = jnp.zeros((4,), jnp.float32)
        cache_lib._run_policy("_capture_t", empty, empty, zeros, empty,
                              empty, 2, t=7)
        assert seen["t"] == 7
        # fallback without t: floored at 0, never the all-empty sentinel -1
        cache_lib._run_policy("_capture_t", empty, empty, zeros, empty,
                              empty, 2)
        assert seen["t"] == 0
    finally:
        policy_registry._REGISTRY.pop("_capture_t", None)


def test_select_lru_accepts_epoch():
    origin = jnp.asarray([0, 1, -1], jnp.int32)
    ts = jnp.asarray([2, 4, -1], jnp.int32)
    z = jnp.zeros((3,), jnp.float32)
    g = jnp.zeros((3,), jnp.int32)
    arr = jnp.asarray([2, 4, -1], jnp.int32)
    sel_t, meta_t = cache_lib.select_lru(origin, ts, z, g, arr, 2, t=9)
    sel, meta = cache_lib.select_lru(origin, ts, z, g, arr, 2)
    # lru ignores the clock: same retention either way, but both accept it
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel))


def test_insert_budget_gate():
    cache = cache_lib.init_cache({"w": jnp.zeros((4,))}, 2)
    params = {"w": jnp.ones((4,))}
    out = cache_lib.insert(cache, params, 3, 1, 5.0, 0, tau_max=10,
                           transfer_budget=0.4)
    assert int(jnp.sum(out.valid)) == 0          # contact too short
    out = cache_lib.insert(cache, params, 3, 1, 5.0, 0, tau_max=10,
                           transfer_budget=1.0)
    assert int(jnp.sum(out.valid)) == 1
    out_ref = cache_lib.insert(cache, params, 3, 1, 5.0, 0, tau_max=10)
    assert_caches_equal(out, out_ref)
