"""Fast preset gate: every registered preset must resolve() without
error (a broken preset fails tier-1, not the nightly smoke) and
round-trip through JSON losslessly."""
import pytest

from repro import api


def test_presets_registered():
    names = api.available_presets()
    assert {"paper-noniid", "grouped-overlap", "budget-limited",
            "trace-replay"} <= set(names)


@pytest.mark.parametrize("name", api.available_presets())
def test_preset_resolves(name):
    scenario = api.get_preset(name)
    assert scenario.name == name
    rs = scenario.resolve()
    assert rs.scenario is scenario
    assert rs.mob_model.name == rs.mobility.model


@pytest.mark.parametrize("name", api.available_presets())
def test_preset_json_roundtrip(name):
    scenario = api.get_preset(name)
    again = api.Scenario.from_json(scenario.to_json())
    assert again == scenario
    again.resolve()


def test_preset_docs_present():
    for name in api.available_presets():
        assert api.preset_doc(name).strip(), name


def test_unknown_preset_raises_naming_available():
    with pytest.raises(ValueError, match="paper-noniid"):
        api.get_preset("warp-speed")


def test_preset_overridable():
    s = api.get_preset("paper-noniid").with_overrides(
        {"dfl.policy": "mobility_aware", "epochs": 5})
    assert s.experiment.dfl.policy == "mobility_aware"
    assert s.experiment.epochs == 5
    s.resolve()
