"""Fleet telemetry: bit-exactness of telemetry-on runs, compile
discipline, on-device metric invariants, span/event plumbing, and the
report tool's dashboards."""
import dataclasses
import importlib.util
import json
import os

import jax.numpy as jnp
import pytest

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig
from repro.core import rounds as rounds_lib
from repro.fl.experiment import ExperimentConfig
from repro.telemetry import (EventLog, SpanTimer, accumulate, init_metrics,
                             summarize, validate_events, validate_jsonl,
                             zero_exchange_stats)

TINY = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=10.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=4, eval_every=2, n_train=300, n_test=60, image_hw=8,
    lr_plateau=False,
)


def tiny_scenario(telemetry=False, **kw):
    merged = {**TINY, **kw}
    return api.Scenario(experiment=ExperimentConfig(**merged),
                        record_cache_stats=True, telemetry=telemetry)


def _report_module():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "report.py")
    spec = importlib.util.spec_from_file_location("repro_report_tool", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# bit-exactness + compile discipline
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["cached", "dfl", "cfl"])
def test_fused_telemetry_is_bit_exact_and_single_trace(algorithm):
    off = api.run(tiny_scenario(algorithm=algorithm))
    on = api.run(tiny_scenario(algorithm=algorithm, telemetry=True))
    assert on.acc == off.acc          # identical trajectory, bit for bit
    assert on.lr == off.lr
    assert on.cache_num == off.cache_num
    assert on.traces == off.traces == 1
    assert on.config_hash == off.config_hash
    assert off.telemetry is None and off.phase_s == {}
    assert on.telemetry is not None


@pytest.mark.slow
def test_legacy_engine_telemetry_is_bit_exact():
    off = api.run(tiny_scenario().with_overrides({"engine": "legacy"}))
    on = api.run(tiny_scenario(telemetry=True)
                 .with_overrides({"engine": "legacy"}))
    assert on.acc == off.acc
    assert on.telemetry["fleet"]["epochs"] == TINY["epochs"]


@pytest.mark.slow
def test_record_cache_stats_reports_for_all_algorithms():
    # the cached-only gate is lifted: dfl runs report (empty) occupancy too
    r = api.run(tiny_scenario(algorithm="dfl"))
    assert len(r.cache_num) == len(r.acc) == 2
    assert all(v == 0.0 for v in r.cache_num)


# ---------------------------------------------------------------------------
# on-device fleet metrics
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_metrics_invariants():
    r = api.run(tiny_scenario(telemetry=True))
    f = r.telemetry["fleet"]
    N, epochs = TINY["dfl"].num_agents, TINY["epochs"]
    assert f["epochs"] == epochs and f["num_agents"] == N
    assert sum(f["staleness_hist"]) == f["cache_entry_epochs"]
    assert 0 <= f["staleness_mean"] <= TINY["dfl"].tau_max
    assert f["staleness_p95"] < len(f["staleness_hist"])
    # every agent has at least seen a model of its own origin via partners
    assert 0 <= f["spread_min"] <= f["spread_mean"] <= f["spread_max"] <= N
    assert 0 <= f["reach_fraction"] <= 1
    assert f["offered"] >= f["admitted"] >= 0
    assert f["denied"] == f["offered"] - f["admitted"]
    assert f["contacts"] > 0
    assert f["budget_utilization"] is None  # unbudgeted run: no capacity
    # dispersion series covers every eval point
    ev = r.telemetry["eval"]
    assert len(ev["acc_std"]) == len(r.acc)
    assert all(lo <= hi for lo, hi in zip(ev["acc_min"], ev["acc_max"]))
    assert len(ev["contacts_per_epoch"]) == len(r.acc)
    assert all(c >= 0 for c in ev["contacts_per_epoch"])


@pytest.mark.slow
def test_budgeted_run_reports_utilization():
    r = api.run(tiny_scenario(telemetry=True).with_overrides(
        {"dfl.transfer_budget": 1.0}))
    f = r.telemetry["fleet"]
    assert f["link_capacity"] > 0 and f["capped_links"] > 0
    assert 0.0 <= f["budget_utilization"] <= 1.0
    assert f["admitted"] <= f["offered"]


def test_accumulate_counts_entries_and_contacts():
    N, C, B = 4, 2, 5
    m = init_metrics(N, B)
    # hand-built fleet state: agent i caches a model from origin (i+1)%N
    # with age 1, second slot empty
    origin = jnp.stack([jnp.array([(i + 1) % N, -1]) for i in range(N)])
    ts = jnp.full((N, C), 2, jnp.int32)
    state = _FakeState(t=jnp.asarray(4, jnp.int32),
                       cache=_FakeCache(origin=origin.astype(jnp.int32),
                                        ts=ts))
    partners = jnp.array([[1, -1], [0, -1], [3, 3], [-1, -1]], jnp.int32)
    m = accumulate(m, state, partners, zero_exchange_stats())
    s = summarize(m)
    assert s["epochs"] == 1
    assert s["cache_entry_epochs"] == N          # one valid entry per agent
    # ages clamp into bin 1 (t_agg=3, ts=2)
    assert s["staleness_hist"][1] == N
    assert s["spread_mean"] == 1.0
    # duplicate partner id (agent 2 row) deduped; padding ignored
    assert s["contacts"] == 3.0


@dataclasses.dataclass
class _FakeCache:
    origin: jnp.ndarray
    ts: jnp.ndarray


@dataclasses.dataclass
class _FakeState:
    t: jnp.ndarray
    cache: _FakeCache


# ---------------------------------------------------------------------------
# spans + events
# ---------------------------------------------------------------------------

def test_span_timer_nesting_and_totals():
    closed = []
    timer = SpanTimer(on_close=lambda *row: closed.append(row))
    with timer.span("outer"):
        with timer.span("inner"):
            pass
        with timer.span("inner"):
            pass
    tot = timer.totals()
    assert set(tot) == {"outer", "inner"}
    assert tot["outer"] >= tot["inner"] >= 0.0
    assert timer.summary()["inner"]["count"] == 2
    assert [c[0] for c in closed] == ["inner", "inner", "outer"]
    assert [c[3] for c in closed] == [2, 2, 1]   # depths


def test_event_log_schema_and_jsonl_roundtrip(tmp_path):
    log = EventLog("abc123")
    log.emit("run_start", algorithm="cached", engine="fused",
             num_agents=6, epochs=2)
    log.emit("eval", epoch=2, acc=0.5)
    log.emit("run_end", best_acc=0.5, final_acc=0.5, wall_s=1.0)
    assert validate_events(log.to_dicts()) == []
    path = tmp_path / "events.jsonl"
    log.write_jsonl(str(path))
    assert validate_jsonl(str(path)) == []
    lines = path.read_text().strip().splitlines()
    assert [json.loads(l)["kind"] for l in lines] == \
        ["run_start", "eval", "run_end"]


def test_event_validation_catches_bad_streams():
    good = {"kind": "eval", "t": 1.0, "run": "abc", "epoch": 2,
            "data": {"acc": 0.5}}
    assert validate_events([good]) == []
    assert validate_events([])                       # empty stream
    assert validate_events([{**good, "kind": "nope"}])
    assert validate_events([{**good, "t": -1.0}])
    assert validate_events([{**good, "data": {}}])   # missing required key
    bad_order = [dict(good, t=2.0), dict(good, t=1.0)]
    assert any("sorted" in p for p in validate_events(bad_order))
    two_runs = [good, dict(good, run="other", t=2.0)]
    assert any("distinct run" in p for p in validate_events(two_runs))


@pytest.mark.slow
def test_run_emits_validated_event_stream():
    r = api.run(tiny_scenario(telemetry=True))
    events = r.telemetry["events"]
    assert validate_events(events) == []
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("eval") == len(r.acc)
    assert "compile" in kinds and "phase" in kinds
    assert all(e["run"] == r.config_hash for e in events)
    assert {"build", "compile", "dispatch", "eval"} <= set(r.phase_s)


# ---------------------------------------------------------------------------
# scenario plumbing
# ---------------------------------------------------------------------------

def test_telemetry_flag_excluded_from_content_hash():
    assert tiny_scenario().content_hash() == \
        tiny_scenario(telemetry=True).content_hash()


def test_telemetry_flag_round_trips():
    s = tiny_scenario(telemetry=True)
    assert api.Scenario.from_json(s.to_json()) == s
    assert s.with_overrides({"telemetry": "false"}).telemetry is False


# ---------------------------------------------------------------------------
# sweep + report dashboards
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_carries_telemetry_columns_and_report_renders(tmp_path):
    base = tiny_scenario(telemetry=True)
    sw = api.sweep(base, {"dfl.transfer_budget": [1.0, float("inf")]})
    assert sw.retraces == 0
    doc = sw.write_bench(str(tmp_path / "BENCH_tiny.json"), name="tiny")
    for cell in doc["cells"]:
        assert "telemetry" in cell
        assert "staleness_mean" in cell["telemetry"]
    report = _report_module()
    md = report.render(doc)
    assert "Budget-utilization frontier" in md
    assert "budget util" in md
    # finite-budget cell realized a utilization; inf cell has none
    finite = [c for c in doc["cells"]
              if c["overrides"]["dfl.transfer_budget"] == 1.0]
    assert finite[0]["telemetry"]["budget_utilization"] is not None


@pytest.mark.slow
def test_report_renders_fresh_run_json(tmp_path):
    r = api.run(tiny_scenario(telemetry=True))
    path = tmp_path / "run.json"
    path.write_text(r.to_json())
    report = _report_module()
    md = report.render(json.loads(path.read_text()))
    assert "# Run report" in md
    assert "Staleness vs accuracy" in md
    assert "Phase times" in md
    assert "Fleet metrics" in md
    assert r.config_hash in md


def test_report_renders_committed_bench_artifact():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_budget.json")
    if not os.path.exists(path):
        pytest.skip("no committed BENCH_budget.json")
    with open(path) as f:
        doc = json.load(f)
    report = _report_module()
    md = report.render(doc)
    # pre-telemetry artifact: renders without telemetry columns
    assert "# Benchmark report" in md
    assert "Budget-utilization frontier" in md
    assert "| transfer_budget |" in md
