"""Manhattan mobility model invariants."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import MobilityConfig
from repro.mobility import manhattan as mob


CFG = MobilityConfig(grid_w=6, grid_h=9, step_seconds=1.0)


def test_positions_stay_on_grid():
    N = 20
    state = mob.init_mobility(jax.random.PRNGKey(0), N, CFG)
    key = jax.random.PRNGKey(1)
    for _ in range(50):
        key, k = jax.random.split(key)
        state = mob.step(state, k, CFG)
    pos = np.asarray(mob.positions(state, CFG))
    assert (pos[:, 0] >= -1e-3).all()
    assert (pos[:, 0] <= (CFG.grid_w - 1) * CFG.block_w + 1e-3).all()
    assert (pos[:, 1] >= -1e-3).all()
    assert (pos[:, 1] <= (CFG.grid_h - 1) * CFG.block_h + 1e-3).all()
    # a vehicle is always on a street: x or y aligns with the grid
    on_x = np.min(np.abs(pos[:, 0:1] - np.arange(CFG.grid_w) * CFG.block_w),
                  axis=1) < 1e-2
    on_y = np.min(np.abs(pos[:, 1:2] - np.arange(CFG.grid_h) * CFG.block_h),
                  axis=1) < 1e-2
    assert (on_x | on_y).all()


def test_contacts_symmetric_no_self():
    state = mob.init_mobility(jax.random.PRNGKey(2), 30, CFG)
    met = np.asarray(mob.contacts_now(state, CFG))
    assert (met == met.T).all()
    assert not met.diagonal().any()


def test_band_restriction():
    N = 12
    band, group = mob.make_bands(N, 3, free_per_band=1)
    state = mob.init_mobility(jax.random.PRNGKey(3), N, CFG)
    state = mob.init_mobility(jax.random.PRNGKey(3), N, CFG,
                              band=jnp.asarray(band))
    key = jax.random.PRNGKey(4)
    for _ in range(100):
        key, k = jax.random.split(key)
        state = mob.step(state, k, CFG)
    y = np.asarray(state.node[:, 1])
    b = np.asarray(band)
    h = CFG.grid_h // 3
    for i in range(N):
        if b[i] >= 0:
            assert b[i] * h <= y[i] < (b[i] + 1) * h + 1, (i, b[i], y[i])


def test_simulate_epoch_contact_union():
    state = mob.init_mobility(jax.random.PRNGKey(5), 16, CFG)
    state2, met, dur = mob.simulate_epoch(state, jax.random.PRNGKey(6), CFG,
                                          30.0)
    met = np.asarray(met)
    dur = np.asarray(dur)
    assert (met == met.T).all()
    # durations: symmetric step counts, bounded by the epoch length, and
    # positive exactly where the union matrix saw a contact
    assert (dur == dur.T).all()
    assert dur.min() >= 0 and dur.max() <= 30
    assert ((dur > 0) == met).all()
    # higher speed should produce at least as many contacts on average
    fast = MobilityConfig(grid_w=6, grid_h=9, speed=3 * CFG.speed)
    _, met_fast, _ = mob.simulate_epoch(state, jax.random.PRNGKey(6), fast,
                                        30.0)
    assert np.asarray(met_fast).sum() >= met.sum() * 0.5  # stochastic slack


def test_partners_padding():
    met = jnp.asarray([[False, True, True], [True, False, False],
                       [True, False, False]])
    p = np.asarray(mob.partners_from_contacts(met, 2))
    assert p[0].tolist() == [1, 2]
    assert p[1].tolist() == [0, -1]
