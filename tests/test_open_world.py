"""Open-world fleets: the liveness schedule, frozen out-of-coverage
agents, DTN-style cache spread through live carriers, the diurnal
contact envelope, and the engines' compile discipline with churn on."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig
from repro.core import rounds as rounds_lib
from repro.fl.experiment import ExperimentConfig, build_fleet, make_engine
from repro.mobility import registry as mob_registry
from repro.mobility import trace as trace_lib
from repro.models import cnn as cnn_lib

CHURN = dict(churn_period=4, churn_fraction=0.25)    # 1 of every 4 epochs out

FAST = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=30.0, **CHURN),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=4, eval_every=2, n_train=400, n_test=100, image_hw=12,
    lr_plateau=False,
)


def _cfg(algorithm="cached", **kw):
    return ExperimentConfig(algorithm=algorithm, distribution="noniid",
                            **{**FAST, **kw})


def _loss_fn(model_cfg):
    return lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                        b["labels"])


# ---------------------------------------------------------------------------
# the liveness schedule
# ---------------------------------------------------------------------------

def test_liveness_mask_schedule():
    N, period, fraction = 6, 4, 0.25
    down = round(fraction * period)
    masks = np.stack([np.asarray(rounds_lib.liveness_mask(t, N, period,
                                                          fraction))
                      for t in range(period)])
    assert masks.dtype == bool and masks.shape == (period, N)
    # every agent spends exactly `down` epochs of each cycle out of coverage
    np.testing.assert_array_equal(masks.sum(0), period - down)
    # staggered phases: outages spread over the cycle, never the whole fleet
    assert (masks.any(1)).all()
    # period-periodic in t
    np.testing.assert_array_equal(
        np.asarray(rounds_lib.liveness_mask(period + 2, N, period, fraction)),
        masks[2])
    # pure arithmetic on a traced t: jit produces the identical mask
    jitted = jax.jit(lambda t: rounds_lib.liveness_mask(t, N, period,
                                                        fraction))
    np.testing.assert_array_equal(np.asarray(jitted(jnp.int32(3))), masks[3])


def test_liveness_mask_never_empties_fleet():
    # resolve() rejects schedules that would take every agent out at once
    scenario = api.Scenario().with_overrides(
        {"dfl.churn_period": 4, "dfl.churn_fraction": 0.99})
    with pytest.raises(ValueError, match="churn"):
        scenario.resolve()


# ---------------------------------------------------------------------------
# dead agents freeze; their cached models keep spreading
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["cached", "dfl", "cfl"])
def test_dead_agents_frozen_one_epoch(algorithm):
    cfg = _cfg(algorithm, epochs=1, eval_every=1)
    fleet = build_fleet(cfg)
    state, mstate = fleet.state, fleet.mobility_state
    eng = make_engine(cfg, loss_fn=_loss_fn(fleet.model_cfg),
                      mob_model=fleet.mob_model, mob_cfg=fleet.mobility,
                      group_slots=fleet.group_slots, chunk=1)
    before = jax.tree_util.tree_map(np.asarray, state.params)
    state, mstate, key, _ = eng.run(state, mstate, jax.random.PRNGKey(5),
                                    0.1, fleet.data, fleet.counts, 1)
    live = np.asarray(rounds_lib.liveness_mask(
        0, cfg.dfl.num_agents, cfg.dfl.churn_period, cfg.dfl.churn_fraction))
    assert not live.all() and live.any()
    np.testing.assert_array_equal(np.asarray(state.live), live)
    after = jax.tree_util.tree_map(np.asarray, state.params)
    for b, a in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        # out-of-coverage agents' models are bit-frozen ...
        np.testing.assert_array_equal(b[~live], a[~live])
    # ... while at least one live agent actually trained
    changed = [not np.array_equal(b[live], a[live])
               for b, a in zip(jax.tree_util.tree_leaves(before),
                               jax.tree_util.tree_leaves(after))]
    assert any(changed)


@pytest.mark.slow
def test_dead_agent_cache_entries_still_spread(tmp_path):
    """The DTN effect: agent 0's model reaches agent 3 through carrier 1
    while agent 0 itself is out of coverage — and 0 and 3 never meet.

    Schedule (period 4, fraction 0.25 -> agent (4 - t) % 4 is dead at
    epoch t): contact (0, 1) at epoch 1 (both live; 3 is dead), contact
    (1, 3) at epoch 4 (both live; 0 is dead)."""
    n, epochs = 4, 5
    seq = np.zeros((epochs, n, n), bool)
    seq[1, 0, 1] = True
    seq[4, 1, 3] = True
    path = os.path.join(tmp_path, "dtn_trace.npz")
    trace_lib.save_trace(path, seq)

    cfg = _cfg(
        "cached", epochs=epochs, eval_every=epochs,
        dfl=dataclasses.replace(FAST["dfl"], num_agents=n, cache_size=3),
        mobility=MobilityConfig(model="trace", trace_path=path,
                                trace_frames_per_epoch=1))
    fleet = build_fleet(cfg)
    state, mstate = fleet.state, fleet.mobility_state
    eng = make_engine(cfg, loss_fn=_loss_fn(fleet.model_cfg),
                      mob_model=fleet.mob_model, mob_cfg=fleet.mobility,
                      group_slots=fleet.group_slots, chunk=epochs)
    state, mstate, key, _ = eng.run(state, mstate, jax.random.PRNGKey(5),
                                    0.1, fleet.data, fleet.counts, epochs)
    # final epoch (t=4): agent 0 was out of coverage during the hand-off
    np.testing.assert_array_equal(np.asarray(state.live),
                                  [False, True, True, True])
    origins = np.asarray(state.cache.origin)
    valid = np.asarray(state.cache.valid)
    # agent 1 picked up agent 0's model at the direct contact ...
    assert 0 in origins[1][valid[1]]
    # ... and relayed it to agent 3 while agent 0 was dead
    assert 0 in origins[3][valid[3]]


def test_engine_single_trace_with_churn_and_diurnal():
    cfg = _cfg("cached", epochs=4,
               mobility=MobilityConfig(grid_w=4, grid_h=6,
                                       diurnal_period=60.0,
                                       diurnal_amplitude=0.5))
    fleet = build_fleet(cfg)
    state, mstate = fleet.state, fleet.mobility_state
    eng = make_engine(cfg, loss_fn=_loss_fn(fleet.model_cfg),
                      mob_model=fleet.mob_model, mob_cfg=fleet.mobility,
                      group_slots=fleet.group_slots, chunk=2)
    state, mstate, key, _ = eng.run(state, mstate, jax.random.PRNGKey(3),
                                    0.1, fleet.data, fleet.counts, 2)
    assert eng.traces == 1
    state, mstate, key, _ = eng.run(state, mstate, key, 0.05,
                                    fleet.data, fleet.counts, 1)
    assert eng.traces == 1    # churn + diurnal knobs stay trace-static


# ---------------------------------------------------------------------------
# diurnal contact envelope
# ---------------------------------------------------------------------------

def _tiny_mob_cfg(name, trace_path, **kw) -> MobilityConfig:
    return MobilityConfig(model=name, grid_w=4, grid_h=6, area_w=200.0,
                          area_h=200.0, levy_max_flight=200.0,
                          community_radius=50.0, trace_path=trace_path,
                          trace_frames_per_epoch=5, **kw)


def _make_trace(tmp_path, n=6):
    rng = np.random.default_rng(0)
    seq = rng.random((20, n, n)) < 0.3
    path = os.path.join(tmp_path, "trace.npz")
    trace_lib.save_trace(path, seq | seq.transpose(0, 2, 1))
    return path


def test_diurnal_amplitude_one_gates_all_contacts(tmp_path):
    """Amplitude 1.0 with a period well past the epoch span: the envelope
    is measurably below peak at every (strictly positive) step time —
    measurably, so float32 cos can't round activity back up to 1.0 — and
    every registered mobility model must report zero contacts and zero
    durations."""
    path = _make_trace(tmp_path)
    for name in mob_registry.available():
        cfg = _tiny_mob_cfg(name, path, diurnal_amplitude=1.0,
                            diurnal_period=80.0)
        model = mob_registry.get_model(name)
        st = model.init(jax.random.PRNGKey(0), 6, cfg)
        _, met, dur = model.simulate_epoch(st, jax.random.PRNGKey(1),
                                           cfg=cfg, seconds=20.0)
        assert not bool(np.asarray(met).any()), f"{name}: contacts leaked"
        assert int(np.asarray(dur).sum()) == 0, f"{name}: durations leaked"


def test_diurnal_fully_active_envelope_is_bitexact(tmp_path):
    """A negligible amplitude enables the gated scan but keeps every step
    active — contacts, durations and motion must be bit-identical to the
    envelope-off path (the gate adds masking only, never perturbs the
    key stream or trajectories)."""
    path = _make_trace(tmp_path)
    for name in mob_registry.available():
        model = mob_registry.get_model(name)
        outs = []
        for amplitude in (0.0, 1e-12):
            cfg = _tiny_mob_cfg(name, path, diurnal_amplitude=amplitude)
            st = model.init(jax.random.PRNGKey(0), 6, cfg)
            st, met, dur = model.simulate_epoch(st, jax.random.PRNGKey(1),
                                                cfg=cfg, seconds=20.0)
            outs.append((met, dur, model.positions(st, cfg)))
        for a, b in zip(outs[0], outs[1]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


# ---------------------------------------------------------------------------
# live-only eval
# ---------------------------------------------------------------------------

def test_fleet_eval_live_only_averages_over_live_agents():
    cfg = _cfg("cached", epochs=1, eval_every=1)
    fleet = build_fleet(cfg)
    acc_fn = fleet.acc_fn()
    live = jnp.asarray([True, False, True, True, False, True])
    state = dataclasses.replace(fleet.state, live=live)
    acc, cache_num, _ = rounds_lib.fleet_eval(state, acc_fn,
                                              fleet.test_batch,
                                              live_only=True)
    _, accs = rounds_lib.fleet_accuracy(state, acc_fn, fleet.test_batch)
    lf = np.asarray(live)
    assert float(acc) == pytest.approx(float(np.asarray(accs)[lf].mean()),
                                       abs=1e-6)
    valid = np.asarray(state.cache.valid)
    assert float(cache_num) == pytest.approx(
        float(valid[lf].sum() / lf.sum()), abs=1e-6)
    # live_only=False remains the historical all-agents average
    acc_all, _, _ = rounds_lib.fleet_eval(state, acc_fn, fleet.test_batch)
    assert float(acc_all) == pytest.approx(float(np.asarray(accs).mean()),
                                           abs=1e-6)


# ---------------------------------------------------------------------------
# engines agree under churn
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_churn_matches_fused():
    overrides = {
        "epochs": 4, "eval_every": 2, "n_train": 300, "n_test": 60,
        "image_hw": 8, "lr_plateau": False, "partner_sample": "lowest-id",
        "dfl.num_agents": 8, "dfl.cache_size": 3, "dfl.local_steps": 2,
        "dfl.batch_size": 16, "dfl.epoch_seconds": 10.0,
        "dfl.churn_period": 4, "dfl.churn_fraction": 0.25,
        "mobility.grid_w": 4, "mobility.grid_h": 6,
        "mobility.diurnal_period": 20.0, "mobility.diurnal_amplitude": 0.5,
    }
    base = api.Scenario().with_overrides(overrides)
    fused = api.run(base)
    sharded = api.run(dataclasses.replace(base, engine="sharded", mesh=0))
    assert sharded.traces == 1
    assert all(np.isfinite(a) for a in sharded.acc)
    np.testing.assert_allclose(fused.acc, sharded.acc, atol=2e-3)
