"""End-to-end fleet integration: the paper's qualitative claims on a
scaled-down problem (synthetic data, small fleet, few epochs)."""
import dataclasses

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full experiment trajectories (minutes)

from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import ExperimentConfig, run_experiment

FAST = dict(
    dfl=DFLConfig(num_agents=10, cache_size=5, tau_max=10, local_steps=5,
                  lr=0.1, batch_size=32, epoch_seconds=60.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=16,
    n_train=2000,
    n_test=400,
    image_hw=16,
    lr_plateau=False,
)


def run(algorithm, distribution="noniid", **kw):
    cfg = ExperimentConfig(algorithm=algorithm, distribution=distribution,
                           **{**FAST, **kw})
    return run_experiment(cfg)


def test_cached_dfl_learns():
    hist = run("cached")
    assert hist["best_acc"] > 0.5, hist["acc"]


def test_cached_beats_plain_dfl_noniid():
    """The paper's headline claim (Fig. 2) at test scale."""
    cached = run("cached", seed=1)
    plain = run("dfl", seed=1)
    assert cached["best_acc"] > plain["best_acc"] - 0.02, (
        cached["acc"], plain["acc"])


def test_cfl_upper_bounds():
    cfl = run("cfl", seed=2)
    assert cfl["best_acc"] > 0.5


def test_group_policy_runs():
    hist = run("cached", distribution="grouped",
               dfl=dataclasses.replace(FAST["dfl"], policy="group",
                                       cache_size=6))
    assert hist["best_acc"] > 0.3


def test_iid_easier_than_noniid():
    iid = run("cached", distribution="iid", seed=3, epochs=8)
    assert iid["best_acc"] > 0.55
