"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True on CPU, per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("C,D", [(1, 128), (3, 1000), (10, 70001),
                                 (16, 131072), (30, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_aggregate_sweep(C, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(C * D), 3)
    cache = jax.random.normal(k1, (C, D), dtype)
    w = jax.random.uniform(k2, (C,))
    valid = (jax.random.uniform(k3, (C,)) > 0.3).astype(jnp.float32)
    out = ops.cache_aggregate(cache, w, valid, block_d=8192)
    exp = ref.cache_aggregate_ref(cache, w, valid)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol)


def test_cache_aggregate_all_invalid():
    cache = jnp.ones((4, 256))
    out = ops.cache_aggregate(cache, jnp.ones((4,)), jnp.zeros((4,)))
    np.testing.assert_allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize(
    "B,S,KV,G,hd,win",
    [(2, 700, 2, 4, 64, 0),     # unaligned S
     (1, 1024, 4, 1, 128, 0),   # MHA-style (G=1)
     (2, 1500, 2, 2, 64, 256),  # sliding window
     (3, 300, 1, 8, 128, 0),    # deep GQA fan-out
     (2, 512, 2, 7, 64, 0)])    # odd group count (qwen-like)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, KV, G, hd, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + hd), 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    length = jnp.asarray(S - 17, jnp.int32)
    out = ops.decode_attention(q, k, v, length, window=win, block_s=256)
    exp = ref.decode_attention_ref(q, k, v, length, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(length=st.integers(1, 160), block_s=st.sampled_from([32, 64, 128]))
def test_decode_attention_length_property(length, block_s):
    """Any valid length, any block size: masked positions never leak."""
    B, S, KV, G, hd = 1, 160, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(length), 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    # poison the invalid region: must not affect the result
    k_poison = k.at[:, length:].set(1e4)
    v_poison = v.at[:, length:].set(-1e4)
    out = ops.decode_attention(q, k_poison, v_poison,
                               jnp.asarray(length, jnp.int32),
                               block_s=block_s)
    exp = ref.decode_attention_ref(q, k, v, jnp.asarray(length, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_softmax_directly():
    """Cross-check the oracle itself against a dense softmax."""
    B, S, KV, G, hd = 2, 64, 2, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = ref.decode_attention_ref(q, k, v, jnp.asarray(S, jnp.int32))
    s = jnp.einsum("bkgh,bskh->bkgs", q, k) / np.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    exp = jnp.einsum("bkgs,bskh->bkgh", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)
