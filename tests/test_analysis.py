"""repro.analysis: rule fixtures, suppressions, baselines, and the gate.

Each lint rule RPR001–RPR005 has a known-bad snippet it must flag and a
known-good sibling it must pass; the contract rules are exercised by
injecting deliberately broken registry entries. The final test is the
tier-1 gate: the repo's own ``src/`` tree must be analyzer-clean.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import findings as findings_lib
from repro.analysis import linter

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def rules_hit(src, path="src/repro/core/fixture.py", select=None):
    return {f.rule for f in linter.lint_source(src, path, select=select)
            if f.active}


# ---------------------------------------------------------------------------
# RPR001 — PRNG key reuse
# ---------------------------------------------------------------------------

BAD_KEY_REUSE = """
import jax

def sample(key, n):
    a = jax.random.normal(key, (n,))
    b = jax.random.uniform(key, (n,))
    return a + b
"""

GOOD_KEY_SPLIT = """
import jax

def sample(key, n):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (n,))
    b = jax.random.uniform(k2, (n,))
    return a + b
"""

BAD_KEY_CLOSURE = """
import jax

def epoch(state, key, steps):
    def body(i, carry):
        noise = jax.random.normal(key, (4,))
        return carry + noise
    return jax.lax.fori_loop(0, steps, body, state)
"""

GOOD_KEY_CARRY = """
import jax

def epoch(state, key, steps):
    def body(carry, k):
        st, key = carry
        key, sub = jax.random.split(key)
        noise = jax.random.normal(sub, (4,))
        return (st + noise, key), None
    (state, key), _ = jax.lax.scan(body, (state, key), None, length=steps)
    return state
"""

GOOD_DISCARDED_SUBKEY = """
import jax

def epoch(key):
    _, k_local, k_policy = jax.random.split(key, 3)
    a = jax.random.normal(k_local, (4,))
    b = jax.random.normal(k_policy, (4,))
    return a + b
"""

GOOD_KEY_REBOUND = """
import jax

def chain(key):
    key, k1 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    key, k2 = jax.random.split(key)
    return a + jax.random.normal(k2, (4,))
"""


def test_rpr001_flags_reuse():
    assert "RPR001" in rules_hit(BAD_KEY_REUSE)


def test_rpr001_flags_loop_closure_capture():
    assert "RPR001" in rules_hit(BAD_KEY_CLOSURE)


@pytest.mark.parametrize("src", [GOOD_KEY_SPLIT, GOOD_KEY_CARRY,
                                 GOOD_DISCARDED_SUBKEY, GOOD_KEY_REBOUND])
def test_rpr001_passes_disciplined_keys(src):
    assert "RPR001" not in rules_hit(src)


# ---------------------------------------------------------------------------
# RPR002 — retrace hazards
# ---------------------------------------------------------------------------

BAD_TRACED_AXIS_CLOSURE = """
import jax

def make_engine(cfg):
    @jax.jit
    def run(state):
        return state * cfg.dfl.lr
    return run
"""

GOOD_TRACED_AXIS_ARG = """
import jax

def make_engine(cfg):
    @jax.jit
    def run(state, lr):
        return state * lr
    return run
"""

BAD_TRACER_BRANCH = """
import jax

@jax.jit
def clip(x, lo):
    if x > lo:
        return x
    return lo
"""

GOOD_TRACER_WHERE = """
import jax
import jax.numpy as jnp

@jax.jit
def clip(x, lo):
    if x.shape[0] > 4:
        x = x[:4]
    return jnp.where(x > lo, x, lo)
"""


def test_rpr002_flags_traced_axis_closure():
    assert "RPR002" in rules_hit(BAD_TRACED_AXIS_CLOSURE)


def test_rpr002_flags_python_branch_on_tracer():
    assert "RPR002" in rules_hit(BAD_TRACER_BRANCH)


@pytest.mark.parametrize("src", [GOOD_TRACED_AXIS_ARG, GOOD_TRACER_WHERE])
def test_rpr002_passes_static_control_flow(src):
    assert "RPR002" not in rules_hit(src)


# ---------------------------------------------------------------------------
# RPR003 — donation after use
# ---------------------------------------------------------------------------

BAD_DONATE_READ = """
import jax

def run(step_fn, state, key):
    step = jax.jit(step_fn, donate_argnums=(0,))
    new_state = step(state, key)
    return state.params, new_state
"""

GOOD_DONATE_REBIND = """
import jax

def run(step_fn, state, key):
    step = jax.jit(step_fn, donate_argnums=(0,))
    state = step(state, key)
    return state.params, state
"""


def test_rpr003_flags_read_after_donation():
    assert "RPR003" in rules_hit(BAD_DONATE_READ)


def test_rpr003_passes_rebound_donation():
    assert "RPR003" not in rules_hit(GOOD_DONATE_REBIND)


def test_rpr003_handles_conditional_donation():
    src = """
import jax

def make(run_fn, donate):
    eng = jax.jit(run_fn, donate_argnums=(0, 1) if donate else ())
    def call(state, mstate):
        out = eng(state, mstate)
        return state.t, out
    return call
"""
    assert "RPR003" in rules_hit(src)


# ---------------------------------------------------------------------------
# RPR004 — host sync in hot paths
# ---------------------------------------------------------------------------

BAD_HOT_SYNC = """
import numpy as np

def accumulate(xs):
    total = 0.0
    for x in xs:
        total += float(x)
    return np.asarray(total)
"""


def test_rpr004_flags_hot_path_only():
    assert "RPR004" in rules_hit(BAD_HOT_SYNC,
                                 path="src/repro/core/fixture.py")
    # the same code outside core/kernels/engine files is fine
    assert "RPR004" not in rules_hit(BAD_HOT_SYNC,
                                     path="src/repro/launch/fixture.py")


def test_rpr004_exempts_shape_arithmetic():
    src = """
def sizes(x):
    return int(x.shape[0]), float(len(x))
"""
    assert "RPR004" not in rules_hit(src)


def test_rpr004_inline_suppression():
    src = """
def boundary(x):
    return float(x)  # repro: allow=RPR004 scalars only cross to host
"""
    fs = linter.lint_source(src, "src/repro/core/fixture.py")
    assert any(f.rule == "RPR004" and f.suppressed for f in fs)
    assert not any(f.active for f in fs)


def test_rpr004_def_scoped_suppression():
    src = """
# repro: allow=RPR004 summarize is the host boundary
def summarize(m):
    return {"a": float(m.a), "b": int(m.b)}
"""
    fs = linter.lint_source(src, "src/repro/core/fixture.py")
    assert sum(f.rule == "RPR004" for f in fs) == 2
    assert not any(f.active for f in fs)


# ---------------------------------------------------------------------------
# RPR005 — dead code
# ---------------------------------------------------------------------------

BAD_DEAD_CODE = """
import os
import json

def f():
    return json.dumps({})
    print("never")
"""


def test_rpr005_flags_unused_import_and_unreachable():
    msgs = [f.message for f in linter.lint_source(
        BAD_DEAD_CODE, "x.py") if f.rule == "RPR005"]
    assert any("unused import 'os'" in m for m in msgs)
    assert any("unreachable" in m for m in msgs)


def test_rpr005_respects_noqa_and_type_checking():
    src = """
from typing import TYPE_CHECKING
from repro.api import run  # noqa: F401  (re-export)

if TYPE_CHECKING:
    from repro.core.cache import CacheMeta

def f(m: "CacheMeta"):
    return m
"""
    assert "RPR005" not in rules_hit(src)


# ---------------------------------------------------------------------------
# suppression parsing + baselines
# ---------------------------------------------------------------------------

def test_suppression_carries_reason():
    src = "x = float(y)  # repro: allow=RPR004 intentional transfer\n"
    supp = linter.Suppressions(src, __import__("ast").parse(src))
    assert supp.match("RPR004", 1) == "intentional transfer"
    assert supp.match("RPR001", 1) is None


def test_suppression_previous_line():
    src = ("# repro: allow=RPR004,RPR005 both fine here\n"
           "x = float(y)\n")
    supp = linter.Suppressions(src, __import__("ast").parse(src))
    assert supp.match("RPR004", 2) is not None
    assert supp.match("RPR005", 2) is not None


def test_baseline_roundtrip(tmp_path):
    fs = linter.lint_source(BAD_KEY_REUSE, "fixture.py")
    assert any(f.active for f in fs)
    path = str(tmp_path / "baseline.json")
    findings_lib.write_baseline(path, fs)
    fs2 = linter.lint_source(BAD_KEY_REUSE, "fixture.py")
    findings_lib.apply_baseline(fs2, findings_lib.load_baseline(path))
    assert all(not f.active for f in fs2)
    # a new finding is NOT covered by the old baseline
    fs3 = linter.lint_source(BAD_DONATE_READ, "other.py")
    findings_lib.apply_baseline(fs3, findings_lib.load_baseline(path))
    assert any(f.active for f in fs3)


def test_document_counts():
    fs = linter.lint_source(BAD_KEY_REUSE, "fixture.py")
    doc = findings_lib.to_document(fs, wall_s=0.5)
    assert doc["schema"] == findings_lib.SCHEMA
    assert doc["counts"]["active"] == len(fs)
    assert doc["counts"]["per_rule"].get("RPR001", 0) >= 1


# ---------------------------------------------------------------------------
# contract verifier (RPR101–RPR105): clean registries + injected breakage
# ---------------------------------------------------------------------------

def test_contracts_clean_on_repo():
    from repro.analysis import contracts
    fs = contracts.verify_all()
    assert fs == [], "\n".join(f.format() for f in fs)


def test_rpr101_catches_rows_dtype_drift(monkeypatch):
    import jax.numpy as jnp

    from repro.analysis import contracts
    from repro.mobility import base as mbase
    from repro.mobility import registry as mreg

    good = mreg.get_model("random_waypoint")
    def bad_rows(state, key, cfg, seconds, *, row_start, num_rows,
                 col_ids):
        state, met, dur = good.simulate_epoch_rows(
            state, key, cfg, seconds, row_start=row_start,
            num_rows=num_rows, col_ids=col_ids)
        return state, met.astype(jnp.int8), dur  # dtype drift
    bad = mbase.MobilityModel(
        name="random_waypoint", init=good.init, step=good.step,
        positions=good.positions, contacts_now=good.contacts_now,
        simulate_epoch=good.simulate_epoch, simulate_epoch_rows=bad_rows)
    monkeypatch.setattr(mreg, "available", lambda: ["random_waypoint"])
    monkeypatch.setattr(mreg, "get_model", lambda name: bad)
    fs = contracts.verify_mobility()
    assert any(f.rule == "RPR101" and "simulate_epoch_rows" in f.message
               for f in fs)


def test_rpr102_catches_priority_shape_drift(monkeypatch):
    import jax.numpy as jnp

    from repro.analysis import contracts
    from repro.policies import base as pbase
    from repro.policies import registry as preg

    bad = pbase.CachePolicy(
        "lru", lambda meta, ctx, valid: (meta.ts, jnp.zeros((), bool)))
    monkeypatch.setattr(preg, "available", lambda: ["lru"])
    monkeypatch.setattr(preg, "get_policy", lambda name: bad)
    fs = contracts.verify_policies()
    assert any(f.rule == "RPR102" and "keep mask" in f.message for f in fs)


def test_rpr103_catches_spec_drift(monkeypatch):
    from jax.sharding import PartitionSpec as P

    from repro.analysis import contracts
    from repro.telemetry import metrics as metrics_lib

    good = metrics_lib.shard_specs

    def bad_specs(axis):
        import dataclasses
        specs = good(axis)
        return dataclasses.replace(specs, origins_seen=P())  # wrong axis
    monkeypatch.setattr(metrics_lib, "shard_specs", bad_specs)
    fs = contracts.verify_spec_coverage()
    assert any(f.rule == "RPR103" for f in fs)


def test_rpr104_catches_losses_shape_drift(monkeypatch):
    from repro.analysis import contracts
    from repro.core import rounds as rounds_lib

    real = rounds_lib.make_fleet_engine

    def bad_engine(**kw):
        eng = real(**kw)
        run = eng.run
        class Wrapped:
            chunk = eng.chunk
            donate = eng.donate
            def run(self, *args):
                s, m, k, losses = run(*args)
                return s, m, k, losses[:1]  # wrong losses buffer
        return Wrapped()
    monkeypatch.setattr(rounds_lib, "make_fleet_engine", bad_engine)
    fs = contracts.verify_engines()
    assert any(f.rule == "RPR104" and "losses" in f.message for f in fs)


def test_rpr105_catches_missing_static_binding(monkeypatch):
    from repro.analysis import contracts
    from repro.fl import runner as runner_lib

    real = runner_lib._engine_key

    def bad_key(rs, chunk, traced_budget, telemetry=False):
        key = real(rs, chunk, traced_budget, telemetry)
        # drop the algorithm from the key: cells would share engines
        return tuple(k for k in key if k != rs.experiment.algorithm)
    monkeypatch.setattr(runner_lib, "_engine_key", bad_key)
    fs = contracts.verify_engine_key()
    assert any(f.rule == "RPR105" and "algorithm" in f.message
               for f in fs)


def test_traced_axes_literal_in_sync():
    from repro.fl import runner
    assert linter.DEFAULT_TRACED_AXES == runner.TRACED_AXES


# ---------------------------------------------------------------------------
# the tier-1 gate: the repo ships analyzer-clean
# ---------------------------------------------------------------------------

def test_self_run_zero_findings(tmp_path):
    """`python tools/analyze.py src/` exits 0 with zero active findings."""
    out = str(tmp_path / "findings.json")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "analyze.py"),
         "src", "--json", out],
        cwd=ROOT, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.load(open(out))
    assert doc["schema"] == findings_lib.SCHEMA
    assert doc["counts"]["active"] == 0, proc.stdout
    # every suppression in the tree carries a justification
    for f in doc["findings"]:
        if f["suppressed"]:
            assert f["reason"], f
