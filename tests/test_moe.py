"""MoE dispatch: sort-based capacity dispatch vs dense-routing oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import moe as moe_lib


def dense_moe_oracle(params, cfg, x):
    """Every expert applied to every token, combined by top-k gates."""
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    gate = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]))
    up = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    expert_out = jnp.einsum("bsef,efd->bsed", gate * up, params["w_down"])
    combine = jnp.zeros(probs.shape).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], top_e].set(top_w)
    return jnp.einsum("bse,bsed->bsd", combine, expert_out)


def test_no_drop_matches_dense_oracle(key):
    cfg = dataclasses.replace(R.get_smoke_config("mixtral-8x7b"),
                              moe_capacity_factor=float(4))  # no dropping
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = moe_lib.moe_ffn(p, cfg, x)
    y_ref = dense_moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens(key):
    """Tiny capacity must drop tokens (outputs zeroed), not crash."""
    cfg = dataclasses.replace(R.get_smoke_config("mixtral-8x7b"),
                              moe_capacity_factor=0.25)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    y, _ = moe_lib.moe_ffn(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    y_full, _ = moe_lib.moe_ffn(
        p, dataclasses.replace(cfg, moe_capacity_factor=4.0), x)
    # dropped-token output differs from the no-drop output
    assert float(jnp.max(jnp.abs(y - y_full))) > 1e-6


def test_aux_loss_balanced_router(key):
    """Uniform router -> aux ≈ 1; collapsed router -> aux ≈ E."""
    cfg = dataclasses.replace(R.get_smoke_config("grok-1-314b"))
    p = moe_lib.init_moe(key, cfg)
    p = jax.tree_util.tree_map(lambda x: x, p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    _, aux = moe_lib.moe_ffn(p, cfg, x)
    assert 0.8 < float(aux) < 1.3
    # collapse: positive inputs + one-hot router column -> expert 0 always
    x_pos = jnp.abs(x)
    p["router"] = p["router"].at[:, 0].set(10.0)
    _, aux_bad = moe_lib.moe_ffn(p, cfg, x_pos)
    assert float(aux_bad) > 2.0


def test_moe_grad_flows(key):
    cfg = dataclasses.replace(R.get_smoke_config("mixtral-8x7b"),
                              moe_capacity_factor=2.0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_lib.moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    norms = {k: float(jnp.linalg.norm(v.reshape(-1))) for k, v in g.items()}
    assert norms["w_gate"] > 0 and norms["router"] > 0, norms
