"""Encounter statistics on hand-built contact traces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MobilityConfig
from repro.mobility import registry, stats


def pair_trace(pattern, n=3, i=0, j=1):
    """[T, n, n] trace with the given on/off pattern on pair (i, j)."""
    T = len(pattern)
    seq = np.zeros((T, n, n), bool)
    seq[:, i, j] = seq[:, j, i] = np.asarray(pattern, bool)
    return jnp.asarray(seq)


def test_single_pair_counts_and_durations():
    # contact t=1..2, gap t=3..4, contact t=5: 2 encounters, 3 contact steps
    seq = pair_trace([0, 1, 1, 0, 0, 1])
    s = stats.encounter_stats(seq, step_seconds=2.0)
    counts = np.asarray(s["encounter_counts"])
    assert counts[0, 1] == counts[1, 0] == 2
    assert counts.sum() == 4            # both triangles
    # meeting rate: 4 encounter-endpoints / (3 agents * 6 steps * 2 s)
    assert np.isclose(float(s["meeting_rate"]), 4 / (3 * 6 * 2.0))
    # mean duration over COMPLETED contacts only: the t=1..2 contact ended
    # (2 steps * 2 s); the t=5 contact is right-censored and excluded
    assert np.isclose(float(s["mean_contact_duration"]), 4.0)
    assert int(s["completed_contacts"]) == 2       # one per triangle
    assert int(s["censored_contacts"]) == 2
    assert int(s["censored_contact_steps"]) == 2


def test_right_censored_contact_excluded_from_duration():
    """Regression: a contact spanning the window edge must not skew the
    mean (the old code put its steps in the numerator while the
    denominator only counted started encounters)."""
    # single contact starting at t=2 and still active at the last frame
    seq = pair_trace([0, 0, 1, 1, 1])
    s = stats.encounter_stats(seq)
    assert float(s["mean_contact_duration"]) == 0.0   # nothing completed
    assert int(s["completed_contacts"]) == 0
    assert int(s["censored_contacts"]) == 2           # both triangles
    assert int(s["censored_contact_steps"]) == 6      # 3 steps x 2
    # the encounter itself still counts (rising edge in-window)
    assert int(np.asarray(s["encounter_counts"])[0, 1]) == 1


def test_completed_and_censored_mix():
    # one completed 2-step contact, then a censored 2-step contact
    seq = pair_trace([1, 1, 0, 0, 1, 1])
    s = stats.encounter_stats(seq, step_seconds=1.0)
    assert np.isclose(float(s["mean_contact_duration"]), 2.0)
    assert int(s["completed_contacts"]) == 2          # both triangles
    assert int(s["censored_contacts"]) == 2
    assert int(s["censored_contact_steps"]) == 4


def test_inter_contact_gap():
    # falling edge at t=3, next rising edge at t=5 -> gap of 2 steps
    seq = pair_trace([0, 1, 1, 0, 0, 1])
    s = stats.encounter_stats(seq, step_seconds=1.0)
    hist = np.asarray(s["inter_contact_hist"])
    assert hist[2] == 2 and hist.sum() == 2   # one gap per triangle
    assert np.isclose(float(s["mean_inter_contact"]), 2.0)
    cdf = np.asarray(s["inter_contact_cdf"])
    assert np.isclose(cdf[-1], 1.0)
    assert (np.diff(cdf) >= -1e-9).all()


def test_leading_and_trailing_gaps_censored():
    # contact only at t=2: no interior gaps at all
    seq = pair_trace([0, 0, 1, 0, 0])
    s = stats.encounter_stats(seq)
    assert int(np.asarray(s["inter_contact_hist"]).sum()) == 0
    assert float(s["mean_inter_contact"]) == 0.0
    assert int(np.asarray(s["encounter_counts"])[0, 1]) == 1


def test_no_contacts_all_zero():
    seq = jnp.zeros((10, 4, 4), bool)
    s = stats.encounter_stats(seq)
    assert float(s["meeting_rate"]) == 0.0
    assert float(s["contact_fraction"]) == 0.0
    assert float(s["mean_contact_duration"]) == 0.0


def test_diagonal_ignored():
    seq = jnp.tile(jnp.eye(4, dtype=bool)[None], (5, 1, 1))
    s = stats.encounter_stats(seq)
    assert float(s["meeting_rate"]) == 0.0


def test_stats_jit_and_collect():
    cfg = MobilityConfig(model="random_waypoint", area_w=300.0, area_h=300.0)
    model = registry.get_model("random_waypoint")
    state = model.init(jax.random.PRNGKey(0), 8, cfg)
    _, seq = stats.collect_contacts(model, state, jax.random.PRNGKey(1),
                                    cfg, n_steps=40)
    assert seq.shape == (40, 8, 8)
    jitted = jax.jit(lambda s: stats.encounter_stats(s, 1.0))
    out = jitted(seq)
    assert np.isfinite(float(out["meeting_rate"]))
    assert 0.0 <= float(out["contact_fraction"]) <= 1.0
