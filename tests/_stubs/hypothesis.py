"""Minimal stand-in for the `hypothesis` API used by this test suite.

The container image does not ship hypothesis, which previously made the
whole tier-1 suite fail at collection. This shim implements exactly the
surface the tests use — ``given``/``settings`` and the ``integers``,
``sampled_from``, ``floats``, ``booleans``, ``data`` strategies — by
running each property a fixed number of times with seeded pseudo-random
examples. It is only importable because ``conftest.py`` adds this
directory to ``sys.path`` when the real package is missing; with
hypothesis installed, the real one wins and this file is inert.

No shrinking, no example database — a failing example is reported via the
test's own assertion message.
"""
from __future__ import annotations

import functools
import random
import types

__version__ = "0.0-stub"
_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


class _DataObject:
    def __init__(self, rnd):
        self._rnd = rnd

    def draw(self, strategy, label=None):
        return strategy._sample(self._rnd)


def _data():
    s = _Strategy(None)
    s._is_data = True
    return s


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, booleans=_booleans,
    sampled_from=_sampled_from, data=_data)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise NotImplementedError("stub hypothesis: use keyword strategies")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES))
            rnd = random.Random(1234)
            for _ in range(n):
                drawn = {}
                for name, strat in kw_strategies.items():
                    if getattr(strat, "_is_data", False):
                        drawn[name] = _DataObject(rnd)
                    else:
                        drawn[name] = strat._sample(rnd)
                fn(*args, **kwargs, **drawn)
        # keep pytest from following __wrapped__ to fn's signature and
        # treating strategy params as fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
