"""Sharded fleet engine (shard_map over the ``agents`` axis):
equivalence with the single-device fused engine, the single-trace
discipline under sharding, Scenario-level validation/memory guards, and
a forced-host-device smoke for real multi-shard execution.

Multi-device cases run in a subprocess because
``--xla_force_host_platform_device_count`` must enter XLA_FLAGS before
jax initializes; everything else runs on the in-process single device
(a 1-device mesh exercises the full shard_map path, windowing and
collectives included).

Tolerances: cached/dfl under the sharded engine are bit-exact with the
fused engine by construction (per-agent keys are generated at global N
and sliced, gossip candidates differ only in integer indexing); the
accuracy comparison still uses the engine-test atol=2e-3 to absorb eval
FP noise under the budgeted path. cfl averages via a psum of per-shard
partial sums, so its FP summation order differs by design — same atol.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import api
from repro.configs.base import DFLConfig, MobilityConfig
from repro.fl.experiment import build_fleet, make_sharded_engine
from repro.fl.scenario import ExperimentConfig
from repro.launch.mesh import make_fleet_mesh

FAST = dict(
    dfl=DFLConfig(num_agents=6, cache_size=3, tau_max=10, local_steps=2,
                  lr=0.1, batch_size=16, epoch_seconds=30.0),
    mobility=MobilityConfig(grid_w=4, grid_h=6),
    epochs=4,
    eval_every=2,
    n_train=400,
    n_test=100,
    image_hw=12,
    lr_plateau=False,
)

MOBILITIES = {
    "manhattan": MobilityConfig(grid_w=4, grid_h=6),
    "community": MobilityConfig(model="community", area_w=300.0,
                                area_h=300.0),
}


def _scenario(algorithm="cached", mobility="manhattan", **kw):
    merged = {**FAST, "mobility": MOBILITIES[mobility], **kw}
    exp = ExperimentConfig(algorithm=algorithm, **merged)
    return api.Scenario(experiment=exp)


# ---------------------------------------------------------------------------
# sharded (1-device mesh) vs fused: same trajectory
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["cached", "dfl", "cfl"])
@pytest.mark.parametrize("mobility", ["manhattan", "community"])
def test_sharded_matches_fused_trajectory(algorithm, mobility):
    base = _scenario(algorithm, mobility)
    fused = api.run(dataclasses.replace(base, engine="fused",
                                        record_cache_stats=True))
    sharded = api.run(dataclasses.replace(base, engine="sharded", mesh=1,
                                          record_cache_stats=True))
    assert fused.epoch == sharded.epoch
    np.testing.assert_allclose(fused.acc, sharded.acc, atol=2e-3)
    np.testing.assert_allclose(fused.cache_num, sharded.cache_num,
                               atol=1e-5)
    np.testing.assert_allclose(fused.cache_age, sharded.cache_age,
                               atol=1e-4)
    assert fused.traces == 1
    assert sharded.traces == 1


@pytest.mark.slow
def test_sharded_budgeted_telemetry_matches_fused():
    """The budget admission path + telemetry counters reduce to the same
    global values under sharding (psum-folded per epoch)."""
    base = _scenario("cached", dfl=dataclasses.replace(
        FAST["dfl"], transfer_budget=2.0, link_entries_per_step=0.1))
    base = dataclasses.replace(base, telemetry=True)
    fused = api.run(dataclasses.replace(base, engine="fused"))
    sharded = api.run(dataclasses.replace(base, engine="sharded", mesh=1))
    np.testing.assert_allclose(fused.acc, sharded.acc, atol=2e-3)
    ff, sf = fused.telemetry["fleet"], sharded.telemetry["fleet"]
    for k in ("epochs", "staleness_hist", "offered", "admitted",
              "link_capacity", "capped_links", "contacts", "spread_mean",
              "reach_fraction"):
        assert ff[k] == sf[k], f"telemetry {k}: fused {ff[k]} != {sf[k]}"


# ---------------------------------------------------------------------------
# compile discipline under sharding
# ---------------------------------------------------------------------------

def test_sharded_engine_single_trace():
    """lr + epoch budget + transfer budget stay traced: one trace total."""
    cfg = _scenario("cached").experiment
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    from repro.models import cnn as cnn_lib
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    eng = make_sharded_engine(cfg, mesh=make_fleet_mesh(1), loss_fn=loss_fn,
                              mob_model=mob_model, mob_cfg=mob_cfg,
                              group_slots=group_slots, chunk=2)
    key = jax.random.PRNGKey(3)
    state, mstate, key, losses = eng.run(state, mstate, key, 0.1, data,
                                         counts, 2)
    assert eng.traces == 1
    assert np.isfinite(np.asarray(losses)).all()
    state, mstate, key, losses = eng.run(state, mstate, key, 0.05, data,
                                         counts, 1)
    assert eng.traces == 1
    losses = np.asarray(losses)
    assert np.isfinite(losses[0]) and np.isnan(losses[1])


# ---------------------------------------------------------------------------
# validation / guards
# ---------------------------------------------------------------------------

def test_sharded_rejects_random_partner_sample():
    s = dataclasses.replace(_scenario("cached"), engine="sharded")
    s = s.with_overrides({"partner_sample": "random"})
    with pytest.raises(ValueError, match="partner_sample"):
        s.resolve()


def test_sharded_builder_validation():
    cfg = _scenario("cached").experiment
    (model_cfg, state, data, counts, _tb, mstate,
     group_slots, mob_model, mob_cfg) = build_fleet(cfg)
    from repro.models import cnn as cnn_lib
    loss_fn = lambda p, b: cnn_lib.loss_fn(p, model_cfg, b["images"],
                                           b["labels"])
    with pytest.raises(ValueError, match="halo"):
        make_sharded_engine(
            dataclasses.replace(cfg, dfl=dataclasses.replace(
                cfg.dfl, shard_halo=-1)),
            mesh=make_fleet_mesh(1), loss_fn=loss_fn,
            mob_model=mob_model, mob_cfg=mob_cfg)
    with pytest.raises(ValueError, match="lowest-id"):
        make_sharded_engine(
            dataclasses.replace(cfg, partner_sample="random"),
            mesh=make_fleet_mesh(1), loss_fn=loss_fn,
            mob_model=mob_model, mob_cfg=mob_cfg)
    with pytest.raises(ValueError, match="visible"):
        make_fleet_mesh(jax.device_count() + 64)


def test_memory_guard_names_the_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_FLEET_MEM_GB", "0.001")
    with pytest.raises(ValueError) as e:
        _scenario("cached").resolve()
    msg = str(e.value)
    for needle in ("dfl.num_agents", "dfl.cache_size", "sharded",
                   "REPRO_FLEET_MEM_GB"):
        assert needle in msg
    monkeypatch.setenv("REPRO_FLEET_MEM_GB", "0")
    _scenario("cached").resolve()          # 0 disables the guard


# ---------------------------------------------------------------------------
# real multi-shard execution (forced host devices; subprocess)
# ---------------------------------------------------------------------------

_SUBPROCESS_PROG = textwrap.dedent("""
    import dataclasses
    import jax
    assert jax.device_count() == 4, jax.device_count()
    from repro import api
    from repro.configs.base import DFLConfig, MobilityConfig
    from repro.fl.scenario import ExperimentConfig

    exp = ExperimentConfig(
        algorithm="{algorithm}",
        dfl=DFLConfig(num_agents=8, cache_size=3, tau_max=10, local_steps=2,
                      lr=0.1, batch_size=16, epoch_seconds=30.0,
                      shard_halo={halo}),
        mobility=MobilityConfig(grid_w=4, grid_h=6),
        epochs=4, eval_every=2, n_train=400, n_test=100, image_hw=12,
        lr_plateau=False)
    base = api.Scenario(experiment=exp)
    fused = api.run(dataclasses.replace(base, engine="fused"))
    sharded = api.run(dataclasses.replace(base, engine="sharded", mesh=4))
    assert sharded.traces == 1, sharded.traces
    d = max(abs(a - b) for a, b in zip(fused.acc, sharded.acc))
    if {halo} == 0:
        assert d <= 2e-3, (fused.acc, sharded.acc)   # exact window mode
    print("OK", d)
""")


@pytest.mark.slow
@pytest.mark.parametrize("algorithm,halo", [("cached", 0), ("dfl", 0),
                                            ("cfl", 0), ("cached", 2)])
def test_sharded_multi_device_subprocess(algorithm, halo):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    prog = _SUBPROCESS_PROG.format(algorithm=algorithm, halo=halo)
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert proc.stdout.startswith("OK"), proc.stdout
