"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device; only the dry-run subprocess creates 512."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # the container lacks hypothesis; fall back to the minimal stub so the
    # property tests still run (seeded examples, no shrinking)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
