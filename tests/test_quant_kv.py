"""Int8 KV-cache decode: quantization roundtrip + kernel vs f32 oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.quant_kv import (decode_attention_quant, dequantize_kv,
                                    quantize_kv)


def test_quant_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 32)) * 3.0
    q, s = quantize_kv(x)
    err = jnp.max(jnp.abs(dequantize_kv(q, s) - x))
    # symmetric int8: per-row error <= scale/2 = amax/254
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0


@pytest.mark.parametrize("B,S,KV,G,hd,win",
                         [(2, 700, 2, 4, 64, 0),
                          (1, 300, 1, 8, 128, 0),
                          (1, 1024, 2, 2, 64, 256)])
def test_quant_decode_close_to_f32(B, S, KV, G, hd, win):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    length = jnp.asarray(S - 11, jnp.int32)
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    out = decode_attention_quant(q, k_q, k_s, v_q, v_s, length,
                                 window=win, block_s=256)
    exp = ref.decode_attention_ref(q, k, v, length, window=win)
    # int8 KV error bound: ~1% of output scale
    err = float(jnp.max(jnp.abs(out - exp)))
    assert err < 5e-2, err


def test_quant_matches_dequantized_exact():
    """Kernel(int8) must equal oracle(dequantized int8) to float tolerance
    — isolates kernel bugs from quantization error."""
    B, S, KV, G, hd = 1, 256, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    length = jnp.asarray(200, jnp.int32)
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    out = decode_attention_quant(q, k_q, k_s, v_q, v_s, length, block_s=128)
    exp = ref.decode_attention_ref(q, dequantize_kv(k_q, k_s).astype(jnp.float32),
                                   dequantize_kv(v_q, v_s).astype(jnp.float32),
                                   length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_kv_quant_decode_end_to_end():
    """cfg.kv_quant: int8 cache decode must track the f32 forward closely."""
    import dataclasses
    from repro.configs import registry as R
    from repro.models import registry as M
    from repro.models import transformer as T

    key = jax.random.PRNGKey(0)
    cfg = dataclasses.replace(R.get_smoke_config("internlm2-1.8b"),
                              compute_dtype="float32", kv_quant=True)
    p = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 20), 0, cfg.vocab)
    full, _ = T.forward(p, cfg, toks)
    _, state = T.prefill(p, cfg, toks[:, :12], max_len=28)
    assert state.k.dtype == jnp.int8 and state.k_scale is not None
    outs = []
    for t in range(12, 20):
        lg, state = T.decode_step(p, cfg, state, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full[:, 12:20])))
    assert err < 0.35, err
