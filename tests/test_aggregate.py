"""ModelAggregation (Alg. 1): weights, tree path, flat/Pallas path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cache as C
from repro.core.aggregate import aggregate, aggregate_flat, aggregation_weights


def test_weights_normalized():
    w_self, w_cache = aggregation_weights(
        10.0, jnp.asarray([5.0, 5.0, 7.0]), jnp.asarray([1.0, 1.0, 0.0]))
    assert np.isclose(float(w_self + jnp.sum(w_cache)), 1.0)
    assert float(w_cache[2]) == 0.0  # invalid slot excluded


def test_aggregate_matches_manual():
    params = {"w": jnp.ones((4,)) * 2.0}
    cache = C.init_cache(params, 2)
    cache = C.insert(cache, {"w": jnp.ones((4,)) * 8.0}, t=0, origin=1,
                     samples=30.0, group=0, tau_max=10)
    out = aggregate(params, 10.0, cache)
    # (10*2 + 30*8) / 40 = 6.5
    np.testing.assert_allclose(np.asarray(out["w"]), 6.5, rtol=1e-6)


def test_aggregate_empty_cache_is_identity():
    params = {"w": jnp.arange(6.0)}
    cache = C.init_cache(params, 3)
    out = aggregate(params, 5.0, cache)
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(6.0),
                               rtol=1e-6)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(C_=st.integers(1, 8), D=st.integers(1, 300), seed=st.integers(0, 99))
def test_flat_kernel_matches_tree(C_, D, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    flat_params = jax.random.normal(k1, (D,))
    flat_cache = jax.random.normal(k2, (C_, D))
    samples = jax.random.uniform(k3, (C_,), minval=0.1)
    valid = (jax.random.uniform(k4, (C_,)) > 0.4)
    out_kernel = aggregate_flat(flat_params, flat_cache, 1.0, samples,
                                valid, use_kernel=True)
    out_ref = aggregate_flat(flat_params, flat_cache, 1.0, samples,
                             valid, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


def test_fleet_vectorized_aggregate():
    N, cap = 3, 2
    params = {"w": jnp.stack([jnp.full((4,), float(i)) for i in range(N)])}
    cache = C.init_cache({"w": jnp.zeros((4,))}, cap)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape).copy(), cache)
    samples = jnp.ones((N,))
    out = aggregate(params, samples, cache)  # empty caches -> identity
    np.testing.assert_allclose(np.asarray(out["w"][2]), 2.0, rtol=1e-6)
