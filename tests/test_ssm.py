"""SSD (Mamba2) mixer: chunked scan vs step-by-step recurrence oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import ssm as S


def cfg_with(chunk):
    return dataclasses.replace(R.get_smoke_config("mamba2-780m"),
                               ssm_chunk=chunk)


@pytest.mark.parametrize("S_len", [1, 7, 32, 33, 100])
def test_chunked_matches_recurrence(S_len, key):
    cfg = cfg_with(16)
    p = S.init_ssm(key, cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, S_len, cfg.d_model)) * 0.5
    y_chunk, _ = S.ssm_forward(p, cfg, u)
    y_ref = S.ssm_reference(p, cfg, u)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)


def test_chunk_size_invariance(key):
    """Output must not depend on the chunk size (pure reformulation)."""
    p = S.init_ssm(key, cfg_with(8))
    u = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 128)) * 0.5
    outs = []
    for chunk in (8, 16, 64):
        y, _ = S.ssm_forward(p, cfg_with(chunk), u)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)


def test_final_state_continues_decode(key):
    """h_final from the chunked scan must seed step decode exactly."""
    cfg = cfg_with(16)
    p = S.init_ssm(key, cfg)
    u = jax.random.normal(jax.random.PRNGKey(3), (1, 40, cfg.d_model)) * 0.5
    y_full, _ = S.ssm_forward(p, cfg, jnp.concatenate(
        [u, u[:, -1:]], axis=1))
    _, h = S.ssm_forward(p, cfg, u)
    y_step, _ = S.ssm_decode_step(p, cfg, u[:, -1:], h)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_decay_is_stable():
    """A must be negative -> per-step decay in (0, 1]: no state blowup."""
    cfg = cfg_with(16)
    p = S.init_ssm(jax.random.PRNGKey(4), cfg)
    u = jax.random.normal(jax.random.PRNGKey(5), (1, 256, cfg.d_model))
    y, h = S.ssm_forward(p, cfg, u)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(h)))
