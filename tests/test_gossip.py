"""Fleet-level cache exchange (DTN model spreading) semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as C
from repro.core import gossip


def fleet_params(N, scale=1.0):
    return {"w": jnp.arange(N, dtype=jnp.float32)[:, None] * scale
            * jnp.ones((N, 4))}


def empty_fleet_cache(N, cap):
    c = C.init_cache({"w": jnp.zeros((4,))}, cap)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape).copy(), c)


def test_exchange_fetches_partner_model():
    N, cap = 4, 3
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    partners = jnp.asarray([[1], [0], [-1], [-1]], jnp.int32)
    samples = jnp.ones((N,)) * 10
    group = jnp.zeros((N,), jnp.int32)
    out = gossip.exchange(params, cache, partners, 0, samples, group,
                          tau_max=10, policy="lru")
    # agent 0 now caches agent 1's model (value 1.0) and vice versa
    assert int(out.origin[0, 0]) == 1
    assert float(out.models["w"][0, 0, 0]) == 1.0
    assert int(out.origin[1, 0]) == 0
    # isolated agents keep empty caches
    assert int(jnp.sum(out.valid[2])) == 0


def test_exchange_spreads_cached_models_two_hops():
    """i gets j's cache contents: models travel multiple hops over epochs."""
    N, cap = 3, 2
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    # epoch 0: 1 meets 2 -> agent 1 caches model 2
    p01 = jnp.asarray([[-1], [2], [1]], jnp.int32)
    cache = gossip.exchange(params, cache, p01, 0, samples, group,
                            tau_max=10, policy="lru")
    # epoch 1: 0 meets 1 -> agent 0 gets model 1 AND cached model 2
    p10 = jnp.asarray([[1], [0], [-1]], jnp.int32)
    cache = gossip.exchange(params, cache, p10, 1, samples, group,
                            tau_max=10, policy="lru")
    origins0 = set(np.asarray(cache.origin[0]).tolist()) - {-1}
    assert origins0 == {1, 2}
    # the relayed copy of model 2 keeps its ORIGINAL timestamp (staleness!)
    idx2 = int(np.argwhere(np.asarray(cache.origin[0]) == 2)[0, 0])
    assert int(cache.ts[0, idx2]) == 0


def test_exchange_stale_kickout():
    N, cap = 2, 2
    params = fleet_params(N)
    cache = empty_fleet_cache(N, cap)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    p = jnp.asarray([[1], [0]], jnp.int32)
    cache = gossip.exchange(params, cache, p, 0, samples, group,
                            tau_max=5, policy="lru")
    # far in the future with no refresh: entries must be kicked out
    none = jnp.asarray([[-1], [-1]], jnp.int32)
    cache = gossip.exchange(params, cache, none, 20, samples, group,
                            tau_max=5, policy="lru")
    assert int(jnp.sum(cache.valid)) == 0


def test_exchange_newest_copy_wins():
    """When both sides hold copies of the same origin, keep the freshest."""
    N, cap = 3, 2
    params = fleet_params(N)
    samples = jnp.ones((N,))
    group = jnp.zeros((N,), jnp.int32)
    cache = empty_fleet_cache(N, cap)
    # agent0 caches model2@t=0; agent1 meets 2 at t=3 (fresher copy)
    cache = gossip.exchange(params, cache, jnp.asarray([[2], [-1], [0]]),
                            0, samples, group, tau_max=100, policy="lru")
    cache = gossip.exchange(params, cache, jnp.asarray([[-1], [2], [1]]),
                            3, samples, group, tau_max=100, policy="lru")
    # t=4: 0 meets 1 -> 0 should hold model2 with ts=3, not ts=0
    cache = gossip.exchange(params, cache, jnp.asarray([[1], [0], [-1]]),
                            4, samples, group, tau_max=100, policy="lru")
    o0 = np.asarray(cache.origin[0])
    ts0 = np.asarray(cache.ts[0])
    idx = np.argwhere(o0 == 2)
    assert len(idx) == 1
    assert int(ts0[idx[0, 0]]) == 3


def test_all_policies_run():
    """Every cache-update policy must execute through the fleet exchange."""
    import jax
    N, cap = 4, 2
    params = fleet_params(N)
    samples = jnp.ones((N,))
    group = jnp.asarray([0, 0, 1, 1], jnp.int32)
    partners = jnp.asarray([[1], [0], [3], [2]], jnp.int32)
    for policy in ("lru", "fifo", "random", "group"):
        cache = empty_fleet_cache(N, cap)
        out = gossip.exchange(
            params, cache, partners, 0, samples, group, tau_max=10,
            policy=policy,
            group_slots=jnp.asarray([1, 1], jnp.int32),
            rng=jax.random.PRNGKey(0))
        assert int(jnp.sum(out.valid)) >= N  # every agent cached someone


def test_exchange_invariants_random_contact_graphs():
    """Property: after arbitrary contact sequences — caches never exceed
    capacity, hold ≤1 entry per origin, and never violate τ_max."""
    import jax
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 999), epochs=st.integers(1, 5),
           tau_max=st.integers(1, 6))
    def prop(seed, epochs, tau_max):
        N, cap = 5, 3
        key = jax.random.PRNGKey(seed)
        params = fleet_params(N)
        cache = empty_fleet_cache(N, cap)
        samples = jnp.ones((N,))
        group = jnp.zeros((N,), jnp.int32)
        for t in range(epochs):
            key, k = jax.random.split(key)
            met = jax.random.bernoulli(k, 0.4, (N, N))
            met = met & met.T & ~jnp.eye(N, dtype=bool)
            from repro.mobility.manhattan import partners_from_contacts
            partners = partners_from_contacts(met, 2)
            cache = gossip.exchange(params, cache, partners, t, samples,
                                    group, tau_max=tau_max, policy="lru")
            valid = np.asarray(cache.valid)
            ts = np.asarray(cache.ts)
            origin = np.asarray(cache.origin)
            assert valid.sum(axis=1).max() <= cap
            for i in range(N):
                origins_i = origin[i][valid[i]]
                assert len(set(origins_i.tolist())) == len(origins_i)
                assert ((t - ts[i][valid[i]]) < tau_max).all()

    prop()
