"""Perf-variant correctness: the §Perf sharding/numeric knobs must not
change model semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.launch import specs as specs_lib
from repro.models import moe as moe_lib
from repro.sharding.context import use_mesh
from repro.sharding.rules import ShardingRules, param_specs

AXES = {"model": 16, "data": 16}


@pytest.mark.parametrize("arch", R.ARCH_IDS)
def test_pure_fsdp_specs_divisible(arch):
    cfg = R.get_config(arch)
    shapes = specs_lib.param_shapes(cfg)
    rules = ShardingRules(model_size=16, data_size=16, pure_fsdp=True)
    specs = param_specs(cfg, shapes, rules)
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for x, spec in zip(flat_s, flat_p):
        for dim, axis in zip(x.shape, spec):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for n in names:
                size *= AXES[n]
            assert dim % size == 0, (arch, x.shape, spec)


def test_moe_shard_map_matches_gspmd(key):
    """The shard_map-local dispatch must be numerically identical to the
    GSPMD path (validated on a 1x1 mesh, same code path as production)."""
    cfg = dataclasses.replace(R.get_smoke_config("mixtral-8x7b"),
                              moe_capacity_factor=4.0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5

    y_ref, aux_ref = moe_lib.moe_ffn(p, cfg, x)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_sm = dataclasses.replace(cfg, moe_shard_map=True)
    with use_mesh(mesh):
        y_sm, aux_sm = jax.jit(
            lambda p, x: moe_lib.moe_ffn(p, cfg_sm, x))(p, x)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=1e-3)


def test_microbatch_grads_match_full_batch(key):
    """Gradient accumulation must reproduce the full-batch SGD step."""
    from repro.launch import steps as steps_lib
    cfg = dataclasses.replace(R.get_smoke_config("internlm2-1.8b"),
                              compute_dtype="float32")
    params = __import__("repro.models.registry",
                        fromlist=["init_params"]).init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab)}
    p1, l1 = steps_lib.local_sgd_step(params, batch, cfg, lr=0.1)
    p2, l2 = steps_lib.local_sgd_step(params, batch, cfg, lr=0.1,
                                      microbatches=2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_remat_policy_same_loss(key):
    from repro.models import registry as M
    cfg = dataclasses.replace(R.get_smoke_config("qwen2-7b"),
                              compute_dtype="float32")
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    base = float(M.loss_fn(params, cfg, batch, remat=False))
    full = float(M.loss_fn(params, cfg, batch, remat=True))
    cfg_dots = dataclasses.replace(cfg, remat_policy="dots")
    dots = float(M.loss_fn(params, cfg_dots, batch, remat=True))
    np.testing.assert_allclose(base, full, rtol=1e-6)
    np.testing.assert_allclose(base, dots, rtol=1e-6)
