"""End-to-end behaviour tests for the whole system: the pod-scale DFL
round (the paper's technique on production models), the optimizer/schedule
substrate, and a scaled-down dry-run in a subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.launch import steps as steps_lib
from repro.models import registry as M
from repro.optim.schedules import ReduceLROnPlateau
from repro.optim.sgd import sgd_init, sgd_update

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_pod_multi_agent_round(key):
    """Multi-agent Cached-DFL round (the multi-pod step) on CPU."""
    cfg = R.get_smoke_config("internlm2-1.8b")
    A = 2
    params = jax.vmap(lambda k: M.init_params(cfg, k))(
        jax.random.split(key, A))
    cache = steps_lib.init_pod_cache(cfg, M.init_params(cfg, key), 2,
                                     agents=A)
    step = steps_lib.make_train_step(cfg, lr=0.1, multi_pod=True, tau_max=5)
    batch = {"tokens": jax.random.randint(key, (A, 2, 16), 0, cfg.vocab)}
    params, cache, loss = step(params, cache, batch,
                               jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(loss))
    # each agent's cache holds its partner's model
    origins = np.asarray(cache.origin)
    assert origins[0, 0] == 1 and origins[1, 0] == 0
    # cached models differ between agents (they hold each other's weights)
    w0 = np.asarray(jax.tree_util.tree_leaves(cache.models)[0][0, 0])
    w1 = np.asarray(jax.tree_util.tree_leaves(cache.models)[0][1, 0])
    assert not np.allclose(w0, w1)


def test_pod_round_staleness_kickout(key):
    cfg = R.get_smoke_config("internlm2-1.8b")
    A = 2
    params = jax.vmap(lambda k: M.init_params(cfg, k))(
        jax.random.split(key, A))
    cache = steps_lib.init_pod_cache(cfg, M.init_params(cfg, key), 2,
                                     agents=A)
    step = steps_lib.make_train_step(cfg, lr=0.1, multi_pod=True, tau_max=3)
    batch = {"tokens": jax.random.randint(key, (A, 2, 16), 0, cfg.vocab)}
    params, cache, _ = step(params, cache, batch, jnp.asarray(0, jnp.int32))
    assert int(jnp.sum(cache.valid)) == 2
    # long silence: entries inserted at t=0 are stale at t=10
    from repro.core.cache import evict_stale
    cache2 = jax.vmap(lambda c: evict_stale(c, 10, 3))(cache)
    assert int(jnp.sum(cache2.valid)) == 0


def test_sgd_momentum_and_schedule():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    state = sgd_init(params, momentum=0.9)
    p1, state = sgd_update(params, grads, state, lr=0.1, momentum=0.9)
    p2, state = sgd_update(p1, grads, state, lr=0.1, momentum=0.9)
    # momentum accelerates: second step bigger than first
    step1 = float(jnp.abs(params["w"][0] - p1["w"][0]))
    step2 = float(jnp.abs(p1["w"][0] - p2["w"][0]))
    assert step2 > step1

    sched = ReduceLROnPlateau(lr=1.0, patience=1, factor=0.5)
    assert sched.update(0.5) == 1.0   # improves
    assert sched.update(0.5) == 1.0   # bad 1
    assert sched.update(0.5) == 0.5   # bad 2 -> reduce


@pytest.mark.slow
def test_dryrun_subprocess_small():
    """The dry-run entrypoint end-to-end on a reduced config (2 layers,
    no extrapolation) — proves the mesh path works."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "internlm2-1.8b", "--shape", "decode_32k",
         "--mesh", "single", "--layers", "2", "--no-extrapolate",
         "--out", ""],
        capture_output=True, text=True, env=env, timeout=420,
        cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "[ok]" in out.stdout
