"""SSD intra-chunk Pallas kernel vs the pure-jnp chunked-scan math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ssd_chunk_intra
from repro.models.ssm import _segsum


def intra_reference(x, dt, Bm, Cm, log_a):
    """Direct jnp transcription of the intra-chunk terms (ssm.ssd_chunked)."""
    log_a_t = log_a.transpose(0, 1, 3, 2)             # [B, nc, H, T]
    seg = _segsum(log_a_t)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Cm * 0 + Bm)
    att = jnp.exp(seg) * cb[:, :, None, :, :]
    att = att * dt.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y = jnp.einsum("bchij,bcjhp->bcihp", att, x)
    cum = jnp.cumsum(log_a_t, axis=-1)
    w = jnp.exp(cum[..., -1:] - cum) * dt.transpose(0, 1, 3, 2)
    s = jnp.einsum("bchj,bcjhp,bcjn->bchpn", w, x, Bm)
    return y, s


@pytest.mark.parametrize("Bsz,nc,T,H,P,N",
                         [(1, 2, 32, 2, 32, 16),
                          (2, 1, 64, 3, 64, 32),
                          (1, 3, 16, 1, 32, 64)])
def test_ssd_kernel_matches_reference(Bsz, nc, T, H, P, N):
    ks = jax.random.split(jax.random.PRNGKey(T + P), 5)
    x = jax.random.normal(ks[0], (Bsz, nc, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, nc, T, H)))
    Bm = jax.random.normal(ks[2], (Bsz, nc, T, N)) * 0.5
    Cm = jax.random.normal(ks[3], (Bsz, nc, T, N)) * 0.5
    log_a = -jax.nn.softplus(jax.random.normal(ks[4], (Bsz, nc, T, H)))
    y_k, s_k = ssd_chunk_intra(x, dt, Bm, Cm, log_a)
    y_r, s_r = intra_reference(x, dt, Bm, Cm, log_a)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_full_mixer_equivalence(key):
    """Swap the kernel into the full SSD mixer: must match ssm_forward."""
    import dataclasses
    from repro.configs import registry as R
    from repro.models import ssm as S

    cfg = dataclasses.replace(R.get_smoke_config("mamba2-780m"),
                              ssm_chunk=16)
    p = S.init_ssm(key, cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model)) * 0.5
    y_ref, h_ref = S.ssm_forward(p, cfg, u)

    # kernel-backed recomputation of the intra terms + jnp inter-chunk scan
    z, x, Bm, Cm, dt, A = S._project(p, cfg, u)
    Bsz, Sq = u.shape[:2]
    T = cfg.ssm_chunk
    nc = Sq // T
    d_inner, H, P, N = S.ssm_dims(cfg)
    xc = x.reshape(Bsz, nc, T, H, P)
    dtc = dt.reshape(Bsz, nc, T, H)
    Bc = Bm.reshape(Bsz, nc, T, N)
    Cc = Cm.reshape(Bsz, nc, T, N)
    log_a = dtc * A
    y_intra, s_chunk = ssd_chunk_intra(xc, dtc, Bc, Cc, log_a)

    cum = jnp.cumsum(log_a.transpose(0, 1, 3, 2), axis=-1)
    a_chunk = jnp.exp(cum[..., -1])

    def scan_fn(h, inp):
        a_c, s_c = inp
        return h * a_c[..., None, None] + s_c, h

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_last, h_in = jax.lax.scan(
        scan_fn, h0, (a_chunk.transpose(1, 0, 2),
                      s_chunk.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)
    decay_in = jnp.exp(cum).transpose(0, 1, 3, 2)
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, h_in) * decay_in[..., None]
    y = (y_intra + y_inter
         + xc * p["d_skip"][:, None]).reshape(Bsz, Sq, H, P)
    y = (y.reshape(Bsz, Sq, d_inner) * jax.nn.silu(z))
    y = y @ p["w_out"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)
