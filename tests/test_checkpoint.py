"""Checkpoint + model store roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree
from repro.checkpoint.model_store import ModelStore
from repro.utils.tree import tree_allclose


def test_pytree_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (3, 4)),
            "nested": {"b": jnp.arange(5), "c": jnp.ones((2,), jnp.bfloat16)}}
    save_pytree(str(tmp_path / "ckpt"), tree)
    loaded = load_pytree(str(tmp_path / "ckpt"), tree)
    assert tree_allclose(tree, loaded)


def test_model_store_freshest_and_eviction(tmp_path):
    store = ModelStore(str(tmp_path / "store"))
    tpl = {"w": jnp.zeros((4,))}
    for agent, epoch in [(0, 1), (1, 3), (2, 7), (0, 5)]:
        store.put({"w": jnp.full((4,), float(epoch))}, agent=agent,
                  epoch=epoch, samples=1.0)
    # newest-per-agent: agent 0 keeps epoch 5
    fresh = store.freshest(10)
    assert {(e.agent, e.epoch) for e in fresh} == {(0, 5), (1, 3), (2, 7)}
    loaded = store.load(fresh[0], tpl)
    assert float(loaded["w"][0]) == fresh[0].epoch
    # staleness eviction mirrors tau_max kick-out
    store.evict_stale(now_epoch=10, tau_max=5)
    assert {(e.agent, e.epoch) for e in store.entries} == {(2, 7)}


def test_model_store_persistence(tmp_path):
    root = str(tmp_path / "store2")
    s1 = ModelStore(root)
    s1.put({"w": jnp.ones((2,))}, agent=4, epoch=2, samples=3.0)
    s2 = ModelStore(root)  # fresh handle reads the index
    assert len(s2.entries) == 1 and s2.entries[0].agent == 4
